"""Benchmark: convergence vs staleness, per consistency policy.

Two statistical workloads — SGD matrix factorization (:mod:`repro.apps.mf`)
and logistic regression (:mod:`repro.apps.logreg`) — run on the executable
spec with a laggy network and a straggler, so staleness is real and
*measured* (``stats.max_observed_staleness``), not just configured.  Each
policy in {bsp, ssp(s), essp(s), vap, elastic} contributes one loss curve
per workload; the staleness sweep over ``s`` is the paper's
convergence-vs-staleness trade-off, and the ESSP rows demonstrate the
eager-push claim (arXiv:1410.8043): at an equal configured bound the
staleness workers actually observe can only shrink.

Gates:

* zero recorded bound violations in every leg;
* every curve converges (final loss below its start);
* for every workload and every swept ``s``, ESSP's measured read staleness
  <= SSP's at the same configured bound.

    PYTHONPATH=src python benchmarks/bench_convergence.py \
        [--smoke] [--json BENCH_convergence.json]
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.apps import logreg, mf
from repro.core import NetworkModel, policies

try:                                    # package import (benchmarks.run)
    from benchmarks import common as _common
except ImportError:                     # direct script run from benchmarks/
    import common as _common

N_WORKERS = 4
SEED = 7
VTHR = 0.1       # VAP element-wise bound, ~a few hot deltas deep
NORM_B = 1.0     # elastic whole-accumulator L2 bound


def _policy_matrix(smoke: bool):
    svals = [2] if smoke else [1, 2, 4]
    out = [("bsp", policies.bsp(), {"kind": "bsp"})]
    for s in svals:
        out.append((f"ssp{s}", policies.ssp(s),
                    {"kind": "ssp", "staleness": s}))
        out.append((f"essp{s}", policies.essp(s),
                    {"kind": "essp", "staleness": s}))
    out.append(("vap", policies.vap(VTHR),
                {"kind": "vap", "value_bound": VTHR}))
    out.append(("elastic", policies.elastic(NORM_B),
                {"kind": "elastic", "norm_bound": NORM_B}))
    return out


def _net():
    # delivery latency comparable to a compute period + a 3x straggler:
    # SSP reads genuinely run stale, so the sweep has something to measure
    return dict(network=NetworkModel(base_delay=0.6, jitter=0.3, seed=SEED),
                straggler={0: 3.0})


def _mf_leg(pol, n_clocks: int):
    ratings = mf.synthetic_ratings(seed=SEED)
    return mf.run_mf(ratings, 60, 40, 4, pol, N_WORKERS, n_clocks,
                     seed=SEED, collect_stats=True, **_net())


def _logreg_leg(pol, n_clocks: int):
    X, y = logreg.synthetic_classification(seed=SEED)
    return logreg.run_logreg(X, y, pol, N_WORKERS, n_clocks, seed=SEED,
                             collect_stats=True, **_net())


_WORKLOADS = (("mf", _mf_leg), ("logreg", _logreg_leg))


def run(smoke: bool = False) -> List[Dict]:
    n_clocks = 15 if smoke else 40
    rows: List[Dict] = []
    for wname, leg in _WORKLOADS:
        for pname, pol, desc in _policy_matrix(smoke):
            curve, stats = leg(pol, n_clocks)
            rows.append({
                "name": f"convergence/{wname}/{pname}",
                "workload": wname,
                "n_clocks": n_clocks,
                **desc,
                "first_loss": curve[0],
                "final_loss": curve[-1],
                "curve": [round(float(v), 6) for v in curve],
                "measured_staleness": int(stats.max_observed_staleness),
                "n_updates": stats.n_updates,
                "violations": len(stats.violations),
            })
    return rows


def gates(rows: List[Dict]) -> List[str]:
    failed = []
    by = {r["name"]: r for r in rows}
    for r in rows:
        if r["violations"]:
            failed.append(f"{r['name']}: {r['violations']} bound violations")
        if not r["final_loss"] < r["first_loss"]:
            failed.append(f"{r['name']}: did not converge "
                          f"({r['first_loss']:.4f} -> {r['final_loss']:.4f})")
    for wname, _ in _WORKLOADS:
        for r in rows:
            if r["workload"] != wname or r["kind"] != "essp":
                continue
            peer = by[f"convergence/{wname}/ssp{r['staleness']}"]
            print(f"# convergence/{wname} s={r['staleness']}: measured "
                  f"staleness essp {r['measured_staleness']} vs ssp "
                  f"{peer['measured_staleness']}, final loss "
                  f"{r['final_loss']:.4f} vs {peer['final_loss']:.4f}")
            if r["measured_staleness"] > peer["measured_staleness"]:
                failed.append(
                    f"{r['name']}: measured staleness "
                    f"{r['measured_staleness']} > ssp's "
                    f"{peer['measured_staleness']} at equal bound")
    return failed


def write_json(rows: List[Dict], path: str) -> None:
    _common.write_bench_json(path, "bench_convergence", rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (shorter runs, same gates)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write consolidated BENCH_convergence.json here")
    args = ap.parse_args()

    rows = run(smoke=args.smoke)
    for r in rows:
        print(f"{r['name']}: loss {r['first_loss']:.4f} -> "
              f"{r['final_loss']:.4f}, staleness {r['measured_staleness']}")
    failed = gates(rows)
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")
    for msg in failed:
        print(f"# GATE FAILED: {msg}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
