"""Roofline analysis from the dry-run artifacts (brief: ROOFLINE ANALYSIS).

Reads results/dryrun/*.json (written by repro.launch.dryrun), derives the
three per-device roofline terms for the single-pod mesh,

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

identifies the dominant term, computes MODEL_FLOPS/HLO_FLOPs (useful-compute
fraction — catches remat/redundancy waste), and emits the §Roofline table.

Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step (GLOBAL, whole mesh)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_record(rec: Dict, chips: int) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"]["total_bytes"]
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * chips
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_fraction": mf / hlo_total if hlo_total else 0.0,
        "peak_gib": rec["memory"]["peak_bytes_estimate"] / 2**30,
        "fits_16g": rec["memory"]["peak_bytes_estimate"] <= 16 * 2**30,
        "coll_breakdown": rec["collectives"]["bytes"],
        "bound_step_s": max(terms.values()),
    }


def load_all(mesh: str = "16x16", consistency: str = "cvap") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or rec.get("consistency") != consistency:
            continue
        chips = 512 if mesh == "2x16x16" else 256
        row = analyze_record(rec, chips)
        if row:
            out.append(row)
    return out


def suggestion(row: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    d = row["dominant"]
    if d == "collective":
        cb = row["coll_breakdown"]
        big = max(cb, key=cb.get)
        if big in ("all-gather", "reduce-scatter"):
            return ("sequence-parallel gather/scatter dominates — fuse the "
                    "per-layer all-gather pair or overlap with the matmuls")
        return ("delta all-reduce dominates — raise staleness/v_thr, "
                "compress deltas (bf16), or make the sync hierarchical")
    if d == "memory":
        return ("HBM-bound — bf16 state, larger compute tiles, or shard the "
                "replicated-activation axis (seq-parallel mixers)")
    return "compute-bound (good) — raise arithmetic intensity only via MFU tuning"


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "useful FLOP frac | peak GiB | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_fraction']:.2f} | "
            f"{r['peak_gib']:.1f} | {'Y' if r['fits_16g'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    rows = load_all()
    if not rows:
        print("no dry-run results found — run repro.launch.dryrun first")
        return
    print(markdown_table(rows))
    print("\nPer-pair bottleneck suggestions:")
    for r in sorted(rows, key=lambda r: -r["bound_step_s"]):
        print(f"  {r['arch']:24s} {r['shape']:12s} bound={r['dominant']:10s} "
              f"step≥{r['bound_step_s']*1e3:9.2f} ms — {suggestion(r)}")
    os.makedirs(os.path.join(RESULTS_DIR, ".."), exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "..", "roofline.md"), "w") as f:
        f.write(markdown_table(rows))
        f.write("\n## Suggestions\n")
        for r in rows:
            f.write(f"- {r['arch']} × {r['shape']}: {suggestion(r)}\n")
    with open(os.path.join(RESULTS_DIR, "..", "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
