"""Shared bench-result writer: one stamped schema for every ``BENCH_*.json``.

Every benchmark emitter (``bench_runtime``, ``bench_serving``,
``bench_autoscale``, ``bench_wal``, and the consolidated ``benchmarks.run``)
routes its JSON through :func:`write_bench_json`, so every artifact carries
the same provenance block — schema name + version, the git sha it was
measured at, a UTC timestamp, and the host/calibration meta.  Those are the
fields a perf-trajectory diff needs before comparing two artifacts means
anything: same schema, known commit, known host ceiling.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
from datetime import datetime, timezone
from typing import Dict, List, Optional

SCHEMA_VERSION = 2


def git_sha() -> Optional[str]:
    """The commit the numbers were measured at (None outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
    except OSError:
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def host_meta(**calibration) -> Dict:
    """Host identity + whatever calibration numbers the bench measured
    (e.g. ``proc_parallel_x2``, the physical 1->2 process scaling ceiling)."""
    meta = {
        "python": platform.python_version(),
        "machine": platform.machine(),
        "platform": platform.platform(),
        "cpus": os.cpu_count(),
    }
    meta.update({k: v for k, v in calibration.items() if v is not None})
    return meta


def write_bench_json(path: str, bench: str, rows: List[Dict],
                     calibration: Optional[Dict] = None) -> Dict:
    """Write one stamped bench artifact and return the document."""
    out = {
        "schema": f"{bench}/v{SCHEMA_VERSION}",
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "git_sha": git_sha(),
        "generated_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "meta": host_meta(**(calibration or {})),
        "rows": rows,
    }
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return out
