"""Benchmark: the autoscaling control loop — rebalanced vs static layouts.

Three legs, each an A/B against the identical workload with the autoscaler
off, and each gated (a failed gate exits 1 — the CI bench-smoke job runs
``--smoke`` and fails on regression):

* **rebalance** — Zipf-skewed row traffic (hot rows on even ids) through a
  deliberately over-provisioned static layout (every slot active, two of
  them nearly idle).  A cold slot still costs a frontier constraint and
  per-clock fan-out, so the autoscaler's drain/split cycle consolidates to
  a smaller balanced layout and **recovers updates/s**.  Thresholds are
  calibrated from a short probe run (fractions of the measured total load),
  not hard-coded rates, so the leg is host-independent.
  Gate: autoscaled updates/s > static updates/s.

* **serving** — six ``slo=0`` reader threads hammer a single read replica
  under sustained write traffic; serving copies hold the replica lock, so
  ingest starves and reads escalate to the master (SLO violations).  The
  autoscaler sees the windowed escalation rate and adds replicas, splitting
  the read load until ingest keeps up.
  Gate: autoscaled escalation rate < static escalation rate.

* **overhead** — the metrics layer itself (per-shard/per-process counters +
  the ClockMsg load piggyback) A/B'd against ``metrics=False``, best-of-3
  each way.  Gate: overhead < 3% of updates/s.

    PYTHONPATH=src python benchmarks/bench_autoscale.py \
        [--smoke] [--json BENCH_autoscale.json]
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ssp
from repro.runtime import (Autoscaler, AutoscalePolicy, PSRuntime,
                           ReadGateway, RuntimeConfig)

try:                                    # package import (benchmarks.run)
    from benchmarks import common as _common
except ImportError:                     # direct script run from benchmarks/
    import common as _common

R, C = 64, 128
ZIPF_ALPHA = 1.2
N_TOUCH = 24


def _x0(c: int = C):
    return {"w": np.zeros((R, c))}


def zipf_hot_fn(seed: int, c: int = C, n_touch: int = N_TOUCH):
    """Zipf(alpha) row traffic with the hot ranks on EVEN row ids: under
    the round-robin partition (``active[r % A]``) a 2-active layout puts
    every hot row on one slot, and a 4-active layout leaves the odd-row
    slots nearly idle (~9% of the mass split between them)."""
    p = np.array([1.0 / (i + 1) ** ZIPF_ALPHA for i in range(R)])
    p /= p.sum()
    ranked = sorted(range(R), key=lambda r: (r % 2, r))

    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        rows = r.choice(R, size=n_touch, replace=False, p=p)
        d = np.zeros((R, c))
        for i in rows:
            d[ranked[i]] = 0.01
        return {"w": d}
    return fn


# ---------------------------------------------------------------------------
# leg 1: rebalance — drain/split an over-provisioned skewed layout
# ---------------------------------------------------------------------------


def _one_rebalance(clocks: int, autoscale: bool,
                   policy: Optional[AutoscalePolicy]) -> Dict:
    rt = PSRuntime(RuntimeConfig(4, ssp(3), _x0(), n_shards=4,
                                 max_shards=4))
    t0 = time.perf_counter()
    rt.start(zipf_hot_fn(1), clocks, timeout=600)
    asc = Autoscaler(rt, policy=policy).start() if autoscale else None
    stats = rt.wait()
    if asc is not None:
        asc.stop()
    wall = time.perf_counter() - t0
    m = rt.metrics()
    return {
        "updates_per_s": stats.n_updates / wall,
        "clocks_per_s": clocks / wall,
        "rows_applied": sum(s.rows_applied for s in m.shards),
        "final_active": list(m.membership.active),
        "membership_ops": m.membership.n_ops,
        "actions": asc.summary() if asc else {},
        "wall_s": wall,
    }


def calibrate_load(clocks: int = 20) -> float:
    """Total applied rows/s of a short static probe run — the autoscaler
    thresholds below are fractions of this, so the leg doesn't bake in one
    host's absolute rates."""
    r = _one_rebalance(clocks, autoscale=False, policy=None)
    return r["rows_applied"] / r["wall_s"]


def run_rebalance(clocks: int, best_of: int = 2) -> List[Dict]:
    total = calibrate_load()
    pol = AutoscalePolicy(
        interval=0.05, cooldown=0.1,
        split_imbalance=1.3, split_min_rows_s=total / 8,
        # an active slot earning <1/8 of the total load costs more in
        # frontier/fan-out than it gives back; a balanced 3-way layout
        # sits at ~1/3 each, comfortably above the drain line
        drain_max_rows_s=total / 8, min_shards=1)
    rows = []
    for variant, auto in (("static", False), ("autoscaled", True)):
        runs = [_one_rebalance(clocks, auto, pol if auto else None)
                for _ in range(best_of)]
        best = max(runs, key=lambda r: r["updates_per_s"])
        best["name"] = f"autoscale/rebalance/{variant}"
        best["us_per_call"] = 1e6 / max(best["updates_per_s"], 1e-9)
        rows.append(best)
    return rows


# ---------------------------------------------------------------------------
# leg 2: serving — replica scale-up drops the SLO-violation rate
# ---------------------------------------------------------------------------


def _serving_fn(w, clock, view, rng):
    r = np.random.default_rng((5, w, clock))
    g = r.normal(size=(R, 256)) * 0.01
    for _ in range(8):                      # light per-clock compute
        g = g * 0.999 + 0.001
    return {"w": g}


def _one_serving(clocks: int, autoscale: bool, n_readers: int = 6) -> Dict:
    rt = PSRuntime(RuntimeConfig(4, ssp(3), _x0(256), n_shards=2))
    rt.start(_serving_fn, clocks, timeout=600)
    gw = ReadGateway(rt, n_replicas=1)
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            try:
                gw.read("w", slo=0, timeout=0.02)
            except BaseException:
                pass                        # deadline races at shutdown

    threads = [threading.Thread(target=reader, daemon=True)
               for _ in range(n_readers)]
    for th in threads:
        th.start()
    asc = None
    if autoscale:
        # membership churn disabled: this leg isolates the replica signal
        asc = Autoscaler(rt, gw, AutoscalePolicy(
            interval=0.1, cooldown=0.1, escalation_hi=0.05,
            escalation_lo=0.0, max_replicas=3, min_window_reads=5,
            split_imbalance=float("inf"), drain_max_rows_s=0.0)).start()
    scaleup = None                          # (reads, escalations) at 1st op
    t0 = time.perf_counter()
    while rt.running and not stop.is_set():
        if (asc is not None and scaleup is None
                and any(a.kind == "add_replica" and a.ok
                        for a in asc.actions)):
            with gw._slock:
                scaleup = (gw.stats.n_reads, gw.stats.n_escalations)
        time.sleep(0.005)
    stats = rt.wait()
    if asc is not None:
        asc.stop()
    stop.set()
    for th in threads:
        th.join(timeout=5)
    wall = time.perf_counter() - t0
    st = gw.stats
    n_live = gw.replicas.n_live
    row = {
        "n_reads": st.n_reads,
        "n_escalations": st.n_escalations,
        "escalation_rate": st.n_escalations / max(st.n_reads, 1),
        "reads_per_s": st.n_reads / wall,
        "updates_per_s": stats.n_updates / wall,
        "final_replicas": n_live,
        "actions": asc.summary() if asc else {},
    }
    if scaleup is not None:
        r0, e0 = scaleup
        row["escalation_rate_before_scaleup"] = e0 / max(r0, 1)
        row["escalation_rate_after_scaleup"] = (
            (st.n_escalations - e0) / max(st.n_reads - r0, 1))
    gw.close()
    return row


def run_serving(clocks: int) -> List[Dict]:
    rows = []
    for variant, auto in (("static_1_replica", False), ("autoscaled", True)):
        r = _one_serving(clocks, auto)
        r["name"] = f"autoscale/serving/{variant}"
        r["us_per_call"] = 1e6 / max(r["reads_per_s"], 1e-9)
        rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# leg 3: metrics overhead A/B
# ---------------------------------------------------------------------------


def _overhead_fn(w, clock, view, rng):
    g = rng.normal(0.0, 1.0, size=(R, C))
    m = rng.normal(0.0, 1.0, size=(R, R)) / 8.0
    for _ in range(20):
        g = m @ g
        g /= max(1.0, float(np.abs(g).max()))
    return {"w": 0.01 * g}


def _one_overhead(clocks: int, metrics: bool) -> float:
    rt = PSRuntime(RuntimeConfig(2, ssp(3), _x0(), n_shards=2,
                                 metrics=metrics))
    t0 = time.perf_counter()
    rt.start(_overhead_fn, clocks, timeout=600)
    stats = rt.wait()
    return stats.n_updates / (time.perf_counter() - t0)


def run_overhead(clocks: int, best_of: int = 3) -> List[Dict]:
    rows = []
    for variant, on in (("off", False), ("on", True)):
        ups = max(_one_overhead(clocks, on) for _ in range(best_of))
        rows.append({
            "name": f"autoscale/metrics_overhead/{variant}",
            "us_per_call": 1e6 / ups,
            "updates_per_s": ups,
            "metrics": on,
        })
    off = rows[0]["updates_per_s"]
    on = rows[1]["updates_per_s"]
    rows[1]["overhead_frac"] = max(0.0, 1.0 - on / off)
    return rows


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def run(smoke: bool = False) -> List[Dict]:
    rows = []
    rows += run_rebalance(clocks=120 if smoke else 240)
    rows += run_serving(clocks=150 if smoke else 300)
    rows += run_overhead(clocks=20 if smoke else 40)
    return rows


def gates(rows: List[Dict]) -> List[str]:
    by = {r["name"]: r for r in rows}
    failed = []
    reb_s = by["autoscale/rebalance/static"]["updates_per_s"]
    reb_a = by["autoscale/rebalance/autoscaled"]["updates_per_s"]
    print(f"# rebalance: autoscaled {reb_a:.0f} upd/s vs static {reb_s:.0f} "
          f"upd/s (x{reb_a / max(reb_s, 1e-9):.2f}), final layout "
          f"{by['autoscale/rebalance/autoscaled']['final_active']} vs "
          f"{by['autoscale/rebalance/static']['final_active']}")
    if reb_a <= reb_s:
        failed.append("rebalance: autoscaled layout no faster than static")
    srv_s = by["autoscale/serving/static_1_replica"]["escalation_rate"]
    srv_a = by["autoscale/serving/autoscaled"]["escalation_rate"]
    print(f"# serving: escalation rate {srv_a:.3f} autoscaled "
          f"({by['autoscale/serving/autoscaled']['final_replicas']} replicas)"
          f" vs {srv_s:.3f} static (1 replica)")
    after = by["autoscale/serving/autoscaled"].get(
        "escalation_rate_after_scaleup")
    if after is not None:
        print(f"# serving: autoscaled escalation rate after first scale-up "
              f"{after:.3f}")
    if srv_a >= srv_s:
        failed.append("serving: replica scale-up did not drop the "
                      "SLO-violation (escalation) rate")
    ovh = by["autoscale/metrics_overhead/on"]["overhead_frac"]
    print(f"# metrics overhead: {ovh * 100:.1f}% of updates/s (gate <3%)")
    if ovh >= 0.03:
        failed.append(f"metrics overhead {ovh * 100:.1f}% >= 3%")
    return failed


def write_json(rows: List[Dict], path: str) -> None:
    _common.write_bench_json(path, "bench_autoscale", rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (shorter runs, same gates)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write consolidated BENCH_autoscale.json here")
    args = ap.parse_args()

    rows = run(smoke=args.smoke)
    for r in rows:
        extra = ""
        if "final_active" in r:
            extra = f", layout {r['final_active']}, actions {r['actions']}"
        if "escalation_rate" in r:
            extra = (f", esc rate {r['escalation_rate']:.3f}, "
                     f"{r['final_replicas']} replicas")
        print(f"{r['name']}: {r['updates_per_s']:.0f} upd/s{extra}")
    failed = gates(rows)
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")
    for msg in failed:
        print(f"# GATE FAILED: {msg}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
