"""Benchmark: the threaded PS runtime — updates/sec and read latency.

For each consistency policy and worker-thread count, run a fixed number of
clocks of dense SGD-style update traffic through the real runtime
(one client process per worker, hash-partitioned shards) while a foreground
reader hammers Get() against a live process cache.  Reported per
configuration:

  * updates/sec        — Inc throughput through the full shard pipeline;
  * clocks/sec         — end-to-end period rate (includes controller blocking);
  * read p50/p95 (us)  — serving-read latency under concurrent update traffic;
  * blocked fraction   — share of wall time spent in clock/value gates.

This is the systems half of the paper's claim, measured on real threads:
relaxing consistency (BSP -> SSP -> VAP) should buy throughput.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from repro.core import bsp, ssp, vap
from repro.runtime import PSRuntime

KEYS = {"w": (64, 8), "b": (16,)}
CLOCKS = 120


def _update_fn(w, clock, view, rng):
    return {k: rng.normal(0.0, 0.01, size=shape)
            for k, shape in KEYS.items()}


def _one(name: str, policy, n_workers: int) -> Dict:
    x0 = {k: np.zeros(shape) for k, shape in KEYS.items()}
    rt = PSRuntime(n_workers, policy, x0, n_shards=2,
                   threads_per_process=1, seed=0)
    lat: List[float] = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            t0 = time.perf_counter()
            rt.read("w")
            lat.append(time.perf_counter() - t0)
            time.sleep(5e-4)

    t0 = time.perf_counter()
    rt.start(_update_fn, CLOCKS, timeout=300)
    th = threading.Thread(target=reader, daemon=True)
    th.start()
    stats = rt.wait()
    stop.set()
    th.join(timeout=5)
    wall = time.perf_counter() - t0

    q = np.quantile(np.asarray(lat), [0.5, 0.95]) if lat else [0.0, 0.0]
    blocked = (stats.block_time_clock + stats.block_time_value) / (
        max(wall, 1e-9) * n_workers)
    return {
        "name": f"runtime/{name}/w{n_workers}",
        "us_per_call": wall / max(stats.n_updates, 1) * 1e6,
        "updates_per_s": stats.n_updates / wall,
        "clocks_per_s": CLOCKS / wall,
        "read_p50_us": float(q[0]) * 1e6,
        "read_p95_us": float(q[1]) * 1e6,
        "blocked_frac": blocked,
        "n_reads": len(lat),
    }


def run() -> List[Dict]:
    rows = []
    for name, policy in [("bsp", bsp()), ("ssp3", ssp(3)),
                         ("vap0.05", vap(0.05))]:
        for n in (1, 2, 4):
            rows.append(_one(name, policy, n))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']}: {r['updates_per_s']:.0f} upd/s, "
              f"{r['clocks_per_s']:.1f} clocks/s, "
              f"read p50 {r['read_p50_us']:.0f}us p95 {r['read_p95_us']:.0f}us, "
              f"blocked {r['blocked_frac']*100:.0f}%")
