"""Benchmark: the PS runtime — updates/sec and read latency, per transport.

For each (consistency policy x transport x worker count), run a fixed number
of clocks of SGD-style update traffic (a small matmul chain per clock, the
compute:communication ratio of a real worker) through the real runtime while
a foreground reader hammers Get() against a live view.  Reported per
configuration:

  * updates/sec        — Inc throughput through the full shard pipeline;
  * clocks/sec         — end-to-end period rate (includes controller blocking);
  * read p50/p99 (us)  — serving-read latency under concurrent update traffic
                         (process cache for threads, locked master shards for
                         the wire transports);
  * blocked fraction   — share of wall time spent in clock/value gates.

This is the systems half of the paper's claim, measured on real parallelism:
relaxing consistency (BSP -> SSP -> VAP) buys throughput, and the
multi-process transports (``proc``/``tcp``) keep scaling past the GIL where
the threaded backend *collapses* under compute-heavy workers (GIL thrash).

Worker scaling is only meaningful against what the host can physically
parallelize, so the bench first **calibrates**: it forks two busy numpy
processes and measures their aggregate throughput vs one
(``meta.proc_parallel_x2`` in the JSON).  A machine with two real cores
reports ~2.0 and the proc transport should convert >=1.5x of it into
updates/s; a container whose "2 CPUs" serialize (some sandboxes report ~1.0)
caps every transport at ~1x, and the number to read instead is
proc-vs-queue at the same worker count.

CLI (the CI bench-smoke job runs the tiny config and uploads the JSON):

    PYTHONPATH=src python benchmarks/bench_runtime.py \
        [--smoke] [--json BENCH_runtime.json] \
        [--transports queue,proc] [--workers 1,2,4] [--clocks N]
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import bsp, ssp, vap
from repro.runtime import PSRuntime, RuntimeConfig

try:                                    # package import (benchmarks.run)
    from benchmarks import common as _common
except ImportError:                     # direct script run: python benchmarks/bench_runtime.py
    import common as _common

KEYS = {"w": (64, 8), "b": (16,)}
CLOCKS = 60
# matmul chain length per clock (~5 ms of numpy).  A paper "clock" is a full
# pass over the worker's data partition, so per-clock compute dwarfs the
# per-clock update traffic; this keeps the bench at a realistic
# compute:communication ratio while still finishing in seconds.
COMPUTE_ITERS = 200

_POLICIES = [("bsp", bsp), ("ssp3", lambda: ssp(3)),
             ("vap0.05", lambda: vap(0.05))]


def calibrate_parallelism(seconds: float = 0.5) -> float:
    """Aggregate throughput of two forked busy-numpy processes relative to
    one — the host's physical ceiling for 1->2 process scaling."""
    import multiprocessing

    def _busy(reps: int) -> float:
        a = np.ones(500_000)
        b = np.full(500_000, 0.5)
        t0 = time.perf_counter()
        for _ in range(reps):
            np.add(a, b, out=a)
            np.multiply(a, 0.999, out=a)
        return time.perf_counter() - t0

    reps = 50
    while _busy(reps) < seconds / 4:
        reps *= 2
    one = min(_busy(reps) for _ in range(2))
    ctx = multiprocessing.get_context("fork")
    procs = [ctx.Process(target=_busy, args=(reps,)) for _ in range(2)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    two = time.perf_counter() - t0
    return 2.0 * one / max(two, 1e-9)


def _mk_update_fn(compute_iters: int):
    """SGD-flavored worker: read the table, grind a few matmuls, push a
    bounded delta.  The compute chain is the point — with real work per
    clock, transport scaling is measured at a realistic compute:comm ratio
    (the zero-copy A/B dials it down to make the run wire-bound instead)."""
    def _update_fn(w, clock, view, rng):
        x = view.get("w")                               # (64, 8) read path
        g = rng.normal(0.0, 1.0, size=KEYS["w"])
        m = rng.normal(0.0, 1.0, size=(64, 64)) / 8.0
        for _ in range(compute_iters):
            g = m @ g + 0.1 * x
            g /= max(1.0, float(np.abs(g).max()))
        return {"w": 0.01 * g,
                "b": rng.normal(0.0, 0.01, size=KEYS["b"])}
    return _update_fn


_update_fn = _mk_update_fn(COMPUTE_ITERS)


def _one(name: str, policy, n_workers: int, transport: str,
         clocks: int, zero_copy: Optional[bool] = None,
         ps_kernels: bool = False, update_fn=None,
         wire: Optional[str] = None, trace=None,
         variant: Optional[str] = None) -> Dict:
    x0 = {k: np.zeros(shape) for k, shape in KEYS.items()}
    rt = PSRuntime(RuntimeConfig(n_workers, policy, x0, n_shards=2,
                   threads_per_process=1, seed=0, transport=transport,
                   zero_copy=zero_copy, ps_kernels=ps_kernels, trace=trace))
    lat: List[float] = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            t0 = time.perf_counter()
            rt.read("w")
            lat.append(time.perf_counter() - t0)
            time.sleep(5e-4)

    t0 = time.perf_counter()
    rt.start(update_fn or _update_fn, clocks, timeout=600)
    th = threading.Thread(target=reader, daemon=True)
    th.start()
    stats = rt.wait()
    stop.set()
    th.join(timeout=5)
    wall = time.perf_counter() - t0

    q = np.quantile(np.asarray(lat), [0.5, 0.99]) if lat else [0.0, 0.0]
    blocked = (stats.block_time_clock + stats.block_time_value) / (
        max(wall, 1e-9) * n_workers)
    suffix = f"/{wire}" if wire else ""
    if variant:
        suffix += f"/{variant}"
    row = {
        "name": f"runtime/{name}/{transport}/w{n_workers}{suffix}",
        "policy": name,
        "transport": transport,
        "workers": n_workers,
        "us_per_call": wall / max(stats.n_updates, 1) * 1e6,
        "updates_per_s": stats.n_updates / wall,
        "clocks_per_s": clocks / wall,
        "read_p50_us": float(q[0]) * 1e6,
        "read_p99_us": float(q[1]) * 1e6,
        "blocked_frac": blocked,
        "n_reads": len(lat),
    }
    if wire:
        row["wire"] = wire
    if variant:
        row["variant"] = variant
    return row


def run_zero_copy_ab(workers: int = 2, clocks: int = 12,
                     policy_name: str = "ssp3") -> List[Dict]:
    """A/B rows for the shm wire at equal workers: the pickle-5 frame path
    vs the zero-copy raw wire + PS kernels.  Compute per clock is dialed
    way down so the run is wire/apply-bound — this is the configuration the
    zero-copy work targets, and the CI gate compares exactly these rows."""
    from repro.kernels import pallas_mode
    pallas_mode()       # warm the one-time jax import out of the timed runs
    fn = _mk_update_fn(2)
    rows = []
    for wire, zc, pk in (("pickle", False, False), ("zero_copy", True, True)):
        # best-of-2: scheduler noise on small hosts swamps a single short
        # run, and the gate below must not flake on it
        runs = [_one(policy_name, ssp(3), workers, "shm", clocks,
                     zero_copy=zc, ps_kernels=pk, update_fn=fn, wire=wire)
                for _ in range(2)]
        rows.append(max(runs, key=lambda r: r["updates_per_s"]))
    return rows


def run_trace_ab(workers: int = 2, clocks: int = 12,
                 policy_name: str = "ssp3") -> List[Dict]:
    """A/B rows for the tracing tier at equal workers on wire-bound traffic
    (compute dialed down like the zero-copy A/B, so per-update overhead is
    maximally visible): trace off — twice, the A/A pair bounds run-to-run
    noise — vs sampled (5% of lifelines) vs full (every event).  The CI
    gate asserts sampled tracing costs <5% of updates/s; full tracing is
    reported, not gated."""
    fn = _mk_update_fn(2)
    rows = []
    for variant, trace in (("trace_off", None), ("trace_off2", None),
                           ("trace_sampled", {"sample": 0.05}),
                           ("trace_full", 1.0)):
        # best-of-2 per config, same rationale as the zero-copy A/B
        runs = [_one(policy_name, ssp(3), workers, "shm", clocks,
                     update_fn=fn, trace=trace, variant=variant)
                for _ in range(2)]
        rows.append(max(runs, key=lambda r: r["updates_per_s"]))
    return rows


def run(transports: Sequence[str] = ("queue", "proc"),
        workers: Sequence[int] = (1, 2, 4),
        clocks: int = CLOCKS,
        policies=None) -> List[Dict]:
    rows = []
    for name, mk in (policies or _POLICIES):
        for transport in transports:
            for n in workers:
                rows.append(_one(name, mk(), n, transport, clocks))
    return rows


def write_json(rows: List[Dict], path: str,
               parallel_x2: Optional[float] = None) -> None:
    """Consolidated BENCH_runtime.json: the perf trajectory future PRs
    compare against (updates/s + read p50/p99 per policy x transport x
    workers), stamped by benchmarks.common with git sha, UTC timestamp and
    the host parallelism calibration."""
    _common.write_bench_json(path, "bench_runtime", rows,
                             calibration={"proc_parallel_x2": parallel_x2})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: ssp3 only, 1-2 workers, few clocks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write consolidated BENCH_runtime.json here")
    ap.add_argument("--transports", default=None,
                    help="comma list from queue,tcp,shm,proc")
    ap.add_argument("--workers", default=None, help="comma list, e.g. 1,2,4")
    ap.add_argument("--clocks", type=int, default=None)
    ap.add_argument("--ab-zero-copy", action="store_true",
                    help="append shm zero-copy vs pickle A/B rows (equal "
                         "workers, wire-bound traffic) and FAIL if the "
                         "zero-copy path is slower than the pickle path")
    ap.add_argument("--ab-trace", action="store_true",
                    help="append trace off/off/sampled/full A/B rows (equal "
                         "workers, wire-bound traffic) and FAIL if sampled "
                         "tracing costs >=5%% of updates/s")
    args = ap.parse_args()

    transports = (args.transports.split(",") if args.transports
                  else ("queue", "proc"))
    if args.smoke:
        workers = (1, 2)
        clocks = args.clocks or 8
        policies = [("ssp3", lambda: ssp(3))]
    else:
        workers = (1, 2, 4)
        clocks = args.clocks or CLOCKS
        policies = _POLICIES
    if args.workers:
        workers = tuple(int(w) for w in args.workers.split(","))

    cal = calibrate_parallelism()
    print(f"# host calibration: 2-process aggregate throughput x{cal:.2f} "
          f"(physical ceiling for 1->2 worker scaling)")
    rows = run(transports=transports, workers=workers, clocks=clocks,
               policies=policies)
    for r in rows:
        print(f"{r['name']}: {r['updates_per_s']:.0f} upd/s, "
              f"{r['clocks_per_s']:.1f} clocks/s, "
              f"read p50 {r['read_p50_us']:.0f}us p99 {r['read_p99_us']:.0f}us, "
              f"blocked {r['blocked_frac']*100:.0f}%")
    pol0 = rows[0]["policy"]
    per = {(r["transport"], r["workers"]): r["updates_per_s"]
           for r in rows if r["policy"] == pol0}
    for transport in transports:
        if (transport, 1) in per and (transport, 2) in per:
            x = per[(transport, 2)] / max(per[(transport, 1)], 1e-9)
            print(f"# {transport}: 1->2 worker scaling x{x:.2f} "
                  f"(host ceiling x{cal:.2f})")
    for w in sorted({r["workers"] for r in rows}):
        if ("proc" in transports and "queue" in transports
                and (("proc", w) in per and ("queue", w) in per)):
            print(f"# w{w}: proc vs queue x"
                  f"{per[('proc', w)] / max(per[('queue', w)], 1e-9):.2f}")
    gate_failed = False
    if args.ab_zero_copy:
        ab = run_zero_copy_ab(workers=2, clocks=args.clocks or 12)
        rows.extend(ab)
        by_wire = {r["wire"]: r["updates_per_s"] for r in ab}
        x = by_wire["zero_copy"] / max(by_wire["pickle"], 1e-9)
        print(f"# shm wire A/B @ w2: zero-copy {by_wire['zero_copy']:.0f} "
              f"upd/s vs pickle {by_wire['pickle']:.0f} upd/s (x{x:.2f})")
        if x < 1.0:
            print("# GATE FAILED: zero-copy path slower than pickle path")
            gate_failed = True
    if args.ab_trace:
        ab = run_trace_ab(workers=2, clocks=args.clocks or 12)
        rows.extend(ab)
        by = {r["variant"]: r["updates_per_s"] for r in ab}
        base = max(by["trace_off"], by["trace_off2"])
        aa = abs(by["trace_off"] - by["trace_off2"]) / max(base, 1e-9)
        xs = by["trace_sampled"] / max(base, 1e-9)
        xf = by["trace_full"] / max(base, 1e-9)
        print(f"# trace A/B @ w2: off {base:.0f} upd/s "
              f"(A/A spread {aa * 100:.1f}%), sampled x{xs:.2f}, "
              f"full x{xf:.2f}")
        if xs < 0.95:
            print("# GATE FAILED: sampled tracing costs >=5% of updates/s")
            gate_failed = True
    if args.json:
        write_json(rows, args.json, parallel_x2=cal)
        print(f"# wrote {args.json}")
    if gate_failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
