"""Benchmark: the durability tier's hot-path cost (repro.runtime.wal).

One workload, three legs — identical update traffic with the write-ahead
log off, group-committing without fsync, and fsyncing at every clock
boundary — plus a recovery-throughput row:

* **off** — ``wal_dir`` unset: the PR-6 apply hot path, the baseline.
* **group_commit** — ``wal_fsync="none"``: frames are encoded under the
  shard lock (owned bytes, FIFO-behind the apply) and flushed to the OS
  page cache once per clock boundary.  This is the intended production
  configuration; the gate bounds its overhead at <10% of updates/s.
* **fsync_boundary** — ``wal_fsync="boundary"``: an ``fsync`` per group
  commit.  Reported, not gated — the cost is the storage stack's, and the
  A/B against *group_commit* is exactly the durability premium the README
  "Durability" section trades off.
* **recovery** — genesis ``recover_to_vc`` over the group-commit leg's
  log: replayed parts/s (how fast a killed host catches up from disk).

    PYTHONPATH=src python benchmarks/bench_wal.py \
        [--smoke] [--json BENCH_wal.json]
"""
from __future__ import annotations

import argparse
import os
import shutil
import statistics
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ssp
from repro.runtime import PSRuntime, RuntimeConfig, recover_to_vc

try:                                    # package import (benchmarks.run)
    from benchmarks import common as _common
except ImportError:                     # direct script run from benchmarks/
    import common as _common

R, C = 64, 128


def _x0():
    return {"w": np.zeros((R, C))}


HOT_ROWS = 8


def _fn(w, clock, view, rng):
    g = rng.normal(0.0, 1.0, size=(R, C))
    m = rng.normal(0.0, 1.0, size=(R, R)) / 8.0
    for _ in range(40):                     # per-clock compute
        g = m @ g
        g /= max(1.0, float(np.abs(g).max()))
    # sparse delta — a few hot rows per clock, the paper's motivating
    # access pattern (topic models / sparse regression); the runtime
    # elides all-zero rows at flush, so parts carry only these rows
    d = np.zeros((R, C))
    hot = rng.choice(R, size=HOT_ROWS, replace=False)
    d[hot] = 0.01 * g[hot]
    return {"w": d}


def _one_leg(clocks: int, wal_dir: Optional[str],
             wal_fsync: Optional[str]) -> Dict:
    rt = PSRuntime(RuntimeConfig(2, ssp(3), _x0(), n_shards=2,
                                 wal_dir=wal_dir, wal_fsync=wal_fsync))
    t0 = time.perf_counter()
    rt.start(_fn, clocks, timeout=600)
    stats = rt.wait()
    wall = time.perf_counter() - t0
    row = {
        "updates_per_s": stats.n_updates / wall,
        "clocks_per_s": clocks / wall,
        "wall_s": wall,
    }
    if wal_dir:
        m = rt.metrics()
        row["wal_bytes"] = sum(s.wal_bytes for s in m.shards)
        row["wal_commits"] = sum(s.wal_commits for s in m.shards)
        row["wal_segments"] = sum(s.wal_segments for s in m.shards)
        row["wal_fsync_s"] = sum(s.wal_fsync_s for s in m.shards)
    return row


def _recovery_row(wal_dir: str) -> Dict:
    t0 = time.perf_counter()
    rec = recover_to_vc(_x0(), wal_dir)
    wall = time.perf_counter() - t0
    replayed = int(rec["applied_parts"].sum())
    return {
        "name": "wal/recovery_genesis",
        "replayed_parts": replayed,
        "parts_per_s": replayed / max(wall, 1e-9),
        "us_per_call": 1e6 * wall / max(replayed, 1),
        "wall_s": wall,
    }


_VARIANTS = (("off", None), ("group_commit", "none"),
             ("fsync_boundary", "boundary"))


def run(smoke: bool = False, best_of: int = 5) -> List[Dict]:
    clocks = 200 if smoke else 400
    rows: List[Dict] = []
    tmp = tempfile.mkdtemp(prefix="bench_wal_")
    try:
        # interleave the reps (off, gc, fsync / gc, fsync, off / ...) —
        # rotating the leg order per round so neither leg systematically
        # inherits the box state its predecessor leaves (fsync's I/O-idle
        # tail, cache heat) — then take the SECOND-best rep per leg,
        # robust to a single lucky/unlucky rep in either direction
        runs: Dict[str, List] = {v: [] for v, _ in _VARIANTS}
        for i in range(best_of):
            for j in range(len(_VARIANTS)):
                variant, fsync = _VARIANTS[(i + j) % len(_VARIANTS)]
                d = (None if fsync is None
                     else os.path.join(tmp, f"{variant}_{i}"))
                runs[variant].append((_one_leg(clocks, d, fsync), d))
        keep_dir = None
        for variant, _ in _VARIANTS:
            ranked = sorted(runs[variant],
                            key=lambda r: r[0]["updates_per_s"], reverse=True)
            best, d = ranked[1] if len(ranked) > 1 else ranked[0]
            best["name"] = f"wal/{variant}"
            best["us_per_call"] = 1e6 / max(best["updates_per_s"], 1e-9)
            rows.append(best)
            if variant == "group_commit":
                keep_dir = d
                # the gated number: per-ROUND paired ratio (gc rep i over
                # off rep i, run back-to-back), median over rounds — pairing
                # cancels box-level drift that independent per-leg picks
                # cannot, which is what makes the gate stable on shared CPUs
                best["overhead_vs_off"] = max(0.0, 1.0 - statistics.median(
                    g[0]["updates_per_s"] / o[0]["updates_per_s"]
                    for o, g in zip(runs["off"], runs["group_commit"])))
        rows.append(_recovery_row(keep_dir))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def gates(rows: List[Dict]) -> List[str]:
    by = {r["name"]: r for r in rows}
    failed = []
    off = by["wal/off"]["updates_per_s"]
    gc = by["wal/group_commit"]["updates_per_s"]
    fs = by["wal/fsync_boundary"]["updates_per_s"]
    overhead = by["wal/group_commit"].get(
        "overhead_vs_off", max(0.0, 1.0 - gc / off))
    print(f"# wal: off {off:.0f} upd/s, group-commit {gc:.0f} upd/s "
          f"({overhead * 100:.1f}% overhead, gate <10%), fsync/boundary "
          f"{fs:.0f} upd/s ({by['wal/fsync_boundary']['wal_fsync_s']:.2f}s "
          f"in fsync)")
    print(f"# wal: recovery replays "
          f"{by['wal/recovery_genesis']['parts_per_s']:.0f} parts/s")
    if overhead >= 0.10:
        failed.append(f"wal group-commit overhead {overhead * 100:.1f}% "
                      f">= 10% of updates/s")
    return failed


def write_json(rows: List[Dict], path: str) -> None:
    _common.write_bench_json(path, "bench_wal", rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (shorter runs, same gates)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write consolidated BENCH_wal.json here")
    args = ap.parse_args()

    rows = run(smoke=args.smoke)
    for r in rows:
        if "updates_per_s" in r:
            print(f"{r['name']}: {r['updates_per_s']:.0f} upd/s")
        else:
            print(f"{r['name']}: {r['parts_per_s']:.0f} parts/s replayed")
    failed = gates(rows)
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")
    for msg in failed:
        print(f"# GATE FAILED: {msg}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
