"""Benchmark: LDA convergence quality per consistency model (paper §5).

Same corpus and clock budget for every policy; reports the final corpus
log-likelihood and the simulated wall time — the quality/throughput trade
the consistency knobs expose.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import NetworkModel, bsp, cap, cvap, ssp, vap
from repro.data import synthetic_corpus
from repro.apps import lda


def run() -> List[Dict]:
    corpus = synthetic_corpus(n_docs=32, vocab_size=100, n_topics=5,
                              doc_len=50, seed=1)
    rows = []
    for name, pol in [("bsp", bsp()), ("ssp_s2", ssp(2)), ("cap_s2", cap(2)),
                      ("vap", vap(20.0)), ("cvap", cvap(2, 20.0))]:
        lls, stats = lda.run_lda(
            corpus, n_topics=5, policy=pol, n_workers=8, n_clocks=6, seed=0,
            network=NetworkModel(base_delay=0.4, jitter=0.3, seed=1),
            straggler={0: 2.0}, collect_stats=True)
        rows.append({
            "name": f"lda_convergence/{name}",
            "ll_start": lls[0],
            "ll_final": lls[-1],
            "sim_time": stats.sim_time,
            "ll_per_sim_s": (lls[-1] - lls[0]) / stats.sim_time,
            "max_staleness": stats.max_observed_staleness,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
