"""Benchmark: the read-replica serving tier — throughput & latency per SLO.

For each (runtime transport x replica count x staleness SLO), stream
SGD-style updates through the real runtime while reader threads hammer the
:class:`ReadGateway`; the ``replicas=0`` rows are the **locked-master
baseline** (``master_value()``: per-shard-locked assembly of the
authoritative blocks — what serving looked like before the replica tier).
Reported per configuration:

  * reads/sec + read p50/p99 (us)  — serving throughput under live updates;
  * mean/max measured staleness    — the stamp the gateway puts on every
                                     response, measured against the master's
                                     applied vector clock (never above the
                                     requested SLO by construction);
  * escalations                    — reads the replicas could not serve
                                     within the SLO before the deadline.

The claim to read on this host (see the calibration caveat in
BENCH_runtime.json / ROADMAP): at *equal worker count*, **2-replica**
reads beat locked-master reads — the replica copy is a contiguous memcpy
off the hot shard locks, while the master read assembles and scatters
under them, and the fan-out spreads readers across replica locks.  A
single replica funnels every reader through one lock while still paying
the publish/ingest cost, so r1 rows land *below* the baseline: the tier
pays off at fan-out >= 2, which is its reason to exist.

CLI (the CI bench-smoke job runs the tiny config and uploads the JSON):

    PYTHONPATH=src python benchmarks/bench_serving.py \
        [--smoke] [--json BENCH_serving.json] \
        [--transports queue,proc] [--replicas 1,2] [--slos 0,3,any]
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import ssp
from repro.runtime import PSRuntime, ReadGateway, RuntimeConfig

try:                                    # package import (benchmarks.run)
    from benchmarks import common as _common
except ImportError:                     # direct script run from benchmarks/
    import common as _common

KEYS = {"w": (512, 64)}       # 256 KiB of float64: copies & scatters matter
CLOCKS = 40
COMPUTE_ITERS = 60            # worker matmul chain per clock (~ms of numpy)
SERVING_OF = {"queue": "queue", "shm": "shm", "proc": "shm", "tcp": "tcp"}


def _update_fn(w, clock, view, rng):
    view.get("w")                       # exercise the worker read path too
    g = rng.normal(0.0, 1.0, size=(64, 64)) / 8.0
    v = rng.normal(0.0, 1.0, size=(64, KEYS["w"][1]))
    for _ in range(COMPUTE_ITERS):
        v = g @ v
        v /= max(1.0, float(np.abs(v).max()))
    # SGD-realistic sparse touch: a 64-row slice of the 512-row key (the
    # all-zero rows are elided before they reach the wire), so the serving
    # value stays big while per-clock publish traffic stays minibatch-sized
    delta = np.zeros(KEYS["w"])
    r0 = int(rng.integers(0, KEYS["w"][0] - 64))
    delta[r0:r0 + 64] = 0.01 * v
    return {"w": delta}


def _one(transport: str, n_replicas: int, slo, n_workers: int,
         clocks: int, n_readers: int = 2) -> Dict:
    x0 = {k: np.zeros(shape) for k, shape in KEYS.items()}
    rt = PSRuntime(RuntimeConfig(n_workers, ssp(3), x0, n_shards=2,
                   threads_per_process=1, seed=0, transport=transport))
    rt.start(_update_fn, clocks, timeout=600)
    gw = (ReadGateway(rt, n_replicas=n_replicas,
                      transport=SERVING_OF[transport])
          if n_replicas > 0 else None)
    lat: List[float] = []
    stale: List[int] = []
    esc = [0]
    llock = threading.Lock()
    stop = threading.Event()

    def reader():
        my_lat, my_stale, my_esc = [], [], 0
        while not stop.is_set():
            t0 = time.perf_counter()
            if gw is None:
                rt.master_value("w")           # locked-master baseline
                my_stale.append(0)
            else:
                # short deadline: a read the replicas cannot serve within
                # its SLO escalates to the master quickly (the intended
                # serving behavior under write saturation) instead of
                # parking for seconds and skewing the percentiles
                res = gw.read("w", slo=slo, timeout=0.25)
                my_stale.append(res.staleness)
                my_esc += res.escalated
            my_lat.append(time.perf_counter() - t0)
        with llock:
            lat.extend(my_lat)
            stale.extend(my_stale)
            esc[0] += my_esc

    t0 = time.perf_counter()
    threads = [threading.Thread(target=reader, daemon=True)
               for _ in range(n_readers)]
    for th in threads:
        th.start()
    stats = rt.wait()
    window = time.perf_counter() - t0
    stop.set()
    for th in threads:
        th.join(timeout=10)
    if gw is not None:
        gw.close()

    q = np.quantile(np.asarray(lat), [0.5, 0.99]) if lat else [0.0, 0.0]
    slo_label = "master" if n_replicas == 0 else (
        "any" if slo is None else str(slo))
    return {
        "name": f"serving/{transport}/r{n_replicas}/slo_{slo_label}"
                f"/w{n_workers}",
        "transport": transport,
        "serving_transport": SERVING_OF[transport] if n_replicas else None,
        "replicas": n_replicas,
        "slo": slo_label,
        "workers": n_workers,
        "n_reads": len(lat),
        "reads_per_s": len(lat) / max(window, 1e-9),
        "us_per_call": window / max(len(lat), 1) * 1e6,
        "read_p50_us": float(q[0]) * 1e6,
        "read_p99_us": float(q[1]) * 1e6,
        "mean_staleness": float(np.mean(stale)) if stale else 0.0,
        "max_staleness": int(max(stale)) if stale else 0,
        "escalations": int(esc[0]),
        "updates_per_s": stats.n_updates / max(window, 1e-9),
        "violations": len(stats.violations),
    }


def run(transports: Sequence[str] = ("queue", "proc"),
        replica_counts: Sequence[int] = (1, 2),
        slos: Sequence = (0, 3, None),
        n_workers: int = 2,
        clocks: int = CLOCKS) -> List[Dict]:
    rows = []
    for transport in transports:
        rows.append(_one(transport, 0, None, n_workers, clocks))  # baseline
        for n_rep in replica_counts:
            for slo in slos:
                rows.append(_one(transport, n_rep, slo, n_workers, clocks))
    return rows


def write_json(rows: List[Dict], path: str) -> None:
    """Consolidated BENCH_serving.json: replica-vs-locked-master serving
    throughput at equal worker count, per transport x replicas x SLO
    (stamped by benchmarks.common: git sha, UTC timestamp, host meta)."""
    _common.write_bench_json(path, "bench_serving", rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: 2 replicas, slo 3, few clocks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write consolidated BENCH_serving.json here")
    ap.add_argument("--transports", default=None,
                    help="comma list from queue,proc,shm,tcp")
    ap.add_argument("--replicas", default=None, help="comma list, e.g. 1,2")
    ap.add_argument("--slos", default=None,
                    help='comma list of ints or "any", e.g. 0,3,any')
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--clocks", type=int, default=None)
    args = ap.parse_args()

    transports = (args.transports.split(",") if args.transports
                  else ("queue", "proc"))
    if args.smoke:
        replicas = (2,)
        slos = (3,)
        clocks = args.clocks or 10
    else:
        replicas = (1, 2)
        slos = (0, 3, None)
        clocks = args.clocks or CLOCKS
    if args.replicas:
        replicas = tuple(int(r) for r in args.replicas.split(","))
    if args.slos:
        slos = tuple(None if s == "any" else int(s)
                     for s in args.slos.split(","))

    rows = run(transports=transports, replica_counts=replicas, slos=slos,
               n_workers=args.workers, clocks=clocks)
    for r in rows:
        print(f"{r['name']}: {r['reads_per_s']:.0f} reads/s, "
              f"p50 {r['read_p50_us']:.0f}us p99 {r['read_p99_us']:.0f}us, "
              f"staleness mean {r['mean_staleness']:.2f} "
              f"max {r['max_staleness']}, esc {r['escalations']}")
    per = {(r["transport"], r["replicas"], r["slo"]): r["reads_per_s"]
           for r in rows}
    for transport in transports:
        base = per.get((transport, 0, "master"))
        if not base:
            continue
        for (tr, n_rep, slo), v in per.items():
            if tr == transport and n_rep > 0:
                print(f"# {transport} r{n_rep} slo_{slo} vs locked master "
                      f"(same {args.workers} workers): x{v / base:.2f}")
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
