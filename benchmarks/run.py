"""Benchmark harness — one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV per the repo convention, plus the
full result dicts, and regenerates results/roofline.md when dry-run
artifacts exist.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import time


def _csv_line(row: dict) -> str:
    name = row.pop("name")
    us = row.pop("us_per_call", row.pop("sim_time", 0.0) * 1e6
                 if "sim_time" in row else 0.0)
    derived = ";".join(f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items())
    return f"{name},{us:.1f},{derived}"


def main() -> None:
    all_rows = []
    t0 = time.time()

    print("# --- consistency models on SGD (paper §2/§3) ---")
    from benchmarks import bench_consistency
    for r in bench_consistency.run():
        all_rows.append(dict(r))
        print(_csv_line(r))

    print("# --- LDA convergence per policy (paper §5) ---")
    from benchmarks import bench_lda
    for r in bench_lda.run():
        all_rows.append(dict(r))
        print(_csv_line(r))

    print("# --- LDA strong scaling (paper Fig. 5) ---")
    from benchmarks import bench_scalability
    for r in bench_scalability.run():
        all_rows.append(dict(r))
        print(_csv_line(r))

    print("# --- PS runtime: updates/sec + read latency per transport ---")
    from benchmarks import bench_runtime
    cal = bench_runtime.calibrate_parallelism()
    print(f"# host calibration: 2-process aggregate x{cal:.2f}")
    rt_rows = bench_runtime.run()
    for r in rt_rows:
        all_rows.append(dict(r))
        print(_csv_line(dict(r)))
    rt_out = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_runtime.json")
    os.makedirs(os.path.dirname(rt_out), exist_ok=True)
    bench_runtime.write_json(rt_rows, rt_out, parallel_x2=cal)
    print(f"# wrote {rt_out}")

    print("# --- serving tier: replica reads vs locked master, per SLO ---")
    from benchmarks import bench_serving
    sv_rows = bench_serving.run()
    for r in sv_rows:
        all_rows.append(dict(r))
        print(_csv_line(dict(r)))
    sv_out = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_serving.json")
    bench_serving.write_json(sv_rows, sv_out)
    print(f"# wrote {sv_out}")

    print("# --- autoscaling: rebalanced vs static layouts ---")
    from benchmarks import bench_autoscale
    as_rows = bench_autoscale.run()
    for r in as_rows:
        all_rows.append(dict(r))
        print(_csv_line(dict(r)))
    bench_autoscale.gates(as_rows)
    as_out = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_autoscale.json")
    bench_autoscale.write_json(as_rows, as_out)
    print(f"# wrote {as_out}")

    print("# --- durability tier: WAL off vs group-commit vs fsync ---")
    from benchmarks import bench_wal
    wal_rows = bench_wal.run()
    for r in wal_rows:
        all_rows.append(dict(r))
        print(_csv_line(dict(r)))
    bench_wal.gates(wal_rows)
    wal_out = os.path.join(os.path.dirname(__file__), "..", "results",
                           "BENCH_wal.json")
    bench_wal.write_json(wal_rows, wal_out)
    print(f"# wrote {wal_out}")

    print("# --- convergence vs staleness per policy (SGD MF + logreg) ---")
    from benchmarks import bench_convergence
    cv_rows = bench_convergence.run()
    for r in cv_rows:
        all_rows.append(dict(r))
        slim = {k: v for k, v in r.items() if k != "curve"}
        slim.setdefault("us_per_call", 0.0)
        print(_csv_line(slim))
    bench_convergence.gates(cv_rows)
    cv_out = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_convergence.json")
    bench_convergence.write_json(cv_rows, cv_out)
    print(f"# wrote {cv_out}")

    print("# --- kernel reference-path microbenchmarks ---")
    from benchmarks import bench_kernels
    for r in bench_kernels.run():
        all_rows.append(dict(r))
        print(_csv_line(r))

    print("# --- roofline (from dry-run artifacts) ---")
    from benchmarks import roofline
    rows = roofline.load_all()
    if rows:
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                  f"bound={r['dominant']};step_s={r['bound_step_s']:.4g};"
                  f"useful={r['useful_fraction']:.2f};"
                  f"peak_gib={r['peak_gib']:.1f}")
        roofline.main()
    else:
        print("# (no dry-run artifacts; run repro.launch.dryrun --all first)")

    from benchmarks import common
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "bench_results.json")
    common.write_bench_json(out, "bench_results", all_rows,
                            calibration={"proc_parallel_x2": cal})
    print(f"# done in {time.time() - t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
