"""Microbenchmarks of the kernel REFERENCE paths (this container is CPU-only;
the Pallas kernels target TPU and are validated by tests in interpret mode —
wall-clock here times the jnp oracle that the dry-run lowers)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

RNG = np.random.default_rng(0)


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6     # us


def run() -> List[Dict]:
    rows = []

    from repro.kernels.flash_attention import ops as fa
    b, s, kvh, G, dh = 1, 2048, 2, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, G, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, dh)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    us = _time(lambda: fa.flash_attention(q, k, v, pos, pos, window=512))
    flops = 4 * b * kvh * G * s * 512 * dh   # banded
    rows.append({"name": "kernel_ref/flash_attention_2k_w512",
                 "us_per_call": us, "derived_gflops": flops / us / 1e3})

    from repro.kernels.ssd_scan import ops as sd
    b, l, h, p, g, n = 2, 2048, 8, 64, 1, 128
    x = jnp.asarray(RNG.normal(0, 1, (b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.1, 1, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), jnp.float32)
    us = _time(lambda: sd.ssd_scan(x, dt, A, B, C, chunk=256))
    rows.append({"name": "kernel_ref/ssd_scan_2k", "us_per_call": us,
                 "derived_tokens_per_s": b * l / us * 1e6})

    from repro.kernels.rglru_scan import ops as rg
    b, l, w = 2, 2048, 1024
    xx = jnp.asarray(RNG.normal(0, 1, (b, l, w)), jnp.float32)
    r = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    i = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    lam = jnp.asarray(RNG.normal(0, 1, (w,)), jnp.float32)
    us = _time(lambda: rg.rglru(xx, r, i, lam))
    rows.append({"name": "kernel_ref/rglru_2k", "us_per_call": us,
                 "derived_tokens_per_s": b * l / us * 1e6})

    from repro.kernels.vap_accum import ops as va
    n_ = 4_000_000
    pp = jnp.asarray(RNG.normal(0, 1, n_), jnp.float32)
    dd = jnp.asarray(RNG.normal(0, 0.01, n_), jnp.float32)
    uu = jnp.asarray(RNG.normal(0, 0.01, n_), jnp.float32)
    us = _time(lambda: va.vap_accum(pp, dd, uu))
    rows.append({"name": "kernel_ref/vap_accum_4M", "us_per_call": us,
                 "derived_gbytes_per_s": 5 * 4 * n_ / us / 1e3})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
