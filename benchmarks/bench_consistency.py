"""Benchmark: consistency models on distributed SGD (paper §2/§3 claims).

For each policy, P workers minimize the same least-squares objective on the
simulator with a slow network + straggler.  Reported per policy:
  * throughput (clocks/sim-second)  — the systems win;
  * final objective after a fixed number of clocks — algorithmic quality;
  * time-to-target — the combined metric the paper argues relaxed
    consistency improves end-to-end.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import AsyncPS, NetworkModel, bsp, cap, cvap, ssp, vap

DIM = 8
P = 8
CLOCKS = 40


def make_objective(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(0, 1, (64, DIM)) / np.sqrt(DIM)
    xstar = rng.normal(0, 1, DIM)
    y = A @ xstar

    def value(x):
        return float(0.5 * np.mean((A @ x - y) ** 2))

    def fn(w, clock, view, rng_):
        x = view.get("x")
        i = rng_.integers(0, len(y), 8)
        g = (A[i].T @ (A[i] @ x - y[i])) / len(i)
        return {"x": -0.25 * g}

    return fn, value


def run() -> List[Dict]:
    policies = [
        ("bsp", bsp()),
        ("ssp_s3", ssp(3)),
        ("cap_s3", cap(3)),
        ("vap_0.05", vap(0.05)),
        ("vap_strong_0.05", vap(0.05, strong=True)),
        ("cvap_s3_0.05", cvap(3, 0.05)),
    ]
    rows = []
    for name, pol in policies:
        fn, value = make_objective()
        ps = AsyncPS(P, pol, {"x": np.zeros(DIM)},
                     network=NetworkModel(base_delay=0.6, jitter=0.4, seed=3),
                     straggler={0: 2.0}, seed=1)
        stats = ps.run(fn, CLOCKS, divergence_every=1.0)
        final = value(ps.master_value("x"))
        assert stats.violations == [], (name, stats.violations)
        rows.append({
            "name": f"consistency/{name}",
            "throughput_clk_per_s": stats.throughput,
            "sim_time": stats.sim_time,
            "final_objective": final,
            "block_clock_s": stats.block_time_clock,
            "block_value_s": stats.block_time_value,
            "max_divergence": stats.max_divergence,
            "max_staleness": stats.max_observed_staleness,
            "messages": stats.n_messages,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
