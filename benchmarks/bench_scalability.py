"""Benchmark: LDA strong scaling (paper §5, Fig. 5 + Table 1).

Fixed corpus, growing worker count; speedup = throughput(P)/throughput(P0)
compared against ideal linear scaling, under VAP (the paper's configuration)
and BSP (the baseline it beats).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import NetworkModel, bsp, vap
from repro.data import synthetic_corpus
from repro.apps import lda

WORKER_COUNTS = (4, 8, 16)
CLOCKS = 4


def run() -> List[Dict]:
    corpus = synthetic_corpus(n_docs=48, vocab_size=120, n_topics=6,
                              doc_len=60, seed=0)
    rows = []
    for pol_name, make_pol in (("vap", lambda: vap(50.0)), ("bsp", bsp)):
        base_thr = None
        for P in WORKER_COUNTS:
            lls, stats = lda.run_lda(
                corpus, n_topics=6, policy=make_pol(), n_workers=P,
                n_clocks=CLOCKS, seed=0,
                network=NetworkModel(base_delay=0.15, jitter=0.1, seed=0),
                straggler={0: 1.5}, collect_stats=True)
            # throughput in tokens swept per sim second
            thr = corpus.n_tokens * CLOCKS / stats.sim_time
            if base_thr is None:
                base_thr = thr / P          # per-worker baseline
            rows.append({
                "name": f"lda_scaling/{pol_name}/P{P}",
                "workers": P,
                "tokens_per_s": thr,
                "speedup": thr / (base_thr * WORKER_COUNTS[0]),
                "ideal": P / WORKER_COUNTS[0],
                "ll_final": lls[-1],
                "sim_time": stats.sim_time,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
