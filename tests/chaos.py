"""Seeded chaos / fault-injection harness (no hypothesis — not installed).

Two generators, one seed space:

* :func:`random_schedule` — a seeded random *workload*: policy drawn from
  SSP/ESSP/VAP/CVAP (strong and weak)/elastic, per-worker compute-time
  skew, stragglers, and network latency/jitter for the simulator leg.  The
  simulator is the paper's executable spec; :func:`assert_paper_bounds`
  checks the Lemma bounds *exactly* on whatever it observed (zero recorded
  violations, clock staleness ≤ s, element-wise unsynchronized magnitude
  ≤ max(u, v_thr), strong-VAP half-sync ≤ max(u, v_thr), elastic unsynced
  L2 norm ≤ max(max‖u‖₂, B)).

* :func:`random_membership_script` — a seeded random schedule of live
  membership faults for the *runtime* leg: add, remove, and kill/rejoin
  (remove-then-re-add of the same slot, which exercises slot re-activation
  and the stale-marker epoch filter).  The spec is partition-free, which is
  precisely the correctness claim under test: membership change must be
  invisible in the final state, in the bounds, and in the update counters.

The runtime leg (:func:`chaos_run`) runs a free 4-worker interleaving with
the scripted faults, optionally a serving gateway issuing SLO'd reads and a
seeded replica wedger, and returns everything the caller needs to assert
(a) final state == simulator on deterministic schedules, (b) mid-run
staleness stamps ≤ bound, (c) zero lost/duplicated updates by counter
audit (the runtime's ``_final_checks`` folds the per-process counters into
``stats.violations``; :func:`assert_counters` re-checks them explicitly).
"""
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AsyncPS, NetworkModel, policies
from repro.runtime import MembershipPlan, PSRuntime, ReadGateway, RuntimeConfig

# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------


def x0():
    return {"a": np.arange(32, dtype=float).reshape(8, 4) / 2.0,
            "b": np.ones(5)}


def det_fn(seed: int):
    """Deterministic integer deltas, a pure function of (worker, clock): the
    update *set* is interleaving- and membership-independent, so every leg
    must converge to exactly x0 + sum(deltas)."""
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-3, 4, size=(8, 4)).astype(float),
                "b": r.integers(-3, 4, size=5).astype(float)}
    return fn


def zipf_fn(seed: int, alpha: float = 1.3, burst_every: int = 3):
    """Zipf-skewed bursty deltas, still a pure function of (worker, clock).

    Row popularity follows a Zipf(alpha) ranking with the hottest rows on
    EVEN row ids of ``a`` — under the round-robin partition
    (``active[r % A]``) a 2-active layout concentrates them on one slot, so
    the load is genuinely imbalanced until a split spreads the even rows
    over more owners.  Every ``burst_every``-th clock is a burst (many rows
    touched), the rest are lulls (few) — the bursty signal the autoscaler's
    windowed rates must ride without breaking the bounds.  Untouched rows
    are zero and the client elides them, so per-shard rows-applied load
    mirrors the skew."""
    n_rows = x0()["a"].shape[0]
    # rank rows: even ids first (hot), then odd — Zipf over that ranking
    ranked = sorted(range(n_rows), key=lambda r: (r % 2, r))
    p = np.array([1.0 / (i + 1) ** alpha for i in range(n_rows)])
    p /= p.sum()

    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed ^ 0x21BF, w, clock))
        burst = (clock % burst_every) == 0
        n_touch = int(r.integers(3, n_rows + 1)) if burst else 1
        rows = r.choice(n_rows, size=n_touch, replace=False, p=p)
        da = np.zeros_like(x0()["a"])
        for i in rows:
            da[ranked[i]] = r.integers(-3, 4, size=da.shape[1])
        out = {"a": da}
        if burst:
            out["b"] = r.integers(-3, 4, size=5).astype(float)
        return out
    return fn


def expected_final(seed: int, n_workers: int, n_clocks: int, fn=None
                   ) -> Dict[str, np.ndarray]:
    fn = det_fn(seed) if fn is None else fn
    out = {k: v.astype(float) for k, v in x0().items()}
    for w in range(n_workers):
        for c in range(n_clocks):
            for k, d in fn(w, c, None, None).items():
                out[k] = out[k] + d
    return out


def random_policy(rng: np.random.Generator):
    """A seeded draw over the paper's bounded policies (SSP / ESSP / VAP /
    CVAP strong and weak / elastic)."""
    kind = rng.choice(["ssp", "essp", "vap", "cvap", "cvap_strong",
                       "elastic"])
    s = int(rng.integers(1, 4))
    vthr = float(rng.uniform(1.0, 6.0))
    if kind == "ssp":
        return f"ssp{s}", policies.ssp(s)
    if kind == "essp":
        return f"essp{s}", policies.essp(s)
    if kind == "vap":
        return f"vap{vthr:.1f}", policies.vap(vthr)
    if kind == "elastic":
        nb = float(rng.uniform(6.0, 15.0))    # ~per-update L2 of det_fn
        return f"el{nb:.1f}", policies.elastic(nb)
    strong = kind == "cvap_strong"
    return (f"cvap{s}_{vthr:.1f}{'s' if strong else ''}",
            policies.cvap(s, vthr, strong=strong))


def random_schedule(seed: int) -> dict:
    """A seeded random simulator workload: policy + compute skew +
    stragglers + network model."""
    rng = np.random.default_rng(seed)
    name, pol = random_policy(rng)
    n_workers = int(rng.integers(3, 6))
    tpp = 1 if n_workers % 2 else int(rng.choice([1, 2]))
    base = float(rng.uniform(0.2, 1.5))
    skew = rng.uniform(0.5, 2.0, size=n_workers)
    straggler = {}
    if rng.random() < 0.5:
        straggler[int(rng.integers(0, n_workers))] = float(rng.uniform(2, 6))
    net = NetworkModel(base_delay=float(rng.uniform(0.01, 0.8)),
                       jitter=float(rng.uniform(0.0, 0.5)), seed=seed)
    return {
        "name": name, "policy": pol, "n_workers": n_workers, "tpp": tpp,
        "compute_time": lambda w: base * float(skew[w]),
        "straggler": straggler, "network": net, "seed": seed,
    }


def run_sim_schedule(sched: dict, n_clocks: int):
    """Drive the simulator (the spec) through a random schedule; returns
    ``(ps, stats)``; callers assert the paper's bounds on the stats."""
    ps = AsyncPS(sched["n_workers"], sched["policy"], x0(),
                 network=sched["network"],
                 threads_per_process=sched["tpp"],
                 compute_time=sched["compute_time"],
                 straggler=sched["straggler"], seed=sched["seed"])
    stats = ps.run(det_fn(sched["seed"]), n_clocks)
    return ps, stats


def assert_paper_bounds(pol, stats) -> None:
    """The paper's Lemma bounds, asserted exactly on observed maxima."""
    assert stats.violations == [], stats.violations[:5]
    if pol.clock_bounded:
        assert stats.max_observed_staleness <= pol.staleness
    if pol.value_bounded:
        bound = max(stats.max_update_mag, pol.value_bound)   # max(u, v_thr)
        assert stats.max_unsynced_mag <= bound + 1e-9
        if pol.strong:
            assert stats.max_halfsync_mag <= bound + 1e-9
    if pol.norm_bounded:
        nb = max(stats.max_update_norm, pol.value_bound)     # max(‖u‖, B)
        assert stats.max_unsynced_norm <= nb + 1e-9


# ---------------------------------------------------------------------------
# membership fault scripts
# ---------------------------------------------------------------------------


def random_membership_script(seed: int, n_clocks: int, n_shards: int,
                             max_shards: int, n_events: int = 4
                             ) -> MembershipPlan:
    """A seeded schedule of live membership faults: add / remove /
    kill+rejoin, at clock boundaries spread over the middle of the run.
    Tracks the active set so every event is valid when it fires."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    active = set(range(n_shards))
    clocks = sorted(rng.choice(
        np.arange(2, max(3, n_clocks - 4)),
        size=min(n_events, max(1, n_clocks - 6)), replace=False).tolist())
    spec: List[Tuple[int, str, Optional[int]]] = []
    killed: List[int] = []
    for c in clocks:
        fresh = sorted(set(range(max_shards)) - active - set(killed))
        ops = []
        if fresh:
            ops.append("add")
        if len(active) > 1:
            ops.extend(["remove", "kill"])
        if killed:                            # killed slots are never active
            ops.append("rejoin")
        if not ops:
            continue
        op = str(rng.choice(ops))
        if op == "add":
            sid = fresh[0]
            spec.append((int(c), "add", sid))
            active.add(sid)
        elif op == "rejoin":                  # re-activate a killed slot
            sid = killed.pop(0)
            spec.append((int(c), "add", sid))
            active.add(sid)
        else:                                 # remove / kill
            sid = int(rng.choice(sorted(active)))
            spec.append((int(c), "remove", sid))
            active.discard(sid)
            if op == "kill":
                killed.append(sid)
    return MembershipPlan.parse(spec)


# ---------------------------------------------------------------------------
# runtime chaos leg
# ---------------------------------------------------------------------------

# the most recent chaos runtime: conftest's failure hook dumps its trace
# export + metrics snapshot into test-artifacts/<test>/ for post-mortems
LAST_RT: Optional[PSRuntime] = None

# chaos runs always record a lightly sampled trace (update lifelines at 5%,
# all non-sampled layer spans at full rate): cheap enough to leave on, and
# the artifact a red chaos assertion is explained with
CHAOS_TRACE = {"sample": 0.05}


class ReplicaWedger:
    """Seeded replica fault injector: wedges a random replica's publish
    edges, holds, releases, repeats — the serving tier must keep honoring
    SLO stamps (stale replicas drop out of the rotation via their vc) and
    recover the wedged replica exactly via drop-and-resync.

    Stands down once the run's completed-clock frontier passes
    ``quiet_after`` so the final publish cycles can resync every replica
    while write traffic (and hence shard publish cycles) still exists."""

    def __init__(self, rset, seed: int, rt=None, quiet_after: int = 0,
                 period: float = 0.05):
        self.rset = rset
        self.rt = rt
        self.quiet_after = quiet_after
        self.rng = np.random.default_rng(seed ^ 0xFA11)
        self.period = period
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name="chaos-wedger")

    def _quiet(self) -> bool:
        return (self.rt is not None and self.quiet_after
                and self.rt.completed_clock() >= self.quiet_after)

    def _run(self) -> None:
        while not self._stop.is_set() and not self._quiet():
            rid = int(self.rng.integers(0, len(self.rset.replicas)))
            self.rset.wedge(rid, True)
            time.sleep(self.period * float(self.rng.uniform(0.5, 2.0)))
            self.rset.wedge(rid, False)
            time.sleep(self.period * float(self.rng.uniform(0.2, 1.0)))
        for rep in self.rset.replicas:
            self.rset.wedge(rep.rid, False)

    def start(self) -> None:
        self.thread.start()

    def stop(self) -> None:
        self._stop.set()
        self.thread.join(timeout=10.0)
        for rep in self.rset.replicas:
            self.rset.wedge(rep.rid, False)


class SloReader:
    """Background gateway reader cycling SLOs; records any stamp that
    exceeds its request (there must be none, ever — including during the
    migration window)."""

    def __init__(self, gw: ReadGateway, keys=("a", "b")):
        self.gw = gw
        self.keys = keys
        self.bad: List[tuple] = []
        self.errors: List[BaseException] = []
        self.n_reads = 0
        self.n_shed = 0                      # fresh reads refused by admission
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name="chaos-slo-reader")

    def _run(self) -> None:
        from repro.runtime import ReadShedError
        slos = [0, 1, 3, None, "fresh"]
        i = 0
        while not self._stop.is_set():
            slo = slos[i % len(slos)]
            key = self.keys[i % len(self.keys)]
            i += 1
            try:
                res = self.gw.read(key, slo=slo, timeout=10.0)
            except ReadShedError:            # admission control under a hot
                self.n_shed += 1             # master: expected, not an error
                continue
            except BaseException as e:       # a dead reader would make the
                self.errors.append(e)        # SLO assertions pass vacuously
                return
            self.n_reads += 1
            if isinstance(slo, int) and res.staleness > slo:
                self.bad.append((slo, res.staleness, res.source))

    def start(self) -> None:
        self.thread.start()

    def stop(self) -> None:
        self._stop.set()
        self.thread.join(timeout=10.0)


def chaos_autoscale_policy():
    """Aggressive knobs so the autoscaler genuinely churns within a short
    chaos run: tight windows, minimal cooldown, split/drain thresholds the
    Zipf bursts and lulls both cross."""
    from repro.runtime import AutoscalePolicy
    return AutoscalePolicy(interval=0.05, cooldown=0.2,
                           split_imbalance=1.2, split_min_rows_s=10.0,
                           drain_max_rows_s=8.0, escalation_hi=0.10,
                           escalation_lo=0.02, drain_patience=2,
                           min_window_reads=3, shed_lock_wait_frac=0.15)


def chaos_run(seed: int, pol, n_clocks: int, transport: str = "queue",
              max_shards: int = 4, n_events: int = 4, serving: bool = False,
              wedge: bool = False, serving_transport: str = "queue",
              autoscale: bool = False, fn=None, wal_dir: Optional[str] = None,
              wal_fsync: Optional[str] = None, snapshot_every: int = 0,
              snapshot_dir: Optional[str] = None, snapshot_keep_last: int = 0,
              timeout: float = 110.0):
    """One full chaos leg: free 4-worker run + scripted membership faults,
    optionally a gateway under SLO'd reads and a replica wedger (which
    needs a wire serving transport — queue edges are unbounded and cannot
    exert backpressure).  Returns ``(rt, stats, plan, reader)``.

    With ``autoscale=True`` the *autoscaler itself* is the membership churn
    driver (no scripted plan — scripted slot picks would race the
    autoscaler's): an :class:`~repro.runtime.Autoscaler` with the
    aggressive :func:`chaos_autoscale_policy` splits/drains shards (and
    scales replicas / sheds fresh reads when ``serving``) while the run's
    bounds and counter audit must keep holding.  The started instance is
    attached as ``rt.autoscaler``.  ``fn`` overrides the update workload
    (default :func:`det_fn`; pass :func:`zipf_fn` for skewed bursts)."""
    global LAST_RT
    plan = None if autoscale else random_membership_script(
        seed, n_clocks, n_shards=2, max_shards=max_shards, n_events=n_events)
    rt = PSRuntime(RuntimeConfig(4, pol, x0(), n_shards=2, threads_per_process=2,
                   seed=seed, max_shards=max_shards, transport=transport,
                   membership_plan=plan, wal_dir=wal_dir, wal_fsync=wal_fsync,
                   snapshot_every=snapshot_every, snapshot_dir=snapshot_dir,
                   snapshot_keep_last=snapshot_keep_last, trace=CHAOS_TRACE))
    LAST_RT = rt
    reader = wedger = gw = asc = None
    rt.start(det_fn(seed) if fn is None else fn, n_clocks, timeout=timeout)
    try:
        if serving:
            gw = ReadGateway(rt, n_replicas=2, transport=serving_transport)
            reader = SloReader(gw)
            reader.start()
            if wedge:
                wedger = ReplicaWedger(gw.replicas, seed, rt=rt,
                                       quiet_after=int(n_clocks * 0.7))
                wedger.start()
        if autoscale:
            from repro.runtime import Autoscaler
            asc = Autoscaler(rt, gw, chaos_autoscale_policy()).start()
            rt.autoscaler = asc
        stats = rt.wait()
    finally:
        if asc is not None:
            asc.stop()
        if wedger is not None:
            wedger.stop()
        if reader is not None:
            reader.stop()
    if gw is not None:
        reader.gw_stats = gw.stats
        reader.replica_errors = list(gw.replicas.errors)
        reader.pub_drops = gw.replicas.pub_drops
        reader.pub_resyncs = gw.replicas.pub_resyncs
        time.sleep(0.2)                # let the last publish cycle drain
        # then wait (bounded) for live replicas that still trail the
        # quiesced master frontier: vc stamps ride FIFO-behind their
        # deltas, so a caught-up replica vc means its values drained too —
        # the fixed sleep alone flaked when a post-wedge resync needed
        # longer than the constant
        rset = gw.replicas
        mvc = rset.master_vc()

        def _lagging() -> set:
            stale = rset.stale_replicas
            return {rep.rid for rep in rset.replicas
                    if not rep.poisoned and rep.rid not in stale
                    and rset.staleness(rep.vc, mvc) > 0}

        deadline = time.monotonic() + 10.0
        while _lagging() and time.monotonic() < deadline:
            time.sleep(0.02)
        # a replica still lagging at the deadline did NOT finish un-stale
        # and drained; it is excluded like a stale one (the callers'
        # `assert reader.final_replicas` still guards against everyone
        # ending stale/poisoned/undrained)
        skip = rset.stale_replicas | _lagging()
        reader.final_replicas = [
            {k: rep.serve(k)[0] for k in x0()}
            for rep in rset.replicas
            if not rep.poisoned and rep.rid not in skip]
        gw.close()
    return rt, stats, plan, reader


def assert_counters(rt) -> None:
    """Explicit zero-lost / zero-duplicated audit: every update part each
    client process sent was applied by exactly one shard slot."""
    applied = np.zeros(rt.n_proc, dtype=np.int64)
    for s in rt.shards:
        applied += s.applied_parts
    assert applied.tolist() == rt._parts_sent.tolist(), (
        f"lost/duplicated updates: sent {rt._parts_sent.tolist()} "
        f"applied {applied.tolist()}")


def assert_wal_recovery(rt, seed: int, n_clocks: int, wal_dir: str,
                        fn=None, snapshot_dir: Optional[str] = None) -> None:
    """Durability-tier audit, the strict upgrade over snapshot-granularity
    loss: rebuild state from ``snapshot + replay(log)`` alone
    (:func:`repro.runtime.snapshot.recover_to_vc`) and assert **zero**
    lost/duplicated updates — the per-origin-process count of parts folded
    into the recovered state equals the per-process parts-sent counters —
    plus recovered final state bitwise equal to the membership-free
    expected state (integer test deltas: f64 sums are exact and
    order-independent)."""
    from repro.runtime import recover_to_vc
    rec = recover_to_vc(x0(), wal_dir, snapshot_dir=snapshot_dir)
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist(), (
        f"wal recovery lost/duplicated updates: sent "
        f"{rt._parts_sent.tolist()} recovered "
        f"{rec['applied_parts'].tolist()} (deduped {rec['n_deduped']})")
    exp = expected_final(seed, 4, n_clocks, fn=fn)
    for k, v in exp.items():
        np.testing.assert_array_equal(
            rec["params"][k], v,
            err_msg=f"wal-recovered state diverges from the membership-free "
                    f"expectation for {k!r}")
