"""Unified metrics surface + RuntimeConfig redesign (PR 7).

Three claims under test:

* **Exact reconciliation** — the metrics tree is not a sampled
  approximation once the run quiesces: after ``wait()``,
  ``rt.metrics()`` totals equal the authoritative counters exactly —
  ``run.n_updates`` == the merged ``RunStats``, the per-process boundary
  snapshots (piggybacked on :class:`ClockMsg` over queue / shm / tcp
  alike) sum to the same total, and the per-shard ``applied_parts``
  audit lists match ``rt._parts_sent`` element-wise (zero lost or
  duplicated update parts).

* **RuntimeConfig is the construction surface** — every validation check
  lives in ``__post_init__``; the legacy positional/kwargs constructor
  still works but warns ``DeprecationWarning``; mixing a config with
  extra args is a ``TypeError``.

* **Gateway read cache never serves staler than requested** — a cached
  value's stamp is re-measured against the *live* master vector clock on
  every hit, so an advanced frontier invalidates the entry naturally and
  the final read always reflects the final master state.
"""
import numpy as np
import pytest

from repro.core import policies
from repro.runtime import (FRESH, PSRuntime, ReadGateway, ReadShedError,
                           RuntimeConfig, RuntimeMetrics)


def _x0():
    return {"a": np.zeros((8, 4)), "b": np.ones(6)}


def _fn(seed):
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-2, 3, size=(8, 4)).astype(float),
                "b": r.integers(-2, 3, size=6).astype(float)}
    return fn


def _run(transport, n_workers=2, n_clocks=8, **kw):
    rt = PSRuntime(RuntimeConfig(n_workers, policies.ssp(2), _x0(),
                                 n_shards=2, transport=transport, **kw))
    rt.start(_fn(7), n_clocks, timeout=60.0)
    stats = rt.wait()
    return rt, stats


# ---------------------------------------------------------------------------
# exact reconciliation: metrics totals == authoritative counters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["queue", "shm", "tcp"])
def test_metrics_reconcile_exactly_after_quiesce(transport):
    n_workers, n_clocks = 2, 8
    rt, stats = _run(transport, n_workers, n_clocks)
    m = rt.metrics()
    assert isinstance(m, RuntimeMetrics)
    assert m.transport == rt.transport_kind
    assert m.metrics_enabled

    # run counters: the unified tree equals the merged RunStats exactly
    assert m.run.n_updates == stats.n_updates == n_workers * n_clocks * 2
    assert m.run.n_violations == len(stats.violations) == 0
    assert m.run.bytes_sent == stats.bytes_sent

    # per-process boundary snapshots arrived from every client (over the
    # same channel/pipe machinery the ClockMsgs already ride) and their
    # final boundary covers the whole run
    assert sorted(p.process for p in m.processes) == list(range(rt.n_proc))
    assert all(p.clock == n_clocks - 1 for p in m.processes)
    assert sum(p.n_updates for p in m.processes) == stats.n_updates

    # per-shard audit: metrics' applied_parts mirror the zero-lost /
    # zero-duplicated counter audit element-wise
    applied = np.zeros(rt.n_proc, dtype=np.int64)
    for s in m.shards:
        applied += np.asarray(s.applied_parts, dtype=np.int64)
    assert applied.tolist() == rt._parts_sent.tolist()
    assert sum(s.parts_applied for s in m.shards) == int(rt._parts_sent.sum())
    assert sum(s.rows_applied for s in m.shards) > 0
    assert sum(s.bytes_applied for s in m.shards) > 0

    # membership/snapshot corners of the tree populate sanely
    assert m.membership.active == rt.partition.active
    assert m.membership.n_slots == rt.n_slots
    assert m.clock == n_clocks
    assert m.replicas == [] and m.gateways == []


def test_metrics_windowed_rates_and_imbalance():
    rt, stats = _run("queue")
    m1 = rt.metrics()                    # window since start: work happened
    assert m1.window_s > 0
    assert sum(s.updates_per_s for s in m1.shards) > 0
    assert m1.shard_imbalance() >= 1.0
    assert m1.hottest_shard().rows_per_s >= m1.coldest_shard().rows_per_s
    m2 = rt.metrics()                    # quiesced window: rates decay to 0
    assert sum(s.updates_per_s for s in m2.shards) == 0.0
    assert m2.run.n_updates == m1.run.n_updates == stats.n_updates


def test_metrics_disabled_still_collects_quiesced_truth():
    rt, stats = _run("queue", metrics=False)
    m = rt.metrics()
    assert not m.metrics_enabled
    assert m.processes == []             # no piggybacked boundary snapshots
    assert m.run.n_updates == stats.n_updates    # stats remain authoritative
    applied = np.zeros(rt.n_proc, dtype=np.int64)
    for s in m.shards:
        applied += np.asarray(s.applied_parts, dtype=np.int64)
    assert applied.tolist() == rt._parts_sent.tolist()


# ---------------------------------------------------------------------------
# RuntimeConfig: the one construction surface
# ---------------------------------------------------------------------------


def test_legacy_constructor_warns_and_matches_config():
    with pytest.deprecated_call():
        rt = PSRuntime(2, policies.ssp(1), _x0(), n_shards=2,
                       transport="queue", seed=3)
    cfg = rt.config
    assert isinstance(cfg, RuntimeConfig)
    assert (cfg.n_workers, cfg.n_shards, cfg.transport, cfg.seed) == (
        2, 2, "queue", 3)
    assert cfg.metrics and cfg.snapshot_every == 0    # defaults fill in


def test_config_plus_extra_args_is_a_type_error():
    cfg = RuntimeConfig(2, policies.ssp(1), _x0())
    with pytest.raises(TypeError, match="RuntimeConfig"):
        PSRuntime(cfg, 3)
    with pytest.raises(TypeError, match="RuntimeConfig"):
        PSRuntime(cfg, transport="tcp")


def test_config_validation_lives_in_post_init():
    with pytest.raises(ValueError, match="transport"):
        RuntimeConfig(2, policies.ssp(1), _x0(), transport="carrier-pigeon")
    with pytest.raises(ValueError, match="shard"):
        RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=0)
    with pytest.raises(ValueError, match="max_shards"):
        RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=3, max_shards=2)
    with pytest.raises(ValueError, match="barrier_reads"):
        RuntimeConfig(2, policies.ssp(1), _x0(), threads_per_process=2,
                      barrier_reads=True)


def test_legacy_unknown_kwarg_is_a_type_error():
    with pytest.raises(TypeError, match="unexpected"):
        with pytest.warns(DeprecationWarning):
            PSRuntime(2, policies.ssp(1), _x0(), such_knob=True)


# ---------------------------------------------------------------------------
# gateway read cache: never staler than requested
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_gateway_cache_never_staler_than_requested():
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2))
    rt.start(_fn(11), 12, timeout=60.0)
    gw = ReadGateway(rt, n_replicas=1, read_cache=True)
    try:
        seen = []
        while rt.running and rt.completed_clock() < 12:
            for slo in (0, 1, None):
                res = gw.read("a", slo=slo, timeout=10.0)
                if isinstance(slo, int):
                    assert res.staleness <= slo, (res.source, res.staleness)
                seen.append(res.source)
        rt.wait()
        # the frontier advanced since every mid-run cache fill, so a final
        # slo=0 read must reflect the final master state, not a stale entry
        final = gw.read("a", slo=0, timeout=10.0)
        np.testing.assert_array_equal(
            final.value, rt.master_value("a").reshape(final.value.shape))
        assert final.staleness == 0
        # and now the cache can serve it: hit, stamped 0 against the live vc
        hit = gw.read("a", slo=0, timeout=10.0)
        assert hit.source == "cache" and hit.staleness == 0
        np.testing.assert_array_equal(hit.value, final.value)
        m = rt.metrics()
        assert m.gateways[0].n_cache_hits == gw.stats.n_cache_hits >= 1
        assert m.gateways[0].reads_by_slo.get("0", 0) >= 2
    finally:
        gw.close()


@pytest.mark.serving
def test_gateway_shed_fresh_admission():
    rt, _ = _run("queue")
    gw = ReadGateway(rt, n_replicas=1, read_cache=False)
    try:
        gw.set_shed_fresh(True)
        with pytest.raises(ReadShedError):
            gw.read("a", slo=FRESH)
        res = gw.read("a", slo=1)            # bounded reads still admitted
        assert res.staleness <= 1
        gw.set_shed_fresh(False)
        assert gw.read("a", slo=FRESH).source == "master"
        m = rt.metrics()
        assert m.gateways[0].n_shed == 1
        assert not m.gateways[0].shedding_fresh
        assert m.gateways[0].reads_by_slo["fresh"] == 2
    finally:
        gw.close()
