"""Elastic shard membership: live re-partitioning under load.

The contract (ISSUE 5 / ROADMAP "elastic shard membership"): shards can be
added and removed **mid-run**, with

  (a) the final state bitwise-equal to the simulator spec on deterministic
      schedules — membership change is invisible to the update algebra;
  (b) the SSP clock bound and VAP value bound holding for accesses issued
      *during* the migration window (check_invariants records every
      mid-run violation, so ``stats.violations == []`` covers the window);
  (c) zero lost or duplicated updates, by per-process counter audit
      (parts sent by each client == parts applied across all shard slots);

for all three transports — in-process queues, forked clients over shm
rings, and tcp loopback — plus serving-tier re-subscription with in-stream
re-bootstrap, down-to-one-shard shrink, slot re-activation, and the
scriptable :class:`MembershipPlan`.
"""
import time

import numpy as np
import pytest

from repro.core import AsyncPS, NetworkModel, policies
from repro.runtime import MembershipPlan, PSRuntime, ReadGateway, RuntimeConfig

from chaos import assert_counters, det_fn, expected_final, x0

pytestmark = pytest.mark.membership

_POLICIES = [
    ("ssp2", policies.ssp(2)),
    ("vap", policies.vap(4.5)),
    ("cvap_strong", policies.cvap(2, 4.5, strong=True)),
]


def _wait_clock(rt, clock, budget=30.0):
    deadline = time.monotonic() + budget
    while rt.completed_clock() < clock:
        assert time.monotonic() < deadline, "runtime stalled before trigger"
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# the core contract: add + remove mid-run == simulator, per policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_add_and_remove_mid_run_equals_simulator(polname, pol):
    """Free 4-worker interleaving; a shard joins at clock >= 5 and the
    original shard 0 retires at clock >= 12.  Final master and every
    process cache equal the (membership-free) simulator bitwise; mid-run
    clock/value bound checks and the update-counter audit record zero
    violations across the migration windows."""
    seed = 3
    fn = det_fn(seed)
    sim = AsyncPS(4, pol, x0(), threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    st_sim = sim.run(fn, 24)

    rt = PSRuntime(RuntimeConfig(4, pol, x0(), n_shards=2, threads_per_process=2,
                   seed=seed, max_shards=4))
    rt.start(fn, 24, timeout=90)
    _wait_clock(rt, 5)
    sid = rt.add_shard()
    assert sid == 2 and rt.partition.active == (0, 1, 2)
    _wait_clock(rt, 12)
    rt.remove_shard(0)
    assert rt.partition.active == (1, 2)
    st_rt = rt.wait()

    assert st_sim.violations == [], st_sim.violations
    assert st_rt.violations == [], st_rt.violations[:5]
    assert st_sim.n_updates == st_rt.n_updates
    assert_counters(rt)
    if pol.clock_bounded:
        assert st_rt.max_observed_staleness <= pol.staleness
    for k, ref in sim.views[0].items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"{polname} master[{k}]")
        for p in range(rt.n_proc):
            np.testing.assert_array_equal(
                rt.view(p)[k].reshape(ref.shape), ref,
                err_msg=f"{polname} proc{p}[{k}]")


# ---------------------------------------------------------------------------
# all transports: the epoch barrier works over real wires
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["queue", "shm", "tcp"])
def test_membership_all_transports(transport):
    """Scripted add + remove mid-run under every transport (forked OS
    clients for shm/tcp): the epoch announce/ack barrier rides the same
    FIFO channels as updates, rows migrate parent-side through the
    vc-stamped snapshot re-partition path, and the quiesced state is
    bitwise x0 + sum(updates) with a clean counter audit."""
    seed = 0
    n_clocks = 22
    plan = MembershipPlan.parse([(4, "add", 2), (10, "remove", 0)])
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(2), x0(), n_shards=2,
                   threads_per_process=2, seed=seed, max_shards=3,
                   transport=transport, membership_plan=plan))
    st = rt.run(det_fn(seed), n_clocks, timeout=110)
    assert st.violations == [], st.violations[:5]
    assert [r for _, r in plan.results] == ["ok", "ok"], plan.results
    assert rt.partition.active == (1, 2)
    assert st.n_updates == 4 * n_clocks * 2
    exp = expected_final(seed, 4, n_clocks)
    for k, ref in exp.items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"{transport} master[{k}]")
    if transport == "queue":
        assert_counters(rt)
    else:
        # proc mode: the per-client sent counters were shipped back over
        # the pipes and checked in _final_checks (violations above); the
        # parent-side applied counters must cover every update part
        applied = int(sum(s.applied_parts.sum() for s in rt.shards))
        assert applied == int(rt._parts_sent.sum()) > 0


# ---------------------------------------------------------------------------
# shrink to one, grow back, re-activate a retired slot
# ---------------------------------------------------------------------------


def test_shrink_to_one_shard_and_reactivate():
    """Remove down to a single shard (everything migrates onto it), then
    re-activate a previously retired slot — the stale-marker epoch filter
    and the seeded frontier markers must keep the clock bound live across
    the re-activation."""
    seed = 5
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), x0(), n_shards=3,
                   threads_per_process=1, seed=seed, max_shards=3))
    rt.start(det_fn(seed), 30, timeout=90)
    _wait_clock(rt, 4)
    rt.remove_shard(0)
    rt.remove_shard(2)
    assert rt.partition.active == (1,)
    _wait_clock(rt, 12)
    rt.add_shard(0)                       # re-activate the retired slot 0
    assert rt.partition.active == (0, 1)
    st = rt.wait()
    assert st.violations == [], st.violations[:5]
    assert_counters(rt)
    exp = expected_final(seed, 2, 30)
    for k, ref in exp.items():
        np.testing.assert_array_equal(rt.master_value(k).reshape(ref.shape),
                                      ref)
    assert rt.membership.log == [(1, (1, 2)), (2, (1,)), (3, (0, 1))]


def test_membership_op_validation():
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), x0(), n_shards=2, seed=0,
                   max_shards=3))
    with pytest.raises(RuntimeError, match="running"):
        rt.add_shard()                    # not started yet
    rt.start(det_fn(0), 12, timeout=60)
    try:
        _wait_clock(rt, 2)
        with pytest.raises(ValueError, match="already active"):
            rt.add_shard(0)
        with pytest.raises(ValueError, match="not active"):
            rt.remove_shard(2)
        rt.add_shard()                    # 3 active: slots exhausted
        with pytest.raises(ValueError, match="max_shards"):
            rt.add_shard()
        rt.remove_shard(1)
        rt.remove_shard(2)
        with pytest.raises(ValueError, match="last active"):
            rt.remove_shard(0)
    finally:
        st = rt.wait()
    assert st.violations == [], st.violations[:5]


def test_max_shards_validation():
    with pytest.raises(ValueError, match="max_shards"):
        PSRuntime(RuntimeConfig(2, policies.bsp(), x0(), n_shards=3, max_shards=2))


# ---------------------------------------------------------------------------
# serving tier across membership: SLO stamps honored, re-bootstrap exact
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_serving_slo_honored_across_membership_change():
    """Gateway reads under SLOs run *through* an add and a remove: every
    response's measured staleness obeys the request (the master frontier
    includes the new owner from install, so mid-migration reads park or
    escalate rather than stamp optimistically), and after quiesce every
    replica equals the master bitwise — the in-stream re-bootstrap made the
    migrated rows exact."""
    seed = 9
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(3), x0(), n_shards=2,
                   threads_per_process=2, seed=seed, max_shards=3))
    rt.start(det_fn(seed), 60, timeout=110)
    gw = ReadGateway(rt, n_replicas=2, transport="queue")
    bad = []
    import itertools
    import threading
    stop = threading.Event()

    def reader():
        slos = itertools.cycle([0, 2, 5, None])
        keys = itertools.cycle(["a", "b"])
        while not stop.is_set():
            slo = next(slos)
            res = gw.read(next(keys), slo=slo, timeout=10.0)
            if slo is not None and res.staleness > slo:
                bad.append((slo, res.staleness, res.source))

    th = threading.Thread(target=reader, daemon=True)
    th.start()
    try:
        _wait_clock(rt, 8)
        rt.add_shard()
        _wait_clock(rt, 25)
        rt.remove_shard(1)
        st = rt.wait()
    finally:
        stop.set()
        th.join(timeout=10.0)
    assert st.violations == [], st.violations[:5]
    assert bad == [], bad[:5]
    assert gw.stats.n_reads > 0
    assert gw.replicas.errors == []
    time.sleep(0.3)                       # let the final publish cycle land
    for rep in gw.replicas.replicas:
        assert not rep.poisoned
        for k, ref in expected_final(seed, 4, 60).items():
            v, _ = rep.serve(k)
            np.testing.assert_array_equal(v.reshape(ref.shape), ref,
                                          err_msg=f"replica{rep.rid}[{k}]")
    gw.close()


# ---------------------------------------------------------------------------
# snapshots interleaved with membership
# ---------------------------------------------------------------------------


def test_snapshot_during_membership_reflects_current_partition():
    """A snapshot taken after a membership change captures the *active*
    shards of the new epoch, restores into any shard count, and its vc
    stamps stay internally consistent (validate_vcs passes on load)."""
    from repro.runtime import snapshot_params, validate_vcs

    seed = 11
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), x0(), n_shards=2,
                   threads_per_process=1, seed=seed, max_shards=3))
    rt.start(det_fn(seed), 20, timeout=90)
    _wait_clock(rt, 4)
    rt.add_shard()
    snap_mid = rt.snapshot()              # mid-run, 3 active shards
    validate_vcs(snap_mid)
    assert snap_mid["n_shards"] == 3 and len(snap_mid["shards"]) == 3
    st = rt.wait()
    assert st.violations == [], st.violations[:5]
    snap = rt.snapshot()
    params = snapshot_params(snap)
    for k, ref in expected_final(seed, 2, 20).items():
        np.testing.assert_array_equal(params[k].reshape(ref.shape), ref)
    # restorable into a different shard count (re-partition path)
    rt2 = PSRuntime(RuntimeConfig(2, policies.bsp(), x0(), n_shards=4, restore_from=snap))
    for k, ref in expected_final(seed, 2, 20).items():
        np.testing.assert_array_equal(rt2.master_value(k).reshape(ref.shape),
                                      ref)
