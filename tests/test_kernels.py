"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes and dtypes (brief requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fk
from repro.kernels.flash_attention import ref as fr
from repro.kernels.rglru_scan import kernel as rk
from repro.kernels.rglru_scan import ref as rr
from repro.kernels.ssd_scan import kernel as sk
from repro.kernels.ssd_scan import ref as sr
from repro.kernels.vap_accum import kernel as vk
from repro.kernels.vap_accum import ref as vr

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # b, sq, skv, kvh, G, dh, dv, window, cap
    (2, 256, 256, 2, 2, 64, 64, None, None),
    (2, 256, 256, 2, 2, 64, 64, 100, None),
    (1, 300, 300, 1, 4, 32, 32, None, 50.0),
    (1, 128, 128, 4, 1, 192, 128, None, None),     # MLA: dv != dh
    (1, 512, 512, 1, 1, 128, 128, 64, 30.0),       # window + cap
    (2, 64, 512, 2, 2, 64, 64, None, None),        # q is a suffix (prefill tail)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    b, sq, skv, kvh, G, dh, dv, window, cap = case
    q = jnp.asarray(RNG.normal(0, 1, (b, sq, kvh, G, dh)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, skv, kvh, dh)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, skv, kvh, dv)), dtype)
    qp = jnp.arange(skv - sq, skv, dtype=jnp.int32)
    kp = jnp.arange(skv, dtype=jnp.int32)
    out = fk.flash_attention_pallas(q, k, v, qp, kp, window=window, cap=cap,
                                    interpret=True)
    ref = fr.attention(q, k, v, qp, kp, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_matches_model_chunked_core():
    """kernel == ref == the model-side banded chunked core."""
    from repro.models.attention import attention_core
    b, s, kvh, G, dh = 1, 1024, 2, 1, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, G, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kvh, dh)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    for window in (None, 128):
        a = fk.flash_attention_pallas(q, k, v, pos, pos, window=window,
                                      interpret=True)
        c = attention_core(q, k, v, pos, pos, window=window, chunk=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=5e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # b, l, h, p, g, n, chunk
    (2, 64, 4, 8, 2, 16, 16),
    (1, 100, 6, 16, 1, 32, 32),     # padding path
    (2, 256, 4, 64, 2, 128, 64),    # production-like dims
    (1, 32, 2, 8, 2, 8, 32),        # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(case, dtype):
    b, l, h, p, g, n, chunk = case
    x = jnp.asarray(RNG.normal(0, 1, (b, l, h, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.1, 1, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), dtype)
    C = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), dtype)
    init = jnp.asarray(RNG.normal(0, 0.5, (b, h, p, n)), jnp.float32)
    y1, s1 = sk.ssd_scan_pallas(x, dt, A, B, C, chunk, initial_state=init,
                                interpret=True)
    y2, s2 = sr.ssd_chunked(x, dt, A, B, C, chunk, initial_state=init)
    # bf16 inputs: kernel carries chunk states in f32 while the oracle's bulk
    # einsums stay bf16 — accumulation-order noise scales with |y| ~ O(5)
    tol = (dict(atol=1e-1, rtol=5e-2) if dtype == jnp.bfloat16
           else dict(atol=5e-5, rtol=1e-4))
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_matches_stepwise():
    """Chunked == naive per-step recurrence (the ultimate oracle)."""
    b, l, h, p, g, n = 1, 40, 4, 8, 2, 16
    x = jnp.asarray(RNG.normal(0, 1, (b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.1, 1, (h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(0, 1, (b, l, g, n)), jnp.float32)
    y, st = sr.ssd_chunked(x, dt, A, B, C, chunk=8)
    Bh, Ch = jnp.repeat(B, h // g, 2), jnp.repeat(C, h // g, 2)
    hstate = jnp.zeros((b, h, p, n))
    for t in range(l):
        yt, hstate = sr.ssd_step(hstate, x[:, t], dt[:, t], A, Bh[:, t], Ch[:, t])
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(yt),
                                   atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(hstate), atol=1e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [(2, 64, 128), (1, 100, 50), (3, 256, 256), (1, 128, 4096)]


@pytest.mark.parametrize("case", RGLRU_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_linear_recurrence(case, dtype):
    b, l, w = case
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (b, l, w)), dtype)
    bb = jnp.asarray(RNG.normal(0, 0.1, (b, l, w)), dtype)
    init = jnp.asarray(RNG.normal(0, 1, (b, w)), jnp.float32)
    h1, l1 = rk.linear_recurrence_pallas(a, bb, initial=init, interpret=True)
    h2, l2 = rr.linear_recurrence(a, bb, initial=init)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_rglru_full_gate_path():
    b, l, w = 2, 96, 64
    x = jnp.asarray(RNG.normal(0, 1, (b, l, w)), jnp.float32)
    r = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    i = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    lam = jnp.asarray(RNG.normal(0, 1, (w,)), jnp.float32)
    h1, l1 = rk.rglru_pallas(x, r, i, lam, interpret=True)
    h2, l2 = rr.rglru(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=5e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-5)


def test_rglru_step_consistency():
    """Sequential steps == full scan."""
    b, l, w = 1, 20, 16
    x = jnp.asarray(RNG.normal(0, 1, (b, l, w)), jnp.float32)
    r = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    i = jnp.asarray(RNG.uniform(0, 1, (b, l, w)), jnp.float32)
    lam = jnp.asarray(RNG.normal(0, 1, (w,)), jnp.float32)
    h_full, _ = rr.rglru(x, r, i, lam)
    h = jnp.zeros((b, w))
    for t in range(l):
        _, h = rr.rglru_step(h, x[:, t], r[:, t], i[:, t], lam)
        np.testing.assert_allclose(np.asarray(h_full[:, t]), np.asarray(h),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# vap accum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 8192, 8193, 100_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vap_accum(n, dtype):
    p = jnp.asarray(RNG.normal(0, 1, n), dtype)
    d = jnp.asarray(RNG.normal(0, 0.01, n), dtype)
    u = jnp.asarray(RNG.normal(0, 0.01, n), dtype)
    p1, d1, m1 = vk.vap_accum_pallas(p, d, u, interpret=True)
    p2, d2, m2 = vr.vap_accum(p, d, u)
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32), **_tol(dtype))
    assert abs(float(m1) - float(m2)) < 1e-2


def test_vap_accum_tree():
    from repro.kernels.vap_accum.ops import vap_accum_tree
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros(7)}}
    delta = jax.tree.map(jnp.zeros_like, tree)
    upd = jax.tree.map(lambda x: x * 0 + 0.5, tree)
    p2, d2, m = vap_accum_tree(tree, delta, upd)
    assert float(m) == 0.5
    np.testing.assert_allclose(np.asarray(p2["a"]), 1.5)


# ---------------------------------------------------------------------------
# ps apply (segment scatter-add)
# ---------------------------------------------------------------------------

from repro.kernels.ps_apply import kernel as pk          # noqa: E402
from repro.kernels.ps_apply import ref as pr             # noqa: E402
from repro.kernels.topk_mag import kernel as tk          # noqa: E402
from repro.kernels.topk_mag import ref as tr             # noqa: E402

PS_APPLY_CASES = [
    # R, C, N — incl. duplicates-heavy, single row, wide block, big batch
    (13, 5, 27),
    (1, 1, 16),
    (8, 128, 8),
    (200, 3, 500),
    (17, 130, 64),    # C > one lane tile
]


@pytest.mark.parametrize("case", PS_APPLY_CASES)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_ps_apply_scatter_add(case, dtype):
    """Kernel must be BITWISE equal to np.add.at (same accumulation order)."""
    from jax.experimental import enable_x64
    import contextlib
    R, C, N = case
    ctx = enable_x64() if dtype == np.float64 else contextlib.nullcontext()
    with ctx:
        dense = RNG.normal(0, 1, (R, C)).astype(dtype)
        rows = RNG.integers(0, R, N).astype(np.int32)
        delta = RNG.normal(0, 1, (N, C)).astype(dtype)
        want = dense.copy()
        np.add.at(want, rows, delta)
        got = np.asarray(pk.scatter_add_pallas(
            jnp.asarray(dense), jnp.asarray(rows), jnp.asarray(delta),
            interpret=True))
        assert got.dtype == want.dtype
        assert np.array_equal(got, want)


def test_ps_apply_dummy_row_is_noop():
    """Sentinel index R routes padding to the dummy row, not real state."""
    R, C = 6, 4
    dense = np.asarray(RNG.normal(0, 1, (R, C)), np.float32)
    rows = np.array([0, R, 5, R], np.int32)
    delta = np.ones((4, C), np.float32)
    want = dense.copy()
    want[0] += 1
    want[5] += 1
    got = np.asarray(pk.scatter_add_pallas(
        jnp.asarray(dense), jnp.asarray(rows), jnp.asarray(delta),
        interpret=True))
    assert np.array_equal(got, want)


def test_ps_apply_ref_duplicates():
    """jnp ref accumulates duplicates like np.add.at (integer-exact)."""
    dense = jnp.zeros((5, 3), jnp.float32)
    rows = jnp.asarray([1, 1, 1, 4], jnp.int32)
    delta = jnp.ones((4, 3), jnp.float32)
    out = np.asarray(pr.scatter_add(dense, rows, delta))
    assert np.array_equal(out[1], [3, 3, 3])
    assert np.array_equal(out[4], [1, 1, 1])
    assert np.array_equal(out[0], [0, 0, 0])


def test_ps_apply_ops_inplace_f64(monkeypatch):
    """Runtime entry keeps f64 bitwise through the interpret-mode kernel."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    from repro.kernels.ps_apply import ops as pops
    dense = RNG.normal(0, 1, (11, 7))
    rows = RNG.integers(0, 11, 23).astype(np.int64)
    delta = RNG.normal(0, 1, (23, 7))
    want = dense.copy()
    np.add.at(want, rows, delta)
    got = dense.copy()
    pops.scatter_add_inplace(got, rows, delta)
    assert got.dtype == np.float64
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# topk mag (largest-|Δ|-first ordering)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 6, 127, 128, 300])
def test_topk_mag_full_order(n):
    """Kernel ordering == stable descending argsort, incl. tie buckets."""
    mags = RNG.integers(0, max(2, n // 3), n).astype(np.float32)
    want = np.argsort(-mags, kind="stable")
    got = np.asarray(tk.topk_mag_pallas(jnp.asarray(mags), interpret=True))
    assert np.array_equal(got, want)
    assert np.array_equal(np.asarray(tr.magnitude_order(jnp.asarray(mags))),
                          want)


def test_topk_mag_prefix_k():
    mags = np.asarray([0.5, 9.0, 1.0, 9.0, 3.0], np.float32)
    got = np.asarray(tk.topk_mag_pallas(jnp.asarray(mags), k=3,
                                        interpret=True))
    assert np.array_equal(got, [1, 3, 4])


def test_topk_mag_ops_matches_seed_sort(monkeypatch):
    """ops path == the seed Python sort key=-max|Δ| order (ties stable)."""
    from repro.kernels.topk_mag import ops as tops
    mags = RNG.integers(0, 4, 40).astype(np.float64)
    idx = list(range(len(mags)))
    idx.sort(key=lambda i: -mags[i])
    monkeypatch.setenv("REPRO_PALLAS", "off")
    assert np.array_equal(tops.magnitude_order(mags), idx)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    assert np.array_equal(tops.magnitude_order(mags), idx)


def test_topk_mag_ops_refines_sub_f32_resolution_ties(monkeypatch):
    """Magnitudes distinct in f64 that collapse to one f32 value must still
    ship in exact f64 descending order: the kernel's f32 coarse pass alone
    would resolve them first-occurrence and diverge from the numpy path
    (send order feeds non-associative float applies — bitwise simulator
    conformance depends on it)."""
    from repro.kernels.topk_mag import ops as tops
    rng = np.random.default_rng(3)
    # perturbations far below f32 resolution at 1.0 (~6e-8): one f32 bucket
    sub = 1.0 + rng.permutation(8) * 1e-12
    assert np.unique(sub.astype(np.float32)).size == 1
    # mix in genuinely distinct values and an exact f64 tie inside the
    # bucket (index 8 duplicates one of the first eight values)
    mags = np.concatenate([sub, [sub[3], 2.0, 0.5, 7.0]])
    want = np.argsort(-mags, kind="stable")
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    got = tops.magnitude_order(mags)
    assert np.array_equal(got, want)
    # the exact f64 tie stays first-occurrence: original index before dup
    assert list(got).index(3) < list(got).index(8)
