"""Prefill + decode must reproduce the teacher-forced full forward — for all
10 architectures (MoE capacity bumped so drop boundaries don't differ
between modes)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import model as M
from repro.models.common import ShardCtx, instantiate_tree

ARCH_IDS = sorted(ARCHS)


def _cfg(name):
    cfg = dataclasses.replace(reduced_config(name), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = _cfg(arch)
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    s = 16
    ids = jax.random.randint(jax.random.key(1), (2, s + 3), 0, cfg.vocab_size)
    extra = (jax.random.normal(jax.random.key(2),
                               (2, cfg.frontend.n_embeds, cfg.d_model)) * 0.01
             if cfg.frontend else None)

    x, _, _ = M.forward(cfg, ctx, params, ids, extra_emb=extra, remat=False)
    w = M.head_matrix(cfg, params)

    _, caches = M.prefill(cfg, ctx, params, ids[:, :s], capacity=s + 8,
                          extra_emb=extra)
    for j in range(3):   # three consecutive decode steps
        pos = jnp.full((2,), s + j, jnp.int32)
        logits_d, caches = M.decode_step(cfg, ctx, params, ids[:, s + j:s + j + 1],
                                         pos, caches)
        gt = (x[:, s + j] @ w).astype(jnp.float32)
        if cfg.final_softcap:
            gt = jnp.tanh(gt / cfg.final_softcap) * cfg.final_softcap
        err = float(jnp.max(jnp.abs(logits_d - gt)))
        assert err < 2e-3, (arch, j, err)


@pytest.mark.parametrize("arch", ["gemma2-9b", "recurrentgemma-9b", "qwen3-8b"])
def test_sliding_window_ring_cache(arch):
    """Decode far past the window: ring cache must overwrite correctly."""
    cfg = dataclasses.replace(_cfg(arch), window=8, long_context_window=8)
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    total = 24
    ids = jax.random.randint(jax.random.key(1), (1, total + 1), 0,
                             cfg.vocab_size)
    # ground truth under long-ctx windowing
    x, _, _ = M.forward(cfg, ctx, params, ids, remat=False, long_ctx=True)
    w = M.head_matrix(cfg, params)
    s = 8
    _, caches = M.prefill(cfg, ctx, params, ids[:, :s], capacity=s,
                          long_ctx=True)
    for j in range(total - s):
        pos = jnp.full((1,), s + j, jnp.int32)
        logits_d, caches = M.decode_step(cfg, ctx, params,
                                         ids[:, s + j:s + j + 1], pos, caches,
                                         long_ctx=True)
    gt = (x[:, total - 1] @ w).astype(jnp.float32)
    if cfg.final_softcap:
        gt = jnp.tanh(gt / cfg.final_softcap) * cfg.final_softcap
    err = float(jnp.max(jnp.abs(logits_d - gt)))
    assert err < 2e-3, (arch, err)
