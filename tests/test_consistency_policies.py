"""Unit tests: policies and the Consistency Controller (paper §2, §4.3)."""
import numpy as np
import pytest

from repro.configs import ConsistencySpec
from repro.core import controller, policies


def test_policy_constructors():
    assert policies.bsp().staleness == 0
    assert policies.bsp().push_at_clock_only
    assert policies.ssp(3).staleness == 3
    assert policies.ssp(3).push_at_clock_only
    assert not policies.cap(3).push_at_clock_only
    assert policies.vap(0.5).value_bounded
    assert not policies.vap(0.5).clock_bounded
    p = policies.cvap(2, 0.1, strong=True)
    assert p.clock_bounded and p.value_bounded and p.strong
    e = policies.essp(2)
    assert e.clock_bounded and not e.push_at_clock_only
    assert e.server_push_on_boundary and not e.tracks_sync
    el = policies.elastic(0.5)
    assert el.norm_bounded and el.tracks_sync
    assert not el.clock_bounded and not el.value_bounded


def test_policy_validation():
    with pytest.raises(ValueError):
        policies.Policy("nonsense")
    with pytest.raises(ValueError):
        policies.Policy("cap", staleness=-1)
    with pytest.raises(ValueError):
        policies.Policy("vap", value_bound=0.0)


def test_policy_rejects_inactive_bounds():
    """Bounds the kind does not interpret raise instead of silently
    dropping (the dead-parameter bugfix)."""
    with pytest.raises(ValueError):
        policies.Policy("vap", staleness=3, value_bound=0.5)
    with pytest.raises(ValueError):
        policies.Policy("elastic", staleness=3, value_bound=0.5)
    with pytest.raises(ValueError):
        policies.Policy("ssp", staleness=2, value_bound=0.5)
    with pytest.raises(ValueError):
        policies.Policy("bsp", value_bound=0.5)
    with pytest.raises(ValueError):
        policies.Policy("essp", staleness=1, value_bound=0.5)
    with pytest.raises(ValueError):
        policies.Policy("ssp", staleness=2, strong=True)
    with pytest.raises(ValueError):
        policies.Policy("elastic", value_bound=0.5, strong=True)
    with pytest.raises(ValueError):
        policies.Policy("essp", staleness=1, push_at_clock_only=True)
    with pytest.raises(ValueError):
        policies.Policy("elastic", value_bound=0.5, push_at_clock_only=True)
    # interpreted combinations stay legal
    policies.Policy("bsp", staleness=3)          # clock-bounded, read by gate
    policies.Policy("cvap", staleness=2, value_bound=0.5, strong=True)
    policies.Policy("vap", value_bound=0.5)
    policies.Policy("elastic", value_bound=0.5)


def test_from_spec():
    p = policies.from_spec(ConsistencySpec(model="cvap", staleness=4,
                                           value_bound=0.25))
    assert p.kind == "cvap" and p.staleness == 4 and p.value_bound == 0.25
    assert policies.from_spec(ConsistencySpec(model="bsp")).kind == "bsp"
    e = policies.from_spec(ConsistencySpec(model="essp", staleness=2))
    assert e.kind == "essp" and e.staleness == 2
    el = policies.from_spec(ConsistencySpec(model="elastic", value_bound=0.7))
    assert el.kind == "elastic" and el.value_bound == 0.7 and el.norm_bounded


def test_clock_gate_bsp_is_barrier():
    p = policies.bsp()
    # worker at clock 1 must have seen every update of period 0
    assert controller.clock_gate(p, 1, np.array([0, 0, 0]))
    assert not controller.clock_gate(p, 1, np.array([0, -1, 0]))
    assert controller.clock_gate(p, 0, np.array([-1, -1]))   # nothing needed yet


def test_clock_gate_staleness_window():
    p = policies.cap(2)
    # worker at clock 3 needs everything stamped <= 0
    assert controller.clock_gate(p, 3, np.array([0, 0]))
    assert not controller.clock_gate(p, 3, np.array([-1, 0]))
    assert controller.clock_gate(p, 2, np.array([-1, -1]))


def test_clock_gate_vap_never_blocks():
    p = policies.vap(0.1)
    assert controller.clock_gate(p, 100, np.array([-1, -1]))


def test_value_gate_blocks_and_oversize_exception():
    p = policies.vap(1.0)
    ok, _ = controller.value_gate(p, np.array([0.8]), np.array([0.3]))
    assert not ok                               # 1.1 > 1.0 and accum nonzero
    ok, _ = controller.value_gate(p, np.array([0.0]), np.array([5.0]))
    assert ok                                   # lone oversized update admitted
    ok, _ = controller.value_gate(p, np.array([0.5]), np.array([0.4]))
    assert ok                                   # 0.9 <= 1.0


def test_value_gate_elementwise():
    p = policies.vap(1.0)
    ok, viol = controller.value_gate(p, np.array([0.9, 0.0]),
                                     np.array([0.2, 0.2]))
    assert not ok and viol[0] and not viol[1]


def test_strong_delivery_gate():
    p = policies.vap(1.0, strong=True)
    assert controller.strong_delivery_gate(p, np.array([0.0]), np.array([0.5]))
    assert not controller.strong_delivery_gate(p, np.array([0.8]), np.array([0.5]))
    # oversized update admitted when budget is free
    assert controller.strong_delivery_gate(p, np.array([0.0]), np.array([9.0]))
    # weak policy never gates delivery
    pw = policies.vap(1.0, strong=False)
    assert controller.strong_delivery_gate(pw, np.array([99.0]), np.array([1.0]))


def test_vap_unsynced_bound():
    p = policies.vap(0.5)
    assert controller.vap_unsynced_bound(p, 0.1) == 0.5
    assert controller.vap_unsynced_bound(p, 2.0) == 2.0   # max(u, v_thr)
