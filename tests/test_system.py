"""End-to-end behaviour tests: real training runs on the synthetic corpus,
MoE dispatch against a dense reference, frontends, the full LDA application
on the async PS, and consistency-model convergence comparisons."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ConsistencySpec, TrainConfig, reduced_config
from repro.launch.train import run as train_run


@pytest.mark.slow
def test_e2e_train_loss_decreases():
    cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
    tcfg = TrainConfig(arch="olmo-1b", steps=30, lr=2e-3, optimizer="adam",
                       log_every=5,
                       consistency=ConsistencySpec(model="bsp"))
    _, hist = train_run(tcfg, cfg, mesh=None, batch_size=4, seq_len=64,
                        log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


@pytest.mark.slow
def test_e2e_consistency_models_all_train():
    cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
    finals = {}
    for model, s, v in [("bsp", 0, 0.0), ("cap", 3, 0.0), ("cvap", 3, 0.05)]:
        tcfg = TrainConfig(arch="olmo-1b", steps=20, lr=2e-3, optimizer="adam",
                           log_every=19,
                           consistency=ConsistencySpec(model=model, staleness=s,
                                                       value_bound=v))
        _, hist = train_run(tcfg, cfg, mesh=None, batch_size=4, seq_len=64,
                            log=lambda *_: None)
        finals[model] = hist[-1]["loss"]
        assert np.isfinite(hist[-1]["loss"])
    # single replica: all consistency models see the same data/updates
    assert abs(finals["bsp"] - finals["cap"]) < 1e-4


def test_moe_matches_dense_expert_loop():
    """Capacity→∞ MoE == explicit loop over experts weighted by the router."""
    from repro.configs import get_config
    from repro.models import moe as F
    from repro.models.common import ShardCtx, instantiate_tree

    cfg = dataclasses.replace(
        reduced_config("olmoe-1b-7b"), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    ctx = ShardCtx()
    defs = F.moe_defs(cfg, 1)
    p = instantiate_tree(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.5
    y, aux = F.moe_fwd(cfg, ctx, p, x)

    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(cfg.moe.n_experts):
        h = jax.nn.silu(xt @ p["w_in"][e]) * (xt @ p["w_gate"][e])
        ye = h @ p["w_out"][e]
        w_e = jnp.where(ei == e, gv, 0.0).sum(-1)
        out = out + ye * w_e[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(out), atol=2e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models import moe as F
    from repro.models.common import ShardCtx, instantiate_tree
    cfg = dataclasses.replace(reduced_config("olmoe-1b-7b"), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    p = instantiate_tree(F.moe_defs(cfg, 1), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, _ = F.moe_fwd(cfg, ShardCtx(), p, x)
    assert bool(jnp.isfinite(y).all())


def test_frontend_override_positions():
    from repro.models import model as M
    from repro.models.common import ShardCtx, instantiate_tree
    cfg = dataclasses.replace(reduced_config("pixtral-12b"), dtype="float32")
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    ids = jnp.ones((1, 16), jnp.int32)
    e1 = jax.random.normal(jax.random.key(1), (1, cfg.frontend.n_embeds, cfg.d_model))
    e2 = e1.at[:, 0].add(1.0)
    x1, _, _ = M.forward(cfg, ctx, params, ids, extra_emb=e1, remat=False)
    x2, _, _ = M.forward(cfg, ctx, params, ids, extra_emb=e2, remat=False)
    # patch embeddings must influence the output; identical elsewhere at layer 0
    assert float(jnp.max(jnp.abs(x1 - x2))) > 1e-6


def test_lda_on_async_ps():
    """The paper's evaluation application: collapsed-Gibbs LDA over the
    parameter server, log-likelihood must rise under every policy."""
    from repro.core import NetworkModel, bsp, vap
    from repro.data import synthetic_corpus
    from repro.apps import lda  # noqa

    corpus = synthetic_corpus(n_docs=24, vocab_size=60, n_topics=4,
                              doc_len=40, seed=0)
    for pol in [bsp(), vap(5.0)]:
        lls = lda.run_lda(corpus, n_topics=4, policy=pol, n_workers=4,
                          n_clocks=8, seed=0,
                          network=NetworkModel(base_delay=0.1, seed=0))
        assert lls[-1] > lls[0], (pol.kind, lls[0], lls[-1])
