"""Wire-transport tests: framing round-trips, partial reads, FIFO seq
assertions, shared-memory ring behavior, and tcp end-to-end integration.

Property-style: message contents, frame chunking, and batch sizes are
randomized over seeded sweeps, so the codec is exercised across array
shapes/dtypes and every short-frame split point rather than a single happy
path.
"""
import os
import queue
import threading
import time

import numpy as np
import pytest

from repro.runtime import messages as M
from repro.runtime import transport as T


def _msg_equal(a, b):
    assert type(a) is type(b)
    for f in a.__dataclass_fields__:
        va, vb = getattr(a, f), getattr(b, f)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f"{type(a)}.{f}")
            assert va.dtype == vb.dtype
        else:
            assert va == vb, f"{type(a).__name__}.{f}: {va} != {vb}"


def _sample_msgs(rng, n=20):
    """A mixed bag of every message type with randomized array payloads."""
    out = []
    for i in range(n):
        kind = i % 7
        rows = np.sort(rng.choice(64, size=rng.integers(1, 9), replace=False))
        delta = rng.normal(size=(len(rows), int(rng.integers(1, 5))))
        if kind == 0:
            out.append(M.UpdateMsg(i, int(rng.integers(4)), 0,
                                   int(rng.integers(10)), "k", rows, delta))
        elif kind == 1:
            out.append(M.DeliverMsg(i, 1, 0, 1, 3, "key/with|chars", rows,
                                    delta))
        elif kind == 2:
            out.append(M.AckMsg(i, int(rng.integers(4))))
        elif kind == 3:
            out.append(M.ClockMsg(int(rng.integers(4)), int(rng.integers(50))))
        elif kind == 4:
            out.append(M.ClockMarker(0, 1, int(rng.integers(50))))
        elif kind == 5:
            out.append(M.FullyDelivered(i, 2, "k", rows, delta, 0))
        else:
            out.append(M.ProcDoneMsg(int(rng.integers(4))))
    out.append(M.ShardFinMsg(1))
    return out


# ---------------------------------------------------------------------------
# framing round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_frame_roundtrip_all_types(seed):
    rng = np.random.default_rng(seed)
    msgs = _sample_msgs(rng)
    dec = T.FrameDecoder()
    got = dec.feed(T.encode_frame(msgs))
    assert len(got) == len(msgs)
    for a, b in zip(msgs, got):
        _msg_equal(a, b)
    assert dec.pending_bytes == 0


def test_frame_roundtrip_edge_arrays():
    """Empty rows, single element, large block, f32 vs f64, non-C-order."""
    big = np.random.default_rng(0).normal(size=(500, 64))
    cases = [
        M.UpdateMsg(0, 0, 0, 0, "k", np.arange(0), np.zeros((0, 3))),
        M.UpdateMsg(1, 0, 0, 0, "k", np.arange(1), np.ones((1, 1))),
        M.UpdateMsg(2, 0, 0, 0, "k", np.arange(500), big),
        M.DeliverMsg(3, 0, 0, 0, 0, "k", np.arange(4),
                     np.ones((4, 2), dtype=np.float32)),
        M.DeliverMsg(4, 0, 0, 0, 0, "k", np.arange(4),
                     np.asfortranarray(np.ones((4, 2)))),
    ]
    for msg in cases:
        got = T.FrameDecoder().feed(T.encode_frame([msg]))
        assert len(got) == 1
        _msg_equal(msg, got[0])


@pytest.mark.parametrize("seed", [0, 1])
def test_decoder_handles_arbitrary_chunking(seed):
    """Byte-by-byte and random-split feeds must yield identical messages —
    partial reads / short frames stay buffered, never error."""
    rng = np.random.default_rng(seed)
    msgs = _sample_msgs(rng, n=10)
    stream = b"".join(T.encode_frame([m]) for m in msgs)

    # byte-by-byte
    dec = T.FrameDecoder()
    got = []
    for i in range(len(stream)):
        got.extend(dec.feed(stream[i:i + 1]))
    assert len(got) == len(msgs)
    for a, b in zip(msgs, got):
        _msg_equal(a, b)

    # random chunk sizes
    dec, got, off = T.FrameDecoder(), [], 0
    while off < len(stream):
        n = int(rng.integers(1, 200))
        got.extend(dec.feed(stream[off:off + n]))
        off += n
    assert len(got) == len(msgs)
    assert dec.pending_bytes == 0


def test_short_frame_stays_buffered():
    frame = T.encode_frame([M.AckMsg(7, 1)])
    dec = T.FrameDecoder()
    assert dec.feed(frame[:-1]) == []          # one byte short: no message
    assert dec.pending_bytes == len(frame) - 1
    got = dec.feed(frame[-1:])
    assert len(got) == 1 and got[0].uid == 7


def test_truncated_payload_raises():
    frame = bytearray(T.encode_frame([M.AckMsg(7, 1)]))
    # lie about the payload length: claim 3 fewer bytes than the pickle needs
    import struct
    plen = struct.unpack_from("<I", frame, 0)[0]
    struct.pack_into("<I", frame, 0, plen - 3)
    with pytest.raises(ValueError):
        T.FrameDecoder().feed(bytes(frame[:len(frame) - 3]))


def test_eof_sentinel_closes_stream():
    dec = T.FrameDecoder()
    msgs = dec.feed(T.encode_frame([M.AckMsg(1, 0)]) + T.eof_frame())
    assert len(msgs) == 1
    assert dec.closed
    with pytest.raises(ValueError):
        dec.feed(b"\x00")


# ---------------------------------------------------------------------------
# seq stamping + FIFO assertions
# ---------------------------------------------------------------------------


def test_wirechannel_batches_and_stamps_seq():
    sink = bytearray()
    chan = T.WireChannel("c", sink.extend)
    chan.send_many([M.AckMsg(i, 0) for i in range(5)])
    chan.send(M.ClockMsg(0, 9))
    got = T.FrameDecoder().feed(bytes(sink))
    assert [m.seq for m in got] == list(range(6))


def test_wirechannel_seq_monotone_across_threads():
    """Many sender threads share one channel: stream order must carry
    contiguous seqs (stamp + write are atomic under the channel lock)."""
    sink = bytearray()
    lock = threading.Lock()

    def write(data):
        with lock:
            sink.extend(data)

    chan = T.WireChannel("c", write)

    def sender(base):
        for i in range(50):
            chan.send(M.AckMsg(base * 1000 + i, 0))

    threads = [threading.Thread(target=sender, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = T.FrameDecoder().feed(bytes(sink))
    assert [m.seq for m in got] == list(range(200))
    fifo = T.FifoAssert()
    assert all(fifo.check("c", m.seq) is None for m in got)


def test_fifo_assert_detects_gap_reorder_replay():
    fifo = T.FifoAssert()
    assert fifo.check("a", 0) is None
    assert fifo.check("a", 1) is None
    assert "seq 3 after 1" in fifo.check("a", 3)      # gap
    assert fifo.check("a", 4) is None                 # resynced after gap
    assert fifo.check("b", 0) is None                 # per-sender state
    fifo2 = T.FifoAssert()
    fifo2.check("a", 0)
    assert fifo2.check("a", 0) is not None            # replay
    fifo3 = T.FifoAssert()
    fifo3.check("a", 1)                               # starts past 0: gap
    assert fifo3.check("a", 0) is not None            # reorder


def test_frame_roundtrip_serving_and_ackbatch_msgs():
    """The serving-tier publish messages and the batched ack round-trip the
    codec, numpy buffers (uids, vcs, dense state blocks) intact."""
    rng = np.random.default_rng(7)
    rows = np.arange(0, 12, 2)
    delta = rng.normal(size=(6, 3))
    state = {"k": {"rows": rows.copy(), "values": delta.copy()},
             "k2": {"rows": np.arange(4), "values": rng.normal(size=(4, 1))}}
    vc = np.array([3, -1, 7], dtype=np.int64)
    msgs = [
        M.AckBatchMsg(np.arange(17, dtype=np.int64), 1),
        M.ReplicaDeltaMsg(0, "k", rows, delta),
        M.ReplicaVcMsg(1, vc),
        M.ReplicaStateMsg(0, state, vc),
        M.ReplicaFinMsg(1),
    ]
    got = T.FrameDecoder().feed(T.encode_frame(msgs))
    assert [type(m) for m in got] == [type(m) for m in msgs]
    np.testing.assert_array_equal(got[0].uids, msgs[0].uids)
    assert got[0].process == 1
    np.testing.assert_array_equal(got[1].delta, delta)
    np.testing.assert_array_equal(got[2].clock_vc, vc)
    for key in state:
        np.testing.assert_array_equal(got[3].state[key]["rows"],
                                      state[key]["rows"])
        np.testing.assert_array_equal(got[3].state[key]["values"],
                                      state[key]["values"])
    assert got[4].shard == 1


def test_vap_acks_coalesce_into_batched_frames():
    """Satellite of the serving PR: per-row acks coalesce into one
    AckBatchMsg per (client, shard, flush) — the ack *message* count stays
    well below the acked-update count (clock-only policies skip acks
    entirely, so the cycle only exists under a value bound)."""
    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig

    x0 = {f"k{i}": np.zeros(4) for i in range(6)}

    def fn(w, clock, view, rng):
        return {k: rng.normal(size=4) for k in x0}

    rt = PSRuntime(RuntimeConfig(2, policies.vap(1e6), x0, n_shards=2,
                   threads_per_process=1, seed=0))
    st = rt.run(fn, 30, timeout=60)
    assert st.violations == []
    # every delivered part is acked exactly once...
    assert st.n_acked_updates > 0
    # ...but the acks ride far fewer messages than updates they cover
    assert st.n_ack_msgs <= st.n_acked_updates // 2, (
        st.n_ack_msgs, st.n_acked_updates)


def test_clock_only_policies_send_no_acks():
    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig

    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), {"a": np.zeros((4, 2))}, n_shards=2))
    st = rt.run(lambda w, c, v, r: {"a": np.ones((4, 2))}, 10, timeout=60)
    assert st.violations == []
    assert st.n_ack_msgs == 0 and st.n_acked_updates == 0


# ---------------------------------------------------------------------------
# x86-TSO assumption of the shm rings: runtime-checked, not just documented
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", ["aarch64", "ARM64"])
def test_shm_transport_refuses_weakly_ordered_isa(monkeypatch, machine):
    """The shm ring cursors assume x86 total store ordering; on arm the
    transport must refuse loudly with a pointer at tcp, not corrupt."""
    import platform

    monkeypatch.setattr(platform, "machine", lambda: machine)
    with pytest.raises(RuntimeError, match=r'transport="tcp"'):
        T.ShmTransport(1, 1)
    with pytest.raises(RuntimeError, match="total store ordering"):
        T.require_tso()


def test_serving_shm_refuses_weakly_ordered_isa(monkeypatch):
    import platform

    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig
    from repro.runtime.serving import ReplicaSet

    rt = PSRuntime(RuntimeConfig(1, policies.ssp(1), {"a": np.zeros(4)}, n_shards=1))
    monkeypatch.setattr(platform, "machine", lambda: "aarch64")
    with pytest.raises(RuntimeError, match=r'transport="tcp"'):
        ReplicaSet(rt, 1, transport="shm")


def test_runtime_flags_tampered_seq():
    """End-to-end: a frame whose seqs were tampered with on the wire is
    detected by the receiving shard's FIFO assertion."""
    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig

    rt = PSRuntime(RuntimeConfig(1, policies.ssp(1), {"a": np.zeros((4, 2))}, n_shards=1))
    msgs = [M.UpdateMsg(0, 0, 0, 0, "a", np.arange(1), np.ones((1, 2)))]
    msgs[0].seq = 5                                     # wire says 5, not 0
    shard = rt.shards[0]
    assert shard._handle_batch(list(msgs)) is False
    assert any("FIFO violation" in v for v in rt.stats.violations)


# ---------------------------------------------------------------------------
# shared-memory ring
# ---------------------------------------------------------------------------


def test_shm_ring_roundtrip_with_wraparound():
    ring = T.ShmRing.create(256)       # tiny: every few frames wrap
    try:
        rng = np.random.default_rng(3)
        sent = [M.AckMsg(int(i), int(rng.integers(4))) for i in range(200)]
        got = []
        dec = T.FrameDecoder()

        def consumer():
            while len(got) < len(sent):
                got.extend(dec.feed(ring.read_available()))
                time.sleep(1e-4)

        th = threading.Thread(target=consumer)
        th.start()
        chan = T.WireChannel("r", lambda d: ring.write(d, time.monotonic() + 30))
        for m in sent:
            chan.send(m)
        th.join(timeout=30)
        assert len(got) == len(sent)
        assert [m.uid for m in got] == [m.uid for m in sent]
        assert [m.seq for m in got] == list(range(len(sent)))
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_blocks_until_drained():
    ring = T.ShmRing.create(128)
    try:
        frame = T.encode_frame([M.AckMsg(0, 0)])
        n_fit = 128 // len(frame)
        for _ in range(n_fit):
            ring.write(frame)
        state = {}

        def writer():
            t0 = time.monotonic()
            ring.write(frame, deadline=time.monotonic() + 30)
            state["blocked_for"] = time.monotonic() - t0

        th = threading.Thread(target=writer)
        th.start()
        time.sleep(0.15)
        assert th.is_alive()               # full ring: writer is blocked
        ring.read_available()              # consumer drains -> space frees
        th.join(timeout=30)
        assert not th.is_alive()
        assert state["blocked_for"] >= 0.1
    finally:
        ring.close()
        ring.unlink()


def test_wirechannel_splits_batches_over_max_frame():
    """Batches above the frame cap split into several frames (a bounded
    ring cannot take arbitrarily large frames); FIFO seqs stay contiguous."""
    frames = []
    msgs = [M.UpdateMsg(i, 0, 0, 0, "k", np.arange(16),
                        np.ones((16, 16))) for i in range(32)]
    one = len(T.encode_frame([msgs[0]]))
    chan = T.WireChannel("c", frames.append, max_frame=3 * one)
    chan.send_many(msgs)
    assert len(frames) > 1                      # actually split
    assert all(len(f) <= 3 * one for f in frames)
    got = T.FrameDecoder().feed(b"".join(frames))
    assert [m.uid for m in got] == list(range(32))
    assert [m.seq for m in got] == list(range(32))


def test_proc_runtime_handles_rows_larger_than_default_ring():
    """A key bigger than the 1 MiB default ring: capacity is sized from the
    largest part, so a whole-key Inc round-trips through the shm backend."""
    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig

    big = (2048, 128)                           # 2 MiB of float64 rows
    def fn(w, clock, view, rng):
        return {"w": np.ones(big)}

    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), {"w": np.zeros(big)}, n_shards=2,
                   threads_per_process=1, seed=0, transport="shm"))
    st = rt.run(fn, 3, timeout=90)
    assert st.violations == []
    assert float(rt.master_value("w").sum()) == 2 * 3 * big[0] * big[1]


def test_shm_ring_rejects_oversized_frame():
    ring = T.ShmRing.create(64)
    try:
        with pytest.raises(ValueError, match="exceeds ring capacity"):
            ring.write(b"x" * 65)
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_write_timeout():
    ring = T.ShmRing.create(32)
    try:
        ring.write(b"x" * 30)
        with pytest.raises(RuntimeError, match="timed out"):
            ring.write(b"y" * 10, deadline=time.monotonic() + 0.3)
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_survives_stale_cursor_reads():
    """Cross-process cursor reads can transiently return stale values on
    virtualized hosts (observed in the wild: a reader briefly seeing
    tail=0 after thousands of bytes).  A bogus reading must never reach
    the ring arithmetic — the old code computed a *negative* available
    count and rewound head, replaying the whole stream."""
    ring = T.ShmRing.create(64)
    try:
        ring.write(b"a" * 10)
        assert ring.read_available() == b"a" * 10
        ring.write(b"b" * 10)
        # simulate a stale tail read (behind head): must read as empty and
        # must NOT move the head cursor
        good_tail = ring._tail()
        ring._set_tail(0)
        assert ring.read_available() == b""
        assert ring._head() == 10
        # ...and a garbage tail far beyond what the ring could hold
        ring._set_tail(10 + ring.capacity + 1)
        assert ring.read_available() == b""
        ring._set_tail(good_tail)
        assert ring.read_available() == b"b" * 10        # stream intact
        # producer side: a stale head must clamp free space to "full",
        # never overstate it (that would overwrite unread bytes)
        good_head = ring._head()
        ring._set_head(ring._tail() + 1)
        assert ring.free_bytes() == 0
        assert not ring.try_write(b"x")
        ring._set_head(good_head)
        assert ring.free_bytes() == ring.capacity
    finally:
        ring.close()
        ring.unlink()


# ---------------------------------------------------------------------------
# tcp end-to-end
# ---------------------------------------------------------------------------


def test_tcp_transport_duplex_end_to_end():
    """One connection per (process, shard) pair; framed messages flow both
    directions and arrive in FIFO order with contiguous seqs."""
    tp = T.TcpTransport(n_proc=2, n_shards=2)
    tp.listen()
    client_conns = {}

    def client(pid):
        client_conns[pid] = tp.connect(pid)

    threads = [threading.Thread(target=client, args=(p,)) for p in range(2)]
    for t in threads:
        t.start()
    conns = tp.accept_all(deadline=time.monotonic() + 30)
    for t in threads:
        t.join()
    assert set(conns) == {(p, s) for p in range(2) for s in range(2)}

    try:
        # client 1 -> shard 0: a batched frame of updates
        chan = T.WireChannel("p1->s0", client_conns[1][0].write)
        rows = np.arange(3)
        chan.send_many([M.UpdateMsg(i, 2, 1, 0, "k", rows, np.ones((3, 2)) * i)
                        for i in range(10)])
        inbox = queue.Queue()
        errs = []
        T.start_reader("rx", conns[(1, 0)].read_chunk, inbox, errs.append)
        got = [inbox.get(timeout=10) for _ in range(10)]
        assert [m.uid for m in got] == list(range(10))
        assert [m.seq for m in got] == list(range(10))
        np.testing.assert_array_equal(got[3].delta, np.ones((3, 2)) * 3)

        # shard 0 -> client 1 on the same connection (duplex)
        back = T.WireChannel("s0->p1", conns[(1, 0)].write)
        back.send(M.ShardFinMsg(0))
        inbox2 = queue.Queue()
        T.start_reader("rx2", client_conns[1][0].read_chunk, inbox2,
                       errs.append)
        fin = inbox2.get(timeout=10)
        assert isinstance(fin, M.ShardFinMsg) and fin.shard == 0
        assert errs == []
    finally:
        for conn in conns.values():
            conn.close()
        for cs in client_conns.values():
            for conn in cs.values():
                conn.close()


# ---------------------------------------------------------------------------
# zero-copy raw wire (RowCodec + RingViewReader)
# ---------------------------------------------------------------------------


def _mk_zero_copy(cap=1 << 16, keys=("k", "k2")):
    ring = T.ShmRing.create(cap)
    codec = T.RowCodec(list(keys))
    bell = os.pipe()
    reader = T.RingViewReader(ring, codec, bell[0], threading.Event())
    chan = T.WireChannel("zc", T.ring_parts_writer(ring),
                         max_frame=cap // 4, codec=codec,
                         on_flush=lambda: T.ShmEdge.ring_bell(bell[1]))
    return ring, codec, reader, chan, bell


def _close_zero_copy(ring, bell):
    # decoded views must be dropped before the segment closes, else
    # SharedMemory.__del__ trips over the exported buffers at GC time
    import gc
    gc.collect()
    ring.close()
    ring.unlink()
    for fd in bell:
        try:
            os.close(fd)
        except OSError:
            pass


def _ring_mem(reader):
    return np.frombuffer(reader.ring.buf, dtype=np.uint8)


@pytest.mark.parametrize("seed", [0, 1])
def test_raw_wire_roundtrip_mixed_batch(seed):
    """Raw-eligible Update/Deliver msgs and pickle fallbacks (control msgs,
    unknown keys, f32 deltas) interleave on one stream in FIFO order."""
    rng = np.random.default_rng(seed)
    ring, codec, reader, chan, bell = _mk_zero_copy()
    try:
        msgs = []
        for i in range(24):
            rows = np.sort(rng.choice(64, size=int(rng.integers(1, 9)),
                                      replace=False)).astype(np.int64)
            delta = rng.normal(size=(len(rows), 3))
            kind = i % 4
            if kind == 0:
                msgs.append(M.UpdateMsg(i, 1, 0, int(rng.integers(9)), "k",
                                        rows, delta, epoch=2))
            elif kind == 1:
                msgs.append(M.DeliverMsg(i, 1, 0, 1, 3, "k2", rows, delta))
            elif kind == 2:
                msgs.append(M.AckMsg(i, 1))               # pickle fallback
            else:
                msgs.append(M.UpdateMsg(i, 1, 0, 0, "unknown-key", rows,
                                        delta.astype(np.float32)))
        chan.send_many(msgs)
        got = []
        while len(got) < len(msgs):
            got.extend(reader._decode_ready())
        assert [m.seq for m in got] == list(range(len(msgs)))
        for a, b in zip(msgs, got):
            _msg_equal(a, b)
        T.release_msgs(got)
        # EOF closes the zero-copy stream like the pickle one
        chan.close()
        assert reader.read_msgs() is None
        assert reader.closed
        got.clear()
    finally:
        _close_zero_copy(ring, bell)


def test_zero_copy_views_alias_ring_until_released():
    """Raw frames decode as views INTO the ring; the shared head cursor
    holds at the pinned frame and only advances once every message from it
    is released — that is the whole zero-copy contract."""
    ring, codec, reader, chan, bell = _mk_zero_copy()
    try:
        rows = np.arange(5, dtype=np.int64)
        chan.send_many([M.UpdateMsg(i, 0, 0, 0, "k", rows,
                                    np.full((5, 2), float(i)))
                        for i in range(3)])
        got = reader._decode_ready()
        assert len(got) == 3
        mem = _ring_mem(reader)
        for m in got:
            assert np.shares_memory(m.rows, mem)
            assert np.shares_memory(m.delta, mem)
        del m
        assert reader.pinned_frames() == 1
        assert ring._head() == 0                   # nothing released yet
        T.release_msgs(got[:2])
        assert ring._head() == 0                   # frame still partly pinned
        T.release_msg(got[2])
        assert reader.pinned_frames() == 0
        assert ring._head() == ring._tail()        # fully drained
        got.clear()
        del mem
    finally:
        _close_zero_copy(ring, bell)


def test_materialize_unpins_and_owns():
    """materialize_msg copies the arrays out of the ring (no aliasing — the
    use-after-advance guard) and drops the pin so the head can advance."""
    ring, codec, reader, chan, bell = _mk_zero_copy()
    try:
        rows = np.arange(4, dtype=np.int64)
        delta = np.ones((4, 3)) * 7.0
        chan.send(M.UpdateMsg(0, 0, 0, 0, "k", rows, delta))
        (m,) = reader._decode_ready()
        mem = _ring_mem(reader)
        assert np.shares_memory(m.delta, mem)
        T.materialize_msg(m)
        assert not np.shares_memory(m.rows, mem)
        assert not np.shares_memory(m.delta, mem)
        assert m._frame is None
        np.testing.assert_array_equal(m.delta, delta)
        assert reader.pinned_frames() == 0
        assert ring._head() == ring._tail()
        # the owned copy survives the producer overwriting the ring bytes
        chan.send(M.UpdateMsg(1, 0, 0, 0, "k", rows, delta * -1))
        (m2,) = reader._decode_ready()
        np.testing.assert_array_equal(m.delta, delta)
        T.release_msg(m2)
        del m2, mem
    finally:
        _close_zero_copy(ring, bell)


def test_zero_copy_frame_straddling_wraparound_copies_out():
    """A raw frame that straddles the ring wrap point cannot be viewed
    contiguously: it must decode from an owned copy (no pin, no aliasing)
    and the stream must stay intact across the wrap."""
    rows = np.arange(3, dtype=np.int64)

    def msg(i):
        return M.UpdateMsg(i, 0, 0, 0, "k", rows, np.full((3, 1), float(i)))

    codec = T.RowCodec(["k"])
    one = sum(len(p) if isinstance(p, bytes) else p.nbytes
              for p in codec._pack_raw([msg(0)])) + 4
    cap = int(one * 2.5)                # third frame is forced to straddle
    ring, codec, reader, chan, bell = _mk_zero_copy(cap=cap, keys=("k",))
    try:
        mem = _ring_mem(reader)
        straddled = 0
        for i in range(8):
            chan.send(msg(i))
            (m,) = reader._decode_ready()
            assert m.uid == i
            np.testing.assert_array_equal(m.delta, np.full((3, 1), float(i)))
            body = (m.seq * one + 4) % cap if False else None  # doc only
            if np.shares_memory(m.delta, mem):
                T.release_msg(m)
            else:
                straddled += 1
                assert getattr(m, "_frame", None) is None   # owned, unpinned
            assert reader.pinned_frames() == 0
            assert ring._head() == ring._tail()
            del m
        assert straddled > 0            # the wrap path actually ran
        del mem
    finally:
        _close_zero_copy(ring, bell)


def test_doorbell_batched_per_flush_but_rings_every_frame_on_split():
    """The common single-frame flush gets exactly one doorbell wake; a
    batch the codec splits rings once per frame — every published frame
    must be belled before the next write could block on ring space, or a
    parked reader never drains it and the producer spins forever."""
    frames, bells = [], []
    codec = T.RowCodec(["k"])
    rows = np.arange(8, dtype=np.int64)
    msgs = [M.UpdateMsg(i, 0, 0, 0, "k", rows, np.ones((8, 8)))
            for i in range(16)]
    one = codec.raw_size(msgs[0])
    chan = T.WireChannel("c", frames.append, max_frame=2 * one + 64,
                         codec=codec, on_flush=lambda: bells.append(1))
    chan.send_many([msgs[0]])
    assert len(frames) == 1 and len(bells) == 1   # single frame: one bell
    chan.send_many(msgs)
    assert len(frames) > 5              # split into several raw frames...
    assert len(bells) == len(frames)    # ...each belled (none strandable)
    n = len(frames)
    chan.close()                        # EOF frame + its wake so the reader
    assert len(frames) == n + 1         # can see the stream end and exit
    assert len(bells) == len(frames)


def test_multi_frame_batch_larger_than_ring_does_not_deadlock():
    """Deadlock regression: a send_many batch whose frames total more than
    the ring's free capacity must complete against a reader parked on the
    doorbell.  Before the per-frame bell, the producer published early
    frames un-belled and then spun for space while the reader slept in
    os.read — head never advanced and the run hung until the deadline."""
    cap = 1 << 13                          # 8 KiB ring
    ring = T.ShmRing.create(cap)
    codec = T.RowCodec(["k"])
    bell = os.pipe()
    stop = threading.Event()
    reader = T.RingViewReader(ring, codec, bell[0], stop)
    deadline = time.monotonic() + 20       # regression fails loudly, not ∞
    chan = T.WireChannel("zc", T.ring_parts_writer(ring, deadline),
                         max_frame=cap // 4, codec=codec,
                         on_flush=lambda: T.ShmEdge.ring_bell(bell[1]))
    inbox: "queue.Queue" = queue.Queue()
    errs: list = []
    t = T.start_view_reader("rx", reader, inbox, errs.append)
    try:
        rows = np.arange(8, dtype=np.int64)
        msgs = [M.UpdateMsg(i, 0, 0, 0, "k", rows, np.ones((8, 8)) * i)
                for i in range(24)]        # ~15 KiB of raw frames > cap
        sender = threading.Thread(target=chan.send_many, args=(msgs,))
        sender.start()
        got = []
        while len(got) < len(msgs):
            m = inbox.get(timeout=15)      # hangs here before the fix
            T.materialize_msg(m)
            got.append(m)
        sender.join(timeout=15)
        assert not sender.is_alive()
        assert errs == []
        assert [m.seq for m in got] == list(range(len(msgs)))
        for i, m in enumerate(got):
            assert np.array_equal(m.delta, np.ones((8, 8)) * i)
        chan.close()
        assert t.join(timeout=10) is None and not t.is_alive()
        got.clear()
    finally:
        stop.set()
        T.ShmEdge.ring_bell(bell[1])
        _close_zero_copy(ring, bell)


def test_use_after_advance_guard_through_shard_apply():
    """Drive view-backed messages through a real ServerShard batch: after
    _handle_batch returns, every frame must be released (head advanced) and
    nothing the shard retained may alias ring memory."""
    from repro.core import policies
    from repro.runtime import PSRuntime, RuntimeConfig

    x0 = {"k": np.zeros((8, 2)), "k2": np.zeros((8, 2))}
    rt = PSRuntime(RuntimeConfig(2, policies.vap(1e6), x0, n_shards=1))
    shard = rt.shards[0]
    ring, codec, reader, chan, bell = _mk_zero_copy()
    try:
        rows = np.arange(4, dtype=np.int64)
        batch = [M.UpdateMsg(i, 0, 0, 0, "k", rows, np.ones((4, 2)))
                 for i in range(4)]
        batch += [M.UpdateMsg(4 + i, 0, 0, 0, "k2", rows, np.ones((4, 2)))
                  for i in range(2)]
        chan.send_many(batch)
        got = []
        while len(got) < len(batch):
            got.extend(reader._decode_ready())
        assert all(getattr(m, "_frame", None) is not None for m in got)
        assert shard._handle_batch(got) is False     # no shutdown sentinel
        assert rt.stats.violations == []
        # every pin dropped: the read cursor is free to advance
        assert reader.pinned_frames() == 0
        assert ring._head() == ring._tail()
        # ...and whatever the shard retained past the batch (pending VAP
        # deliveries, queued updates, held msgs) owns its arrays
        mem = _ring_mem(reader)
        retained = [m for m, _ in shard.pending.values()]
        retained += [m for q in shard.queued.values() for m in q]
        retained += list(shard._held)
        assert retained, "expected the VAP path to retain deliveries"
        for m in retained:
            assert not np.shares_memory(m.rows, mem)
            assert not np.shares_memory(m.delta, mem)
            assert getattr(m, "_frame", None) is None
        got.clear()
        del mem
    finally:
        _close_zero_copy(ring, bell)


def test_tcpconn_probes_ioctl_once_and_tracks_live_sndbuf():
    """room() must not re-import fcntl/termios per call (the probe happens
    once at connection setup), but it must read the LIVE SO_SNDBUF each
    time — Linux autotunes the send buffer upward when it was never set
    explicitly, and a stale cached size would under-report room() and
    refuse sends that fit."""
    import builtins
    import socket

    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    cli = socket.create_connection(srv.getsockname())
    peer, _ = srv.accept()
    try:
        conn = T.TcpConn(cli)
        real_import = builtins.__import__

        def poisoned(name, *a, **kw):
            if name in ("fcntl", "termios"):
                raise AssertionError(f"room() re-imported {name}")
            return real_import(name, *a, **kw)

        builtins.__import__ = poisoned
        try:
            r1 = conn.room()
            r2 = conn.room()
        finally:
            builtins.__import__ = real_import
        assert r1 >= 0 and r2 >= 0
        if conn._ioctl is not None:
            sndbuf = cli.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF)
            assert r1 == sndbuf            # nothing queued yet
            # growing the kernel buffer must be visible to the next room()
            # call: room() tracks the live getsockopt reading, not a
            # setup-time cache
            cli.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 2 * sndbuf)
            new = cli.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF)
            assert conn.room() == new
        # degraded fallback: no ioctl -> "unknown" room + select probe
        conn._ioctl = None
        assert conn.room() == 1 << 62
        assert conn.try_write(b"ping")
        assert peer.recv(4) == b"ping"
    finally:
        for s in (cli, peer, srv):
            s.close()
