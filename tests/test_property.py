"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import AsyncPS, NetworkModel, controller, policies, theory

SET = dict(deadline=None, max_examples=25)


# ---------------------------------------------------------------------------
# Controller invariants
# ---------------------------------------------------------------------------


@given(vthr=st.floats(0.01, 10), acc=st.floats(-5, 5), delta=st.floats(-5, 5))
@settings(**SET)
def test_value_gate_never_lets_nonzero_accum_exceed(vthr, acc, delta):
    p = policies.vap(vthr)
    ok, _ = controller.value_gate(p, np.array([acc]), np.array([delta]))
    if ok and abs(acc) > 1e-12:
        assert abs(acc + delta) <= vthr + 1e-9


@given(vthr=st.floats(0.01, 10), delta=st.floats(-20, 20))
@settings(**SET)
def test_value_gate_always_admits_from_zero(vthr, delta):
    """A worker with an empty accumulator can always make progress — the
    liveness half of the max(u, v_thr) bound."""
    p = policies.vap(vthr)
    ok, _ = controller.value_gate(p, np.zeros(1), np.array([delta]))
    assert ok


@given(vthr=st.floats(0.01, 10), acc=st.floats(0, 5), delta=st.floats(0.0, 5))
@settings(**SET)
def test_elastic_gate_never_lets_nonzero_accum_exceed(vthr, acc, delta):
    """If elastic_gate admits onto a non-trivial accumulator, the resulting
    unsynced norm stays within the configured bound B."""
    p = policies.elastic(vthr)
    if controller.elastic_gate(p, acc, acc + delta) and acc > 1e-12:
        assert acc + delta <= vthr + 1e-9


@given(vthr=st.floats(0.01, 10), norm=st.floats(0, 50))
@settings(**SET)
def test_elastic_gate_always_admits_from_zero(vthr, norm):
    """An empty accumulator always admits — the liveness half of the
    max(max‖u‖, B) unsynced-norm bound."""
    p = policies.elastic(vthr)
    assert controller.elastic_gate(p, 0.0, norm)


@given(s=st.integers(0, 5), clock=st.integers(0, 20),
       fr=st.lists(st.integers(-1, 20), min_size=1, max_size=6))
@settings(**SET)
def test_clock_gate_monotone_in_frontier(s, clock, fr):
    """If the gate passes with some frontier, it passes with any larger one."""
    p = policies.cap(s)
    fr = np.asarray(fr)
    if controller.clock_gate(p, clock, fr):
        assert controller.clock_gate(p, clock, fr + 1)


@given(s=st.integers(0, 5), clock=st.integers(0, 20),
       fr=st.lists(st.integers(-1, 20), min_size=1, max_size=6))
@settings(**SET)
def test_clock_gate_essp_equals_ssp(s, clock, fr):
    """ESSP keeps SSP's read gate — eager push shrinks *observed* staleness
    but the worst-case admission window is identical."""
    fr = np.asarray(fr)
    assert (controller.clock_gate(policies.essp(s), clock, fr)
            == controller.clock_gate(policies.ssp(s), clock, fr))


@given(u=st.floats(0, 5), vthr=st.floats(0.01, 5), P=st.integers(2, 64))
@settings(**SET)
def test_strong_bound_tighter_than_weak_for_P_ge_2(u, vthr, P):
    assert (theory.strong_vap_divergence_bound(u, vthr)
            <= theory.weak_vap_divergence_bound(u, vthr, P) + 1e-12)


@given(T=st.integers(1, 10_000), F=st.floats(0.1, 10), L=st.floats(0.1, 10),
       v=st.floats(0.01, 1), P=st.integers(1, 64))
@settings(**SET)
def test_regret_bound_positive_and_sqrtT(T, F, L, v, P):
    b1 = theory.theorem1_regret_bound(T, F, L, v, P)
    b4 = theory.theorem1_regret_bound(4 * T, F, L, v, P)
    assert b1 > 0
    assert abs(b4 / b1 - 2.0) < 1e-6        # scales exactly as sqrt(T)


# ---------------------------------------------------------------------------
# Simulator: random configurations never violate the paper's bounds
# ---------------------------------------------------------------------------


@given(
    P=st.integers(2, 6),
    kind=st.sampled_from(["bsp", "ssp", "cap", "essp", "vap", "cvap",
                          "elastic"]),
    s=st.integers(0, 3),
    vthr=st.floats(0.05, 1.0),
    strong=st.booleans(),
    delay=st.floats(0.01, 1.0),
    seed=st.integers(0, 10_000),
)
@settings(deadline=None, max_examples=15)
def test_simulator_invariants_random(P, kind, s, vthr, strong, delay, seed):
    if kind == "bsp":
        pol = policies.bsp()
    elif kind == "ssp":
        pol = policies.ssp(s)
    elif kind == "cap":
        pol = policies.cap(s)
    elif kind == "essp":
        pol = policies.essp(s)
    elif kind == "vap":
        pol = policies.vap(vthr, strong=strong)
    elif kind == "cvap":
        pol = policies.cvap(s, vthr, strong=strong)
    else:
        pol = policies.elastic(vthr)
    rng = np.random.default_rng(seed)

    def fn(w, clock, view, r):
        x = view.get("x")
        return {"x": -0.1 * (x - w) + r.normal(0, 0.1, 2)}

    ps = AsyncPS(P, pol, {"x": np.zeros(2)},
                 network=NetworkModel(base_delay=delay, jitter=delay / 2,
                                      seed=seed),
                 seed=seed)
    stats = ps.run(fn, 8, divergence_every=1.0)
    assert stats.violations == []
    if pol.clock_bounded:
        assert stats.max_observed_staleness <= pol.staleness
    if pol.value_bounded:
        bound = max(stats.max_update_mag, pol.value_bound)
        assert stats.max_unsynced_mag <= bound + 1e-9
        if pol.strong:
            assert stats.max_divergence <= theory.strong_vap_divergence_bound(
                stats.max_update_mag, pol.value_bound) + 1e-9
    if pol.norm_bounded:
        nb = controller.elastic_unsynced_bound(pol, stats.max_update_norm)
        assert stats.max_unsynced_norm <= nb + 1e-9


# ---------------------------------------------------------------------------
# Kernel refs: algebraic properties on random inputs
# ---------------------------------------------------------------------------


@given(b=st.integers(1, 3), l=st.integers(1, 40), w=st.integers(1, 20),
       seed=st.integers(0, 1000))
@settings(**SET)
def test_linear_recurrence_decomposes(b, l, w, seed):
    """h(a, b1 + b2) = h(a, b1) + h(a, b2) — linearity in the input."""
    from repro.kernels.rglru_scan import ref as rr
    rng = np.random.default_rng(seed)
    a = np.asarray(rng.uniform(0.5, 0.99, (b, l, w)), np.float64)
    b1 = np.asarray(rng.normal(0, 1, (b, l, w)), np.float64)
    b2 = np.asarray(rng.normal(0, 1, (b, l, w)), np.float64)
    import jax.numpy as jnp
    h12, _ = rr.linear_recurrence(jnp.asarray(a), jnp.asarray(b1 + b2))
    ha, _ = rr.linear_recurrence(jnp.asarray(a), jnp.asarray(b1))
    hb, _ = rr.linear_recurrence(jnp.asarray(a), jnp.asarray(b2))
    np.testing.assert_allclose(np.asarray(h12), np.asarray(ha) + np.asarray(hb),
                               atol=1e-4)


@given(n=st.integers(1, 5000), seed=st.integers(0, 1000))
@settings(**SET)
def test_vap_accum_identity(n, seed):
    """vap_accum with u=0 is the identity and reports ‖δ‖∞ exactly."""
    import jax.numpy as jnp
    from repro.kernels.vap_accum import ref as vr
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    d = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    p2, d2, m = vr.vap_accum(p, d, jnp.zeros(n, jnp.float32))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))
    assert np.isclose(float(m), float(np.max(np.abs(np.asarray(d)))))
