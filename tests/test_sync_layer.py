"""Tests of the SPMD consistency-sync layer (single-replica semantics here;
multi-device behaviour in test_distributed.py via subprocess)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies
from repro.core.sync import (apply_and_sync, elastic_invariant_ok, force_sync,
                             init_sync_state, sync_trigger, tree_l2_norm,
                             tree_max_abs, vap_invariant_ok)


def _params():
    return {"w": jnp.zeros(4), "b": jnp.zeros(2)}


@functools.partial(jax.jit, static_argnames=("policy",))
def _step(p, s, u, policy):
    return apply_and_sync(p, s, u, policy, dp_axes=())


def test_bsp_syncs_every_step():
    p, s = _params(), init_sync_state(_params())
    for _ in range(3):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                             policies.bsp())
        assert bool(synced)
        assert float(tree_max_abs(s.delta)) == 0.0


def test_cap_clock_period():
    p, s = _params(), init_sync_state(_params())
    pattern = []
    for _ in range(9):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .01, "b": jnp.ones(2) * .01},
                             policies.cap(2))
        pattern.append(bool(synced))
    assert pattern == [False, False, True] * 3


def test_vap_value_trigger():
    pol = policies.vap(0.25)
    p, s = _params(), init_sync_state(_params())
    seen = []
    for _ in range(6):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                             pol)
        seen.append(bool(synced))
        assert bool(vap_invariant_ok(pol, s))
    # 0.1 accumulates: .1 .2 .3>.25 -> sync at step 3, then period 3
    assert seen == [False, False, True, False, False, True]


def test_cvap_first_trigger_wins():
    pol = policies.cvap(5, 0.15)
    p, s = _params(), init_sync_state(_params())
    seen = []
    for _ in range(4):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.zeros(2)}, pol)
        seen.append(bool(synced))
    assert seen == [False, True, False, True]    # value fires before clock


def test_read_my_writes_params_updated_immediately():
    p, s = _params(), init_sync_state(_params())
    pol = policies.cap(5)
    p, s, synced = _step(p, s, {"w": jnp.ones(4), "b": jnp.ones(2)}, pol)
    assert not bool(synced)
    np.testing.assert_allclose(np.asarray(p["w"]), 1.0)   # visible pre-sync


def test_force_sync_resets():
    p, s = _params(), init_sync_state(_params())
    p, s, _ = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                    policies.cap(10))
    p2, s2 = force_sync(p, s, ())
    assert float(tree_max_abs(s2.delta)) == 0.0
    assert int(s2.steps_since_sync) == 0
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(p["w"]))


def test_oversized_update_admitted_bound_tracks_u():
    """A single |u| > v_thr is applied (max(u, v_thr) bound semantics)."""
    pol = policies.vap(0.1)
    p, s = _params(), init_sync_state(_params())
    p, s, synced = _step(p, s, {"w": jnp.ones(4) * 5.0, "b": jnp.zeros(2)}, pol)
    assert bool(synced)            # sync epoch triggers right away
    assert bool(vap_invariant_ok(pol, s))
    assert float(s.max_update_mag) == pytest.approx(5.0)


def test_essp_trigger_equals_ssp():
    """Under lockstep SPMD ESSP collapses to SSP: same clock trigger, step
    for step."""
    p1, s1 = _params(), init_sync_state(_params())
    p2, s2 = _params(), init_sync_state(_params())
    for _ in range(6):
        u = {"w": jnp.ones(4) * .01, "b": jnp.ones(2) * .01}
        p1, s1, t1 = _step(p1, s1, u, policies.ssp(2))
        p2, s2, t2 = _step(p2, s2, u, policies.essp(2))
        assert bool(t1) == bool(t2)
        np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))


def test_elastic_norm_trigger():
    """Elastic syncs when the accumulated drift's L2 norm would pass B, and
    the whole-accumulator invariant holds at every step."""
    pol = policies.elastic(0.25)
    p, s = _params(), init_sync_state(_params())
    seen = []
    for _ in range(6):
        # per-step delta norm = sqrt(6 * 0.1^2) ~ 0.245 <= B; two steps pass
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                             pol)
        seen.append(bool(synced))
        assert bool(elastic_invariant_ok(pol, s))
    assert seen == [False, True, False, True, False, True]


def test_elastic_oversized_update_bound_tracks_norm():
    """A single update with L2 norm > B is admitted; the invariant bound
    widens to max(max‖u‖₂, B) exactly as in the PS layers."""
    pol = policies.elastic(0.1)
    p, s = _params(), init_sync_state(_params())
    u = {"w": jnp.ones(4) * 5.0, "b": jnp.zeros(2)}
    p, s, synced = _step(p, s, u, pol)
    assert bool(synced)
    assert bool(elastic_invariant_ok(pol, s))
    assert float(s.max_update_l2) == pytest.approx(10.0)   # sqrt(4*25)


def test_tree_l2_norm():
    t = {"a": jnp.asarray([3.0, 0.0]), "b": jnp.asarray([[0.0, 4.0]])}
    assert float(tree_l2_norm(t)) == pytest.approx(5.0)


def test_trigger_uniform_with_trigger_axes_noop_single():
    pol = policies.vap(0.5)
    s = init_sync_state(_params())
    d = {"w": jnp.ones(4) * 0.6, "b": jnp.zeros(2)}
    t = sync_trigger(pol, s, d, dp_axes=(), trigger_axes=())
    assert bool(t)


# ---------------------------------------------------------------------------
# hierarchical + compressed paths on a real 2-pod mesh (8 fake devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hierarchical_pod_pending_no_double_count(devices8):
    """Integer per-replica updates on a (pod=2, data=4) mesh with
    hierarchy=3: every intermediate state must match the closed form
    'own-pod updates every epoch + peer-pod updates only at cross epochs' —
    any double counting of ``pod_pending`` across cross-pod epochs (or a
    missed reset) breaks the exact equality."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch import mesh as mesh_lib
from repro.core import policies, sync

mesh = mesh_lib.make_mesh((2, 4), ("pod", "data"))
R, HIER, T = 8, 3, 9
pol = policies.bsp()                     # sync epoch every step
x0 = {"w": jnp.zeros(4, jnp.float32)}

def local(p, s, u):
    sq = lambda t: jax.tree.map(lambda x: x[0], t)
    ex = lambda t: jax.tree.map(lambda x: x[None], t)
    p2, s2, _ = sync.apply_and_sync(sq(p), sq(s), sq(u), pol,
                                    dp_axes=("pod", "data"),
                                    hierarchy=HIER, pod_axis="pod")
    return ex(p2), ex(s2)

stack = lambda t: jax.tree.map(lambda x: jnp.stack([x] * R), t)
spec = lambda t: jax.tree.map(
    lambda x: P(("pod", "data"), *([None] * (x.ndim - 1))), t)
params = stack(x0)
state = stack(sync.init_sync_state(x0, hierarchy=HIER))
fn = jax.jit(mesh_lib.shard_map(
    local, mesh=mesh,
    in_specs=(spec(params), spec(state), spec(params)),
    out_specs=(spec(params), spec(state))))

S_pod = [1.0 + 2 + 3 + 4, 5.0 + 6 + 7 + 8]   # per-step update mass per pod
for t in range(T):
    u = {"w": jnp.stack([jnp.full(4, float(r + 1), jnp.float32)
                         for r in range(R)])}
    params, state = fn(params, state, u)
    w = np.asarray(params["w"])              # (R, 4)
    crossed = 3 * ((t + 1) // HIER)          # epochs whose pend crossed pods
    for r in range(R):
        pod = r // 4
        want = S_pod[pod] * (t + 1) + S_pod[1 - pod] * crossed
        assert np.all(w[r] == want), (t, r, w[r], want)
    pend = np.asarray(state["pod_pending"]  # noqa: F821
                      if isinstance(state, dict) else state.pod_pending["w"])
    if (t + 1) % HIER == 0:
        assert np.all(pend == 0.0), (t, pend)   # reset after crossing
        assert np.all(w == w[0]), t             # pods fully agree
print("HIER_OK")
""")
    assert "HIER_OK" in out


@pytest.mark.slow
def test_bf16_error_feedback_keeps_drift_bounded(devices8):
    """compress='bf16' with fp32 error-feedback residual: after many syncs of
    bf16-unfriendly deltas the replicas must track the exact fp64 sum to
    ~one quantization step — not the T-times-larger drift a residual-free
    quantizer accumulates."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch import mesh as mesh_lib
from repro.core import policies, sync

mesh = mesh_lib.make_mesh((2, 4), ("pod", "data"))
R, T = 8, 40
pol = policies.bsp()
x0 = {"w": jnp.zeros(4, jnp.float32)}

def local(p, s, u):
    sq = lambda t: jax.tree.map(lambda x: x[0], t)
    ex = lambda t: jax.tree.map(lambda x: x[None], t)
    p2, s2, _ = sync.apply_and_sync(sq(p), sq(s), sq(u), pol,
                                    dp_axes=("pod", "data"),
                                    compress="bf16")
    return ex(p2), ex(s2)

stack = lambda t: jax.tree.map(lambda x: jnp.stack([x] * R), t)
spec = lambda t: jax.tree.map(
    lambda x: P(("pod", "data"), *([None] * (x.ndim - 1))), t)
params = stack(x0)
state = stack(sync.init_sync_state(x0, compress="bf16"))
fn = jax.jit(mesh_lib.shard_map(
    local, mesh=mesh,
    in_specs=(spec(params), spec(state), spec(params)),
    out_specs=(spec(params), spec(state))))

exact = np.zeros(4, dtype=np.float64)
max_res = 0.0
for t in range(T):
    vals = [0.001 * (r + 1) + 0.0001 * t for r in range(R)]  # bf16-unfriendly
    u = {"w": jnp.stack([jnp.full(4, v, jnp.float32) for v in vals])}
    exact += np.float64(np.asarray(u["w"])).sum(axis=0)
    params, state = fn(params, state, u)
    max_res = max(max_res, float(np.max(np.abs(np.asarray(state.residual["w"])))))

w = np.asarray(params["w"], dtype=np.float64)
drift = float(np.max(np.abs(w - exact[None, :])))
assert max_res > 0.0, "error-feedback residual never engaged"
# one bf16 quantization step of the per-sync send, NOT T of them
naive = T * 8 * 0.004 * 2 ** -8       # what residual-free drift would allow
assert drift < 2e-3 < naive * 10, (drift, naive)
# replicas agree up to their *current* residuals (each holds back its own
# not-yet-sent quantization error), never more
spread = float(np.max(np.abs(w - w[0])))
assert spread <= 2 * max_res + 1e-7, (spread, max_res)
print("BF16_OK", drift, max_res)
""")
    assert "BF16_OK" in out
