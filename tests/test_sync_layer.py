"""Tests of the SPMD consistency-sync layer (single-replica semantics here;
multi-device behaviour in test_distributed.py via subprocess)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies
from repro.core.sync import (apply_and_sync, force_sync, init_sync_state,
                             sync_trigger, tree_max_abs, vap_invariant_ok)


def _params():
    return {"w": jnp.zeros(4), "b": jnp.zeros(2)}


@functools.partial(jax.jit, static_argnames=("policy",))
def _step(p, s, u, policy):
    return apply_and_sync(p, s, u, policy, dp_axes=())


def test_bsp_syncs_every_step():
    p, s = _params(), init_sync_state(_params())
    for _ in range(3):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                             policies.bsp())
        assert bool(synced)
        assert float(tree_max_abs(s.delta)) == 0.0


def test_cap_clock_period():
    p, s = _params(), init_sync_state(_params())
    pattern = []
    for _ in range(9):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .01, "b": jnp.ones(2) * .01},
                             policies.cap(2))
        pattern.append(bool(synced))
    assert pattern == [False, False, True] * 3


def test_vap_value_trigger():
    pol = policies.vap(0.25)
    p, s = _params(), init_sync_state(_params())
    seen = []
    for _ in range(6):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                             pol)
        seen.append(bool(synced))
        assert bool(vap_invariant_ok(pol, s))
    # 0.1 accumulates: .1 .2 .3>.25 -> sync at step 3, then period 3
    assert seen == [False, False, True, False, False, True]


def test_cvap_first_trigger_wins():
    pol = policies.cvap(5, 0.15)
    p, s = _params(), init_sync_state(_params())
    seen = []
    for _ in range(4):
        p, s, synced = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.zeros(2)}, pol)
        seen.append(bool(synced))
    assert seen == [False, True, False, True]    # value fires before clock


def test_read_my_writes_params_updated_immediately():
    p, s = _params(), init_sync_state(_params())
    pol = policies.cap(5)
    p, s, synced = _step(p, s, {"w": jnp.ones(4), "b": jnp.ones(2)}, pol)
    assert not bool(synced)
    np.testing.assert_allclose(np.asarray(p["w"]), 1.0)   # visible pre-sync


def test_force_sync_resets():
    p, s = _params(), init_sync_state(_params())
    p, s, _ = _step(p, s, {"w": jnp.ones(4) * .1, "b": jnp.ones(2) * .1},
                    policies.cap(10))
    p2, s2 = force_sync(p, s, ())
    assert float(tree_max_abs(s2.delta)) == 0.0
    assert int(s2.steps_since_sync) == 0
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(p["w"]))


def test_oversized_update_admitted_bound_tracks_u():
    """A single |u| > v_thr is applied (max(u, v_thr) bound semantics)."""
    pol = policies.vap(0.1)
    p, s = _params(), init_sync_state(_params())
    p, s, synced = _step(p, s, {"w": jnp.ones(4) * 5.0, "b": jnp.zeros(2)}, pol)
    assert bool(synced)            # sync epoch triggers right away
    assert bool(vap_invariant_ok(pol, s))
    assert float(s.max_update_mag) == pytest.approx(5.0)


def test_trigger_uniform_with_trigger_axes_noop_single():
    pol = policies.vap(0.5)
    s = init_sync_state(_params())
    d = {"w": jnp.ones(4) * 0.6, "b": jnp.zeros(2)}
    t = sync_trigger(pol, s, d, dp_axes=(), trigger_axes=())
    assert bool(t)
