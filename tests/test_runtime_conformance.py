"""Differential conformance suite: three layers, one spec.

The event-driven simulator (:mod:`repro.core.server`) is the executable
specification of the paper's consistency models; the threaded runtime
(:mod:`repro.runtime`) and the SPMD sync layer (:mod:`repro.core.sync`) are
implementations.  This suite makes them mutually checking:

  (a) deterministic update schedules (integer deltas that depend only on
      (worker, clock), so float accumulation is exact and order-independent):
      the quiesced runtime's shard tables and every process cache must equal
      the simulator's final views element-wise, for every policy;
  (b) free-running 4-thread stress (>=200 clocks): the runtime's internal
      mid-run checks — SSP clock bound at every period start, element-wise
      VAP accumulator bound <= max(u, v_thr) after every Inc, per-channel
      FIFO, eventual consistency — must record zero violations for SSP(3),
      VAP, and CVAP;
  (c) the paper's LDA workload under BSP: log-likelihood trajectories from
      period-start snapshots are element-wise identical across simulator
      (barrier-strength network), threaded runtime (barrier_reads), and the
      SPMD sync layer (integer count deltas are exact in every dtype used).
"""
import sys

import numpy as np
import pytest

from repro.core import AsyncPS, NetworkModel, policies
from repro.runtime import PSRuntime, RuntimeConfig

# ---------------------------------------------------------------------------
# (a) deterministic schedules: runtime final state == simulator final state
# ---------------------------------------------------------------------------


def _x0():
    return {"a": np.arange(32, dtype=float).reshape(8, 4) / 2.0,
            "b": np.ones(5)}


def _sched_fn(seed):
    """Integer deltas, a pure function of (worker, clock) — the deterministic
    schedule: the update *set* is interleaving-independent, so both backends
    must converge to exactly x0 + sum(deltas)."""
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-3, 4, size=(8, 4)).astype(float),
                "b": r.integers(-3, 4, size=5).astype(float)}
    return fn


_POLICIES = [
    ("bsp", policies.bsp()),
    ("ssp2", policies.ssp(2)),
    ("cap1", policies.cap(1)),
    ("essp2", policies.essp(2)),
    ("vap", policies.vap(4.5)),
    ("cvap_strong", policies.cvap(2, 4.5, strong=True)),
    ("elastic", policies.elastic(12.0)),
]


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_runtime_final_state_equals_simulator(polname, pol, seed):
    fn = _sched_fn(seed)
    sim = AsyncPS(4, pol, _x0(), threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    st_sim = sim.run(fn, 12)
    rt = PSRuntime(RuntimeConfig(4, pol, _x0(), n_shards=2, threads_per_process=2,
                   seed=seed))
    st_rt = rt.run(fn, 12, timeout=90)

    assert st_sim.violations == [], st_sim.violations
    assert st_rt.violations == [], st_rt.violations
    assert st_sim.n_updates == st_rt.n_updates
    for k, ref in sim.views[0].items():
        shape = ref.shape
        # master copy on the hash-partitioned shard tables
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(shape), ref,
            err_msg=f"{polname} seed={seed} master[{k}]")
        # every process cache converged to the same state (read-my-writes
        # and deliveries both landed, nothing lost or double-applied)
        for p in range(rt.n_proc):
            np.testing.assert_array_equal(
                rt.view(p)[k].reshape(shape), ref,
                err_msg=f"{polname} seed={seed} proc{p}[{k}]")


# ---------------------------------------------------------------------------
# (a') the same, with real OS processes over the wire transports
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
@pytest.mark.parametrize("transport", ["proc", "tcp"])
def test_runtime_final_state_equals_simulator_multiprocess(
        polname, pol, transport):
    """The multi-process runtime (forked clients, shared-memory rings or
    loopback sockets, batched multi-row frames) still refines the executable
    spec: quiesced master + every shipped client cache == simulator."""
    seed = 0
    fn = _sched_fn(seed)
    sim = AsyncPS(4, pol, _x0(), threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    st_sim = sim.run(fn, 12)
    rt = PSRuntime(RuntimeConfig(4, pol, _x0(), n_shards=2, threads_per_process=2,
                   seed=seed, transport=transport))
    st_rt = rt.run(fn, 12, timeout=90)

    assert st_sim.violations == [], st_sim.violations
    assert st_rt.violations == [], st_rt.violations
    assert st_sim.n_updates == st_rt.n_updates
    for k, ref in sim.views[0].items():
        shape = ref.shape
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(shape), ref,
            err_msg=f"{polname} {transport} master[{k}]")
        for p in range(rt.n_proc):
            np.testing.assert_array_equal(
                rt.view(p)[k].reshape(shape), ref,
                err_msg=f"{polname} {transport} proc{p}[{k}]")


# ---------------------------------------------------------------------------
# (b) randomized interleavings: bounds never violated mid-run
# ---------------------------------------------------------------------------


_STRESS = [
    ("ssp3", policies.ssp(3)),
    ("essp3", policies.essp(3)),
    ("vap", policies.vap(1.5)),
    ("cvap", policies.cvap(3, 1.5)),
    ("elastic", policies.elastic(5.0)),
]


@pytest.mark.parametrize("polname,pol", _STRESS, ids=[p[0] for p in _STRESS])
def test_stress_invariants_hold_mid_run(polname, pol):
    """4 real threads, 200 clocks, free interleaving.  The runtime checks the
    clock bound at every period start and the element-wise value bound after
    every Inc (check_invariants=True), recording violations as they happen —
    the assertion below is therefore over every intermediate state, not just
    the final one."""
    old = sys.getswitchinterval()
    sys.setswitchinterval(5e-4)    # more thread interleavings per clock
    try:
        def fn(w, clock, view, rng):
            return {"a": rng.normal(0.0, 0.6, size=(8, 4)),
                    "b": rng.normal(0.0, 0.6, size=5)}

        x0 = {"a": np.zeros((8, 4)), "b": np.zeros(5)}
        rt = PSRuntime(RuntimeConfig(4, pol, x0, n_shards=2, threads_per_process=2, seed=11))
        st = rt.run(fn, 200, timeout=110)
    finally:
        sys.setswitchinterval(old)

    assert st.violations == [], st.violations[:5]
    assert st.n_updates == 4 * 200 * 2
    if pol.clock_bounded:
        # the bound held...
        assert st.max_observed_staleness <= pol.staleness
        # ...and asynchrony actually happened (the check wasn't vacuous).
        # ESSP's eager boundary push may legitimately drive observed
        # staleness to zero, so the non-vacuity half is SSP-only.
        if not pol.server_push_on_boundary:
            assert st.max_observed_staleness > 0
    if pol.value_bounded:
        bound = max(st.max_update_mag, pol.value_bound)
        assert 0.0 < st.max_unsynced_mag <= bound + 1e-9
    if pol.norm_bounded:
        nb = max(st.max_update_norm, pol.value_bound)
        assert 0.0 < st.max_unsynced_norm <= nb + 1e-9


@pytest.mark.parametrize("polname,pol", _STRESS, ids=[p[0] for p in _STRESS])
def test_stress_invariants_hold_multiprocess(polname, pol):
    """Free multi-process interleaving: 2 forked client processes x 2 worker
    threads, no scheduler cooperation at all.  Each child checks the SSP
    clock bound at every period start and the element-wise VAP bound after
    every Inc; the parent merges and asserts zero violations."""
    def fn(w, clock, view, rng):
        return {"a": rng.normal(0.0, 0.6, size=(8, 4)),
                "b": rng.normal(0.0, 0.6, size=5)}

    x0 = {"a": np.zeros((8, 4)), "b": np.zeros(5)}
    rt = PSRuntime(RuntimeConfig(4, pol, x0, n_shards=2, threads_per_process=2, seed=11,
                   transport="proc"))
    st = rt.run(fn, 80, timeout=110)

    assert st.violations == [], st.violations[:5]
    assert st.n_updates == 4 * 80 * 2
    if pol.clock_bounded:
        assert st.max_observed_staleness <= pol.staleness
    if pol.value_bounded:
        bound = max(st.max_update_mag, pol.value_bound)
        assert 0.0 < st.max_unsynced_mag <= bound + 1e-9
    if pol.norm_bounded:
        nb = max(st.max_update_norm, pol.value_bound)
        assert 0.0 < st.max_unsynced_norm <= nb + 1e-9


def test_live_master_reads_multiprocess():
    """Serving against the live master shards while forked clients stream
    updates: reads are per-shard-locked and observe monotone progress."""
    def fn(w, clock, view, rng):
        return {"a": np.ones((8, 4))}

    x0 = {"a": np.zeros((8, 4))}
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(3), x0, n_shards=2,
                   threads_per_process=1, seed=0, transport="proc"))
    rt.start(fn, 50, timeout=90)
    seen = []
    while rt.running and len(seen) < 2000:
        v = rt.read("a")                  # live master read, per-shard locks
        assert v.shape == (8, 4)
        seen.append(float(v.sum()))
    stats = rt.wait()
    assert stats.violations == []
    assert seen == sorted(seen)
    assert float(rt.master_value("a").sum()) == 2 * 50 * 32


# ---------------------------------------------------------------------------
# (c) LDA under BSP: identical trajectories across all three layers
# ---------------------------------------------------------------------------


def test_lda_bsp_trajectories_match_across_layers():
    from repro.apps import lda
    from repro.data import synthetic_corpus

    corpus = synthetic_corpus(n_docs=12, vocab_size=24, n_topics=3,
                              doc_len=15, seed=1)
    kw = dict(n_topics=3, n_workers=3, n_clocks=4, seed=0)
    # simulator: delivery latency >> compute spread makes BSP a strict barrier
    lls_sim = lda.run_lda(
        corpus, policy=policies.bsp(), backend="sim",
        network=NetworkModel(base_delay=100.0, jitter=0.0, seed=0),
        snapshot_trajectory=True, **kw)
    # threaded runtime: barrier_reads stages fresher-than-guaranteed deliveries
    lls_rt = lda.run_lda(
        corpus, policy=policies.bsp(), backend="runtime", barrier_reads=True,
        threads_per_process=1, n_shards=2, snapshot_trajectory=True, **kw)
    # SPMD sync layer: BSP = delta all-reduce every step under vmap('data')
    lls_spmd = lda.run_lda_spmd(corpus, policy=policies.bsp(), **kw)

    assert len(lls_sim) == kw["n_clocks"]
    np.testing.assert_allclose(lls_rt, lls_sim, rtol=0, atol=1e-9)
    np.testing.assert_allclose(lls_spmd, lls_sim, rtol=0, atol=1e-9)
    # and the Gibbs chain is actually sampling (trajectory moves)
    assert lls_sim[-1] != lls_sim[0]


def test_lda_spmd_new_kinds_trajectories():
    """SPMD leg for the new kinds.  Under lockstep SPMD every replica steps
    together, so ESSP's eager server push has nothing extra to deliver and
    the trigger collapses to SSP's clock trigger — trajectories must match
    bitwise.  Elastic with a vanishing norm bound must sync on every step
    that moved anything, reproducing the BSP trajectory."""
    from repro.apps import lda
    from repro.data import synthetic_corpus

    corpus = synthetic_corpus(n_docs=12, vocab_size=24, n_topics=3,
                              doc_len=15, seed=1)
    kw = dict(n_topics=3, n_workers=3, n_clocks=4, seed=0)
    lls_ssp = lda.run_lda_spmd(corpus, policy=policies.ssp(1), **kw)
    lls_essp = lda.run_lda_spmd(corpus, policy=policies.essp(1), **kw)
    np.testing.assert_allclose(lls_essp, lls_ssp, rtol=0, atol=0)

    lls_bsp = lda.run_lda_spmd(corpus, policy=policies.bsp(), **kw)
    lls_el = lda.run_lda_spmd(corpus, policy=policies.elastic(1e-6), **kw)
    np.testing.assert_allclose(lls_el, lls_bsp, rtol=0, atol=1e-9)


def test_essp_observed_staleness_not_worse_than_ssp():
    """The point of ESSP (arXiv:1410.8043): at an equal configured bound the
    eager boundary push can only shrink the staleness workers actually
    observe.  Checked on the executable spec with a laggy network, where
    SSP reads genuinely run stale."""
    seed = 4
    fn = _sched_fn(seed)
    out = {}
    for name, pol in (("ssp", policies.ssp(3)), ("essp", policies.essp(3))):
        sim = AsyncPS(6, pol, _x0(), seed=seed, straggler={0: 2.0},
                      network=NetworkModel(base_delay=0.8, jitter=0.5,
                                           seed=seed))
        st = sim.run(fn, 20)
        assert st.violations == []
        out[name] = st.max_observed_staleness
    assert out["ssp"] > 0          # the comparison is not vacuous
    assert out["essp"] <= out["ssp"]


def test_lda_runtime_backend_trains():
    """LDA runs on the live runtime without conformance scaffolding and the
    log-likelihood rises (same bar as the simulator's system test)."""
    from repro.apps import lda
    from repro.data import synthetic_corpus

    corpus = synthetic_corpus(n_docs=12, vocab_size=30, n_topics=3,
                              doc_len=20, seed=0)
    lls, stats = lda.run_lda(corpus, n_topics=3, policy=policies.vap(5.0),
                             n_workers=4, n_clocks=6, seed=0,
                             backend="runtime", threads_per_process=2,
                             n_shards=2, collect_stats=True)
    assert stats.violations == []
    assert lls[-1] > lls[0], lls


# ---------------------------------------------------------------------------
# serving: live reads while update traffic is in flight
# ---------------------------------------------------------------------------


def test_live_reads_under_concurrent_updates():
    def fn(w, clock, view, rng):
        return {"a": np.ones((8, 4))}

    x0 = {"a": np.zeros((8, 4))}
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(3), x0, n_shards=2,
                   threads_per_process=1, seed=0))
    rt.start(fn, 50, timeout=60)
    seen = []
    while rt.running and len(seen) < 1000:
        v = rt.read("a")                  # a Get() against a live cache
        assert v.shape == (8, 4)
        seen.append(float(v.sum()))
    stats = rt.wait()
    assert stats.violations == []
    # reads observed monotone progress (updates are all +1s)
    assert seen == sorted(seen)
    assert float(rt.read("a").sum()) == 2 * 50 * 32


# ---------------------------------------------------------------------------
# (a'') zero-copy wire + PS kernel paths: same bitwise bar as (a)/(a')
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_runtime_final_state_with_ps_kernels(polname, pol):
    """ps_kernels=True swaps the apply (np.add.at -> kernels/ps_apply) and
    the flush ordering (Python sort -> kernels/topk_mag): the quiesced state
    must stay bitwise equal to the simulator for every policy."""
    seed = 0
    fn = _sched_fn(seed)
    sim = AsyncPS(4, pol, _x0(), threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    st_sim = sim.run(fn, 12)
    rt = PSRuntime(RuntimeConfig(4, pol, _x0(), n_shards=2, threads_per_process=2,
                   seed=seed, ps_kernels=True))
    st_rt = rt.run(fn, 12, timeout=90)

    assert st_sim.violations == [] and st_rt.violations == []
    assert st_sim.n_updates == st_rt.n_updates
    for k, ref in sim.views[0].items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"{polname} kernels master[{k}]")
        for p in range(rt.n_proc):
            np.testing.assert_array_equal(
                rt.view(p)[k].reshape(ref.shape), ref,
                err_msg=f"{polname} kernels proc{p}[{k}]")


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
@pytest.mark.parametrize("zero_copy", [True, False], ids=["zc", "pickle"])
def test_multiprocess_shm_zero_copy_and_kernels(polname, pol, zero_copy):
    """The tentpole configuration: forked clients over shm rings with the
    raw zero-copy wire (and its pickle-5 fallback), Pallas-pathway apply +
    ordering enabled — still refines the executable spec bitwise."""
    seed = 0
    fn = _sched_fn(seed)
    sim = AsyncPS(4, pol, _x0(), threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    st_sim = sim.run(fn, 12)
    rt = PSRuntime(RuntimeConfig(4, pol, _x0(), n_shards=2, threads_per_process=2,
                   seed=seed, transport="shm", zero_copy=zero_copy,
                   ps_kernels=True))
    st_rt = rt.run(fn, 12, timeout=90)

    assert st_sim.violations == [] and st_rt.violations == []
    assert st_sim.n_updates == st_rt.n_updates
    for k, ref in sim.views[0].items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"{polname} zc={zero_copy} master[{k}]")
        for p in range(rt.n_proc):
            np.testing.assert_array_equal(
                rt.view(p)[k].reshape(ref.shape), ref,
                err_msg=f"{polname} zc={zero_copy} proc{p}[{k}]")


def test_final_state_with_interpret_mode_pallas(monkeypatch):
    """REPRO_PALLAS=interpret runs the real kernel bodies (discharged on
    CPU): the sequential scatter-add replays np.add.at order, so even the
    interpreted kernels keep the final state bitwise equal to the simulator.
    Small config — interpret mode is slow."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    seed = 0
    fn = _sched_fn(seed)
    x0 = _x0()
    for pol in (policies.ssp(2), policies.vap(4.5)):
        sim = AsyncPS(2, pol, x0, threads_per_process=1, seed=seed,
                      network=NetworkModel(seed=seed))
        sim.run(fn, 4)
        rt = PSRuntime(RuntimeConfig(2, pol, x0, n_shards=1, threads_per_process=1,
                       seed=seed, ps_kernels=True))
        st = rt.run(fn, 4, timeout=90)
        assert st.violations == []
        for k, ref in sim.views[0].items():
            np.testing.assert_array_equal(
                rt.master_value(k).reshape(ref.shape), ref,
                err_msg=f"interpret master[{k}]")


# ---------------------------------------------------------------------------
# VAP sub-epsilon residuals: exact accounting, no snap-to-zero
# ---------------------------------------------------------------------------


def test_fully_delivered_subtracts_exactly_sub_epsilon():
    """Regression for the 1e-12 snap: with three sub-epsilon deltas in
    flight, acknowledging ONE must leave exactly two in the accumulator.
    The old code zeroed any residual below 1e-12, silently forgetting the
    other two in-flight deltas and diverging from the simulator's exact
    VAP accounting."""
    from repro.runtime import messages as M

    tiny = 2.0 ** -44                   # exact power of two, far below 1e-12
    x0 = {"a": np.zeros((4, 2))}
    rt = PSRuntime(RuntimeConfig(1, policies.vap(1.0), x0, n_shards=1))
    proc = rt.procs[0]
    rows = np.arange(2)
    acc = proc.unsynced[0]["a"]
    acc[rows] += 3 * tiny               # three tiny updates in flight
    proc._handle(M.FullyDelivered(0, 0, "a", rows,
                                  np.full((2, 2), tiny), 0))
    np.testing.assert_array_equal(acc[rows], np.full((2, 2), 2 * tiny))
    assert acc[0, 0] == 2 * tiny        # bitwise: NOT snapped to zero


@pytest.mark.parametrize("transport", ["queue", "proc"])
def test_vap_sub_epsilon_deltas_end_to_end(transport):
    """A whole VAP run whose every delta is a multiple of 2^-44: sums are
    exact at this scale, so the quiesced state must equal the simulator
    bitwise AND every accumulator must drain to exactly 0.0 — which only
    holds if each FullyDelivered subtracts exactly what was added."""
    tiny = 2.0 ** -44
    seed = 3

    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-3, 4, size=(8, 4)) * tiny,
                "b": r.integers(-3, 4, size=5) * tiny}

    x0 = {"a": np.zeros((8, 4)), "b": np.zeros(5)}
    pol = policies.vap(4.5 * tiny)
    sim = AsyncPS(4, pol, x0, threads_per_process=2, seed=seed,
                  network=NetworkModel(seed=seed))
    sim.run(fn, 10)
    kw = {} if transport == "queue" else {"transport": transport}
    rt = PSRuntime(RuntimeConfig(4, pol, x0, n_shards=2, threads_per_process=2,
                   seed=seed, **kw))
    st = rt.run(fn, 10, timeout=90)
    assert st.violations == []
    for k, ref in sim.views[0].items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"sub-epsilon master[{k}]")
    if transport == "queue":
        # quiesced: every in-flight delta was delivered and subtracted back
        # out exactly, so the accumulators are identically zero (the snap
        # would also report zero here — the master/cache equality above and
        # the handler-level test carry the regression weight)
        for p in rt.procs:
            for w_acc in p.unsynced.values():
                for arr in w_acc.values():
                    assert not arr.any()
