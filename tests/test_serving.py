"""Read-replica serving tier: per-read staleness SLOs measured against the
vector clock.

The contract under test is the *measured* stamp, not the request: every
:class:`ReadResult` carries the staleness actually observed against the
master shards' applied vector clocks (sampled after the serving copy, so
the stamp upper-bounds the truth), and these tests assert it never exceeds
the requested SLO — under free 4-worker interleavings for SSP, VAP, and
CVAP, over every serving transport, across mid-run replica joins, and
through master escalation.
"""
import itertools
import time

import numpy as np
import pytest

from repro.core import policies
from repro.runtime import FRESH, PSRuntime, ReadGateway, RuntimeConfig
from repro.runtime.serving import ReplicaSet

pytestmark = pytest.mark.serving


def _x0():
    return {"a": np.zeros((8, 4)), "b": np.zeros(5)}


def _fn(pause=0.0):
    def fn(w, clock, view, rng):
        if pause:
            time.sleep(pause)
        return {"a": rng.normal(0.0, 0.6, size=(8, 4)),
                "b": rng.normal(0.0, 0.6, size=5)}
    return fn


_POLICIES = [
    ("ssp3", policies.ssp(3)),
    ("vap", policies.vap(1.5)),
    ("cvap", policies.cvap(3, 1.5)),
]


# ---------------------------------------------------------------------------
# the core contract: measured <= requested, under free interleaving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_slo_honored_under_free_interleaving(polname, pol):
    """4 free-running workers, 200 clocks; the gateway serves a rotating
    mix of SLOs the whole run and every response's *measured* staleness —
    stamped against the live master vector clock — obeys the request."""
    rt = PSRuntime(RuntimeConfig(4, pol, _x0(), n_shards=2, threads_per_process=2, seed=7))
    rt.start(_fn(), 200, timeout=110)
    gw = ReadGateway(rt, n_replicas=2, transport="queue")
    slos = itertools.cycle([0, 2, 5, None])
    n = 0
    try:
        while rt.running:
            slo = next(slos)
            res = gw.read("a", slo=slo, timeout=5.0)
            bound = float("inf") if slo is None else slo
            assert res.staleness <= bound, (
                f"SLO violated: measured {res.staleness} > requested {slo} "
                f"(source {res.source})")
            assert res.staleness >= 0
            n += 1
            time.sleep(1e-3)       # pace the reader off the workers' GIL
        st = rt.wait()
        assert st.violations == [], st.violations[:5]
        # quiesced: a fresh-by-vc replica read equals the authoritative
        # master on every key (nothing was lost or double-applied on the
        # publish path)
        for key in ("a", "b"):
            res = gw.read(key, slo=0, timeout=15.0)
            np.testing.assert_array_equal(res.value, rt.master_value(key),
                                          err_msg=f"{polname} replica[{key}]")
            assert res.staleness == 0
        assert gw.replicas.violations == []
        assert gw.replicas.errors == []
        assert gw.stats.n_replica_reads > 0          # not all escalated
        assert n > 0
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# serving transports: queue + shm + tcp publish streams, >= 2 replicas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("serving", ["queue", "shm", "tcp"])
def test_gateway_serves_over_transport(serving):
    """Two replicas fed over the given transport both serve reads; stamps
    obey the SLO mid-run and the replicas converge to the master exactly."""
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(3), _x0(), n_shards=2,
                   threads_per_process=2, seed=3))
    rt.start(_fn(pause=0.002), 60, timeout=90)
    gw = ReadGateway(rt, n_replicas=2, transport=serving)
    try:
        while rt.running:
            res = gw.read("a", slo=3, timeout=5.0)
            assert res.staleness <= 3
            time.sleep(1e-3)
        st = rt.wait()
        assert st.violations == []
        for key in ("a", "b"):
            res = gw.read(key, slo=0, timeout=15.0)
            np.testing.assert_array_equal(res.value, rt.master_value(key),
                                          err_msg=f"{serving} replica[{key}]")
        # both replicas participated (least-loaded routing alternates)
        for _ in range(4):
            gw.read("a", slo=0, timeout=15.0)
        assert set(gw.stats.reads_per_replica) == {0, 1}
        assert gw.replicas.violations == []
        assert gw.replicas.errors == []
    finally:
        gw.close()


def test_serving_over_multiprocess_runtime():
    """Forked clients over shm rings *and* a shm-fed replica tier: the
    write path and the read path share the transport machinery end to end."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(3), _x0(), n_shards=2,
                   threads_per_process=1, seed=5, transport="proc"))
    rt.start(_fn(pause=0.002), 40, timeout=120)
    gw = ReadGateway(rt, n_replicas=2, transport="shm")
    try:
        while rt.running:
            res = gw.read("a", slo=3, timeout=5.0)
            assert res.staleness <= 3
            time.sleep(1e-3)
        st = rt.wait()
        assert st.violations == []
        res = gw.read("a", slo=0, timeout=15.0)
        np.testing.assert_array_equal(res.value, rt.master_value("a"))
        assert gw.replicas.errors == []
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# fresh reads + escalation
# ---------------------------------------------------------------------------


def test_fresh_reads_escalate_to_master():
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2, seed=1))
    rt.start(_fn(pause=0.002), 30, timeout=60)
    gw = ReadGateway(rt, n_replicas=1, transport="queue")
    try:
        saw_master = 0
        while rt.running:
            res = gw.read("a", slo=FRESH, timeout=5.0)
            assert res.source == "master"
            assert res.staleness == 0
            saw_master += 1
        rt.wait()
        assert saw_master > 0
        assert gw.stats.n_master_reads == saw_master
    finally:
        gw.close()


def test_unattainable_slo_escalates_to_master():
    """A replica pinned behind the master frontier cannot satisfy slo=0:
    the gateway parks on the doorbell, hits the deadline, and escalates —
    the response is the master value, stamped staleness 0."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2, seed=2))
    # subscribe before start: the shards process the Subscribe when their
    # threads come up, and the replica ingests the whole run
    gw = ReadGateway(rt, n_replicas=1, transport="queue")
    rt.run(_fn(), 10, timeout=60)
    try:
        rep = gw.replicas.replicas[0]
        # let the replica catch up first, then pin it behind the frontier
        res = gw.read("a", slo=0, timeout=10.0)
        assert res.escalated is False
        with rep.lock:
            rep.vc -= 3
        res = gw.read("a", slo=0, timeout=0.6)
        assert res.escalated is True
        assert res.source == "master"
        assert res.staleness == 0
        np.testing.assert_array_equal(res.value, rt.master_value("a"))
        assert gw.stats.n_escalations == 1
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# mid-run join: snapshot bootstrap + in-stream state
# ---------------------------------------------------------------------------


def test_replica_joins_mid_run_equals_master_at_quiesce(tmp_path):
    """A replica added mid-run — warm-started from the latest periodic
    snapshot, corrected by the shards' in-stream bootstrap states — holds
    exactly the master state once the runtime quiesces."""
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(3), _x0(), n_shards=2,
                   threads_per_process=2, seed=9, snapshot_every=5,
                   snapshot_dir=str(tmp_path)))
    rt.start(_fn(pause=0.002), 40, timeout=120)
    gw = ReadGateway(rt, n_replicas=1, transport="queue")
    try:
        # wait until a periodic snapshot exists, then join
        deadline = time.monotonic() + 60
        while rt.latest_snapshot() is None and rt.running:
            assert time.monotonic() < deadline
            time.sleep(2e-3)
        assert rt.latest_snapshot() is not None
        joined = gw.add_replica(bootstrap_from_snapshot=True)
        while rt.running:
            res = gw.read("a", slo=4, timeout=5.0)
            assert res.staleness <= 4
            time.sleep(1e-3)
        st = rt.wait()
        assert st.violations == []
        # force the joined replica to full catch-up via the vc, then
        # compare raw buffers (not just a routed read)
        deadline = time.monotonic() + 15
        rset = gw.replicas
        while rset.staleness(joined.vc, rset.master_vc()) > 0:
            assert time.monotonic() < deadline, "joined replica never caught up"
            time.sleep(5e-3)
        for key in ("a", "b"):
            value, _ = joined.serve(key)
            np.testing.assert_array_equal(
                value.reshape(rt._shapes[key]),
                rt.master_value(key), err_msg=f"joined replica[{key}]")
        assert gw.replicas.violations == []
        assert gw.replicas.errors == []
    finally:
        gw.close()


def test_poisoned_replica_leaves_the_rotation():
    """A replica whose ingest raised can no longer guarantee its vector
    clock covers its values: the gateway must never route to it again
    (values would be stamped fresher than they are)."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2, seed=4))
    gw = ReadGateway(rt, n_replicas=2, transport="queue")
    rt.run(_fn(), 6, timeout=60)
    try:
        rep0 = gw.replicas.replicas[0]

        class Bogus:                       # not a publish message type
            shard = 0
            seq = 10 ** 6

        rep0.inbox.put(Bogus())
        deadline = time.monotonic() + 10
        while not rep0.poisoned:
            assert time.monotonic() < deadline, "ingest error not recorded"
            time.sleep(2e-3)
        assert gw.replicas.errors != []
        for _ in range(4):
            res = gw.read("a", slo=0, timeout=10.0)
            assert res.source == "replica:1", res.source
            np.testing.assert_array_equal(res.value, rt.master_value("a"))
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_gateway_rejects_bad_slo_and_transport():
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    with pytest.raises(ValueError, match="serving transport"):
        ReplicaSet(rt, 1, transport="carrier-pigeon")
    with pytest.raises(ValueError, match="replica"):
        ReplicaSet(rt, 0)
    gw = ReadGateway(rt, n_replicas=1, transport="queue")
    try:
        with pytest.raises(ValueError, match="slo"):
            gw.read("a", slo=-1)
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# replica ingest backpressure: drop-and-resync (ROADMAP follow-up)
# ---------------------------------------------------------------------------


def test_wedged_replica_never_stalls_publish_and_resyncs():
    """A deliberately wedged replica (its ring reader paused) must not
    stall the shard's publish write: the shard drops its frames once the
    tiny ring fills (pub_drops > 0), keeps applying updates at full speed,
    and re-bootstraps the replica with a fresh in-stream state cut once the
    ring drains — after which the replica equals the master exactly."""
    def fn(w, clock, view, rng):
        time.sleep(1e-3)
        return {"a": rng.normal(0.0, 0.6, size=(8, 4))}

    rt = PSRuntime(RuntimeConfig(2, policies.ssp(3), {"a": np.zeros((8, 4))}, n_shards=2,
                   seed=0))
    rt.start(fn, 400, timeout=110)
    rset = ReplicaSet(rt, n_replicas=2, transport="shm", ring_capacity=1)
    try:
        time.sleep(0.1)
        rset.wedge(0, True)
        deadline = time.monotonic() + 60
        while rt.running and rset.pub_drops == 0:
            assert time.monotonic() < deadline, "wedged ring never filled"
            time.sleep(0.005)
        assert rset.pub_drops > 0, "publish should have dropped, not blocked"
        assert 0 in rset.stale_replicas
        rset.wedge(0, False)
        while rt.running and rset.pub_resyncs == 0:
            assert time.monotonic() < deadline, "recovery resync never came"
            time.sleep(0.005)
        st = rt.wait()
        assert st.violations == [], st.violations[:5]
        assert rset.pub_resyncs > 0
        assert rset.errors == [] and rset.violations == []
        time.sleep(0.5)                    # final publish cycles drain
        assert 0 not in rset.stale_replicas
        for rep in rset.replicas:
            assert not rep.poisoned
            v, _ = rep.serve("a")
            np.testing.assert_allclose(
                v, rt.master_value("a").reshape(v.shape), atol=1e-9,
                err_msg=f"replica {rep.rid} did not recover exactly")
    finally:
        rset.close()
        if rt.running:
            rt.wait()
