"""Durability tier: per-shard write-ahead delta log + exact-clock recovery.

Three layers under test:

* **WalWriter / read_segment** (repro.runtime.wal) — the vc-stamped
  append/group-commit wire format on disk: roundtrip, torn-tail recovery
  to the last complete record, segment rotation, seal/reopen naming,
  covered-prefix pruning.
* **UidDedup** (repro.runtime.shard) — the cross-epoch uid-level dedup
  table that makes at-least-once replay idempotent, unit-tested standalone.
* **recover_to_vc** (repro.runtime.snapshot) — ``snapshot + replay(log,
  upto_vc)``: genesis replay, snapshot-positioned replay, point-in-time
  restore, double-replay idempotence, tampered-stamp refusal, retention.

The end-to-end legs assert the durability audit exactly: recovered
``applied_parts`` equals the runtime's per-process parts-sent counters
(zero lost/duplicated updates) and the recovered state is **bitwise**
equal to the live master (integer deltas: f64 sums are exact and
order-independent).
"""
import os

import numpy as np
import pytest

from repro.core import policies
from repro.runtime import (PSRuntime, RuntimeConfig, UidDedup, UpdateMsg,
                           WalWriter, prune_segments, read_segment,
                           recover_to_vc, wal_segments)
from repro.runtime.snapshot import load_snapshot, save_snapshot
from repro.runtime.transport import RowCodec


def _x0():
    return {"a": np.arange(32, dtype=float).reshape(8, 4) / 2.0,
            "b": np.ones(5)}


def _fn(seed):
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-3, 4, size=(8, 4)).astype(float),
                "b": r.integers(-3, 4, size=5).astype(float)}
    return fn


def _expected(seed, n_workers, n_clocks, upto_ts=None):
    out = {k: v.astype(float) for k, v in _x0().items()}
    fn = _fn(seed)
    last = n_clocks if upto_ts is None else min(n_clocks, upto_ts + 1)
    for w in range(n_workers):
        for c in range(last):
            for k, d in fn(w, c, None, None).items():
                out[k] = out[k] + d
    return out


def _run(tmp_path, seed=5, n_clocks=12, **cfg):
    wal_dir = str(tmp_path / "wal")
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(3), _x0(), n_shards=2,
                                 threads_per_process=2, seed=seed,
                                 wal_dir=wal_dir, **cfg))
    rt.run(_fn(seed), n_clocks=n_clocks)
    return rt, wal_dir


def _msg(uid, process, ts, key="a", rows=(0, 1), val=1.0):
    rows = np.asarray(rows, dtype=np.int64)
    cols = 4 if key == "a" else 1
    delta = np.full((len(rows), cols), float(val), dtype=np.float64)
    return UpdateMsg(uid=uid, worker=process, process=process, ts=ts,
                     key=key, rows=rows, delta=delta)


def _codec():
    return RowCodec(list(_x0().keys()))


# ---------------------------------------------------------------------------
# WalWriter / read_segment
# ---------------------------------------------------------------------------


def test_writer_reader_roundtrip(tmp_path):
    w = WalWriter(str(tmp_path), sid=0, codec=_codec(), n_proc=2)
    w.log_parts([_msg(1, 0, 0), _msg(2, 1, 0, key="b", rows=[3])])
    w.commit(np.array([0, 0]))
    w.log_parts([_msg(3, 0, 1, val=-2.5)])
    w.seal(np.array([1, 0]))
    segs = wal_segments(str(tmp_path))
    assert list(segs) == [0] and len(segs[0]) == 1
    (start, path), = segs[0]
    assert start == 0
    records, sealed = read_segment(path, _codec())
    assert sealed
    kinds = [k for k, _ in records]
    assert kinds == ["parts", "vc", "parts", "vc"]
    parts = [m for k, run in records if k == "parts" for m in run]
    assert [(m.uid, m.process, m.ts, m.key) for m in parts] == [
        (1, 0, 0, "a"), (2, 1, 0, "b"), (3, 0, 1, "a")]
    np.testing.assert_array_equal(parts[2].delta,
                                  np.full((2, 4), -2.5))
    stamps = [np.asarray(v.clock_vc) for k, v in records if k == "vc"]
    assert stamps[0].tolist() == [0, 0] and stamps[1].tolist() == [1, 0]
    marks = w.marks()
    assert marks["parts"] == 3
    assert marks["applied"].tolist() == [2, 1]
    assert marks["max_ts"].tolist() == [1, 0]


def test_torn_tail_recovers_to_last_complete_record(tmp_path):
    """A segment truncated at ANY byte offset (simulated torn write) decodes
    cleanly to a prefix of the full record stream — never raises, never
    yields a phantom record."""
    w = WalWriter(str(tmp_path), sid=0, codec=_codec(), n_proc=2)
    for i in range(4):
        w.log_parts([_msg(2 * i, 0, i), _msg(2 * i + 1, 1, i, key="b",
                                             rows=[i])])
        w.commit(np.array([i, i]))
    w.seal()
    (_, path), = wal_segments(str(tmp_path))[0]
    full, sealed = read_segment(path, _codec())
    assert sealed
    data = open(path, "rb").read()
    torn = str(tmp_path / "torn.bin")
    prev_len = -1
    for cut in range(len(data) - 1, -1, -1):
        with open(torn, "wb") as f:
            f.write(data[:cut])
        records, sealed = read_segment(torn, _codec())
        assert not sealed                    # the EOF sentinel is gone
        assert len(records) <= len(full)
        for (k, v), (fk, fv) in zip(records, full):
            assert k == fk                   # a strict prefix, record-wise
        assert len(records) <= max(prev_len, len(full))
        prev_len = len(records)


def test_data_after_eof_is_corruption(tmp_path):
    w = WalWriter(str(tmp_path), sid=0, codec=_codec(), n_proc=2)
    w.log_parts([_msg(1, 0, 0)])
    w.seal(np.array([0, 0]))
    (_, path), = wal_segments(str(tmp_path))[0]
    with open(path, "ab") as f:
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(ValueError, match="data after EOF"):
        read_segment(path, _codec())


def test_segment_rotation_positions_are_contiguous(tmp_path):
    """Tiny segment_bytes forces rotation on nearly every commit; segment
    start positions must tile the slot's log exactly."""
    w = WalWriter(str(tmp_path), sid=3, codec=_codec(), n_proc=2,
                  segment_bytes=64)
    n = 0
    for i in range(10):
        w.log_parts([_msg(i, i % 2, i)])
        n += 1
        w.commit(np.array([i, i]))
    w.seal()
    segs = wal_segments(str(tmp_path))[3]
    assert len(segs) > 1
    pos = 0
    for start, path in segs:
        assert start == pos
        records, sealed = read_segment(path, _codec())
        assert sealed
        pos += sum(len(run) for k, run in records if k == "parts")
    assert pos == n


def test_seal_reopen_names_never_collide(tmp_path):
    """Seal with zero new parts, then write again (kill + rejoin of a slot):
    the generation counter keeps segment names distinct, so the reopened
    log never appends past an EOF sentinel."""
    w = WalWriter(str(tmp_path), sid=0, codec=_codec(), n_proc=2)
    w.log_parts([_msg(1, 0, 0)])
    w.seal(np.array([0, 0]))
    w.seal(np.array([0, 0]))                  # idempotent no-op
    w.log_parts([_msg(2, 0, 1)])              # re-activation, 0 new parts
    w.seal(np.array([1, 0]))                  # before: same start_part=1
    w.log_parts([_msg(3, 1, 0)])
    w.seal(np.array([1, 0]))
    segs = wal_segments(str(tmp_path))[0]
    assert len(segs) == 3
    assert len({path for _, path in segs}) == 3
    for _, path in segs:
        records, sealed = read_segment(path, _codec())   # none raises
        assert sealed


def test_prune_segments_keeps_uncovered_and_last(tmp_path):
    w = WalWriter(str(tmp_path), sid=0, codec=_codec(), n_proc=2,
                  segment_bytes=1)            # rotate every commit
    for i in range(5):
        w.log_parts([_msg(i, 0, i)])
        w.commit(np.array([i, 0]))
    w.seal()
    segs = wal_segments(str(tmp_path))[0]
    assert [s for s, _ in segs] == [0, 1, 2, 3, 4]
    removed = prune_segments(str(tmp_path), {0: 3})
    assert len(removed) == 3                  # segments [0,1) [1,2) [2,3)
    left = wal_segments(str(tmp_path))[0]
    assert [s for s, _ in left] == [3, 4]
    # covering everything still never deletes the last segment
    prune_segments(str(tmp_path), {0: 10 ** 9})
    assert [s for s, _ in wal_segments(str(tmp_path))[0]] == [4]


# ---------------------------------------------------------------------------
# UidDedup (standalone unit — the cross-epoch apply-path dedup table)
# ---------------------------------------------------------------------------


def test_uid_dedup_drops_duplicates_and_prunes_on_advance():
    d = UidDedup(2)
    assert d.fresh(10, 0, 0)
    assert not d.fresh(10, 0, 0)              # exact duplicate
    assert d.n_dropped == 1
    assert d.fresh(11, 0, 1)
    assert d.fresh(20, 1, 0)                  # other process: independent
    d.advance(0, 0)                           # clock 0 complete for proc 0
    assert d.frontier.tolist() == [0, -1]
    assert not d.fresh(12, 0, 0)              # late duplicate below frontier
    assert d.fresh(13, 0, 1)                  # ts above frontier: fresh
    assert 10 not in d._seen[0]               # pruned (covered by frontier)
    assert 20 in d._seen[1]                   # other process untouched
    d.advance(0, -5)                          # never regresses
    assert d.frontier.tolist() == [0, -1]


def test_uid_dedup_cross_epoch_resend():
    """The kill-epoch scenario: a part applied before the cut is resent
    after it (same uid, same ts) — dropped whether or not a ClockMsg
    advanced the frontier in between."""
    d = UidDedup(2)
    assert d.fresh(7, 1, 3)
    assert not d.fresh(7, 1, 3)               # resend before any boundary
    d.advance(1, 3)
    assert not d.fresh(7, 1, 3)               # resend after the boundary
    assert d.n_dropped == 2


# ---------------------------------------------------------------------------
# recover_to_vc: snapshot + replay(log, upto_vc)
# ---------------------------------------------------------------------------


def test_genesis_recovery_bitwise_and_audit(tmp_path):
    rt, wal_dir = _run(tmp_path, seed=5, n_clocks=12)
    rec = recover_to_vc(_x0(), wal_dir)
    assert rec["from_snapshot"] is None
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist()
    assert rec["n_deduped"] == 0
    assert rec["clock"] == 12
    exp = _expected(5, 4, 12)
    for k, v in exp.items():
        np.testing.assert_array_equal(rec["params"][k], v)


def test_snapshot_positioned_replay(tmp_path):
    """With periodic snapshots on, recovery seeds from the newest snapshot
    and replays only the per-slot log suffix beyond its positional marks —
    same bitwise result, same audit."""
    rt, wal_dir = _run(tmp_path, seed=6, n_clocks=15, snapshot_every=4,
                       snapshot_dir=str(tmp_path / "snaps"))
    rec = recover_to_vc(_x0(), wal_dir,
                        snapshot_dir=str(tmp_path / "snaps"))
    assert rec["from_snapshot"] is not None
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist()
    exp = _expected(6, 4, 15)
    for k, v in exp.items():
        np.testing.assert_array_equal(rec["params"][k], v)
    # genesis replay of the same log agrees exactly
    gen = recover_to_vc(_x0(), wal_dir)
    for k in exp:
        np.testing.assert_array_equal(rec["params"][k], gen["params"][k])


def test_point_in_time_restore(tmp_path):
    """``upto_vc`` excludes parts timestamped past the target: the result
    is exactly the additive state of the first ``c+1`` periods."""
    _, wal_dir = _run(tmp_path, seed=7, n_clocks=12)
    for c in (3, 7):
        rec = recover_to_vc(_x0(), wal_dir, upto_vc=[c, c])
        assert rec["clock_vc"].tolist() == [c, c]
        assert rec["clock"] == c + 1
        exp = _expected(7, 4, 12, upto_ts=c)
        for k, v in exp.items():
            np.testing.assert_array_equal(rec["params"][k], v)


def test_point_in_time_skips_uncovered_snapshot(tmp_path):
    """A snapshot that already folds in updates past ``upto_vc`` cannot be
    un-applied; the picker must fall back to an older snapshot or genesis
    and still land bitwise on the point-in-time state."""
    _, wal_dir = _run(tmp_path, seed=8, n_clocks=16, snapshot_every=4,
                      snapshot_dir=str(tmp_path / "snaps"))
    rec = recover_to_vc(_x0(), wal_dir, snapshot_dir=str(tmp_path / "snaps"),
                        upto_vc=[2, 2])
    assert rec["from_snapshot"] is None       # every snapshot is too new
    exp = _expected(8, 4, 16, upto_ts=2)
    for k, v in exp.items():
        np.testing.assert_array_equal(rec["params"][k], v)


def test_double_replay_is_idempotent(tmp_path):
    """At-least-once replay: feeding the same log content twice (a segment
    duplicated under another generation name) changes nothing — the vc
    stamps advance the dedup frontier past the first copy's parts, so the
    second copy is dropped uid-for-uid."""
    rt, wal_dir = _run(tmp_path, seed=9, n_clocks=10)
    clean = recover_to_vc(_x0(), wal_dir)
    for name in list(os.listdir(wal_dir)):
        base, ext = os.path.splitext(name)
        assert base.endswith("_g0000")
        dup = base[:-6] + "_g9999" + ext      # same start_part, later gen
        with open(os.path.join(wal_dir, name), "rb") as src, \
                open(os.path.join(wal_dir, dup), "wb") as dst:
            dst.write(src.read())
    rec = recover_to_vc(_x0(), wal_dir)
    assert rec["n_deduped"] > 0
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist()
    for k in clean["params"]:
        np.testing.assert_array_equal(rec["params"][k], clean["params"][k])


def test_tampered_vc_stamp_refused(tmp_path):
    """An out-of-range vc stamp in the log (bit rot / tampering) is refused
    loudly via snapshot.validate_vcs, not silently replayed."""
    w = WalWriter(str(tmp_path / "wal"), sid=0, codec=_codec(), n_proc=2)
    w.log_parts([_msg(1, 0, 0)])
    w.commit(np.array([1 << 50, 0]))          # beyond the 2^48 stamp range
    w.seal()
    with pytest.raises(ValueError, match="out-of-range"):
        recover_to_vc(_x0(), str(tmp_path / "wal"), n_proc=2)
    w2 = WalWriter(str(tmp_path / "wal2"), sid=0, codec=_codec(), n_proc=2)
    w2.log_parts([_msg(1, 0, 0)])
    w2.commit(np.array([0, 0, 0]))            # wrong width: malformed
    w2.seal()
    with pytest.raises(ValueError, match="malformed"):
        recover_to_vc(_x0(), str(tmp_path / "wal2"), n_proc=2)


def test_recovery_after_torn_tail(tmp_path):
    """Chop bytes off the live tail segment (kill mid-write): recovery
    still works, yielding a consistent prefix state (audit counters simply
    reflect the surviving parts)."""
    rt, wal_dir = _run(tmp_path, seed=11, n_clocks=10)
    full = recover_to_vc(_x0(), wal_dir)
    sid0 = wal_segments(wal_dir)[0]
    start, path = sid0[-1]
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(size - 7)                  # mid-record, mid-payload
    rec = recover_to_vc(_x0(), wal_dir)
    assert (rec["applied_parts"] <= full["applied_parts"]).all()
    assert rec["n_deduped"] == 0


# ---------------------------------------------------------------------------
# retention + snapshot wal-marks plumbing
# ---------------------------------------------------------------------------


def test_retention_prunes_and_restores_from_newest_pair(tmp_path):
    """``snapshot_keep_last=k`` prunes old periodic snapshots and the WAL
    segments they fully cover; restore from the newest retained
    snapshot+log pair is still exact."""
    sdir = str(tmp_path / "snaps")
    rt, wal_dir = _run(tmp_path, seed=12, n_clocks=18, snapshot_every=3,
                       snapshot_dir=sdir, snapshot_keep_last=2,
                       wal_segment_bytes=2048)
    snaps = sorted(os.listdir(sdir))
    assert len(snaps) == 2                    # pruned beyond keep_last
    assert len(rt.snapshots) == 2
    rec = recover_to_vc(_x0(), wal_dir, snapshot_dir=sdir)
    assert rec["from_snapshot"] is not None
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist()
    exp = _expected(12, 4, 18)
    for k, v in exp.items():
        np.testing.assert_array_equal(rec["params"][k], v)


def test_snapshot_wal_marks_roundtrip(tmp_path):
    rt, wal_dir = _run(tmp_path, seed=13, n_clocks=8)
    from repro.runtime.snapshot import take_snapshot
    snap = take_snapshot(rt)
    assert "wal" in snap
    p = str(tmp_path / "s.npz")
    save_snapshot(p, snap)
    back = load_snapshot(p)
    assert back["wal"]["slots"] == snap["wal"]["slots"]
    for f in ("parts", "applied", "max_ts"):
        np.testing.assert_array_equal(back["wal"][f], snap["wal"][f])
    # a snapshot taken at quiesce covers the whole log: replay adds nothing
    rec = recover_to_vc(_x0(), wal_dir, snapshot=back)
    assert rec["n_replayed"] == 0
    assert rec["applied_parts"].tolist() == rt._parts_sent.tolist()


# ---------------------------------------------------------------------------
# config validation + metrics surface
# ---------------------------------------------------------------------------


def test_config_validations(tmp_path):
    ok = dict(n_workers=2, policy=policies.ssp(1), init_params=_x0())
    with pytest.raises(ValueError, match="snapshot_dir"):
        RuntimeConfig(**ok, snapshot_every=5)
    with pytest.raises(ValueError, match="wal_dir"):
        RuntimeConfig(**ok, wal_fsync="boundary")
    with pytest.raises(ValueError, match="wal_fsync"):
        RuntimeConfig(**ok, wal_dir=str(tmp_path), wal_fsync="always")
    with pytest.raises(ValueError, match="wal_segment_bytes"):
        RuntimeConfig(**ok, wal_dir=str(tmp_path), wal_segment_bytes=0)
    with pytest.raises(ValueError, match="snapshot_keep_last"):
        RuntimeConfig(**ok, snapshot_every=5, snapshot_dir=str(tmp_path),
                      snapshot_keep_last=-1)
    with pytest.raises(ValueError, match="snapshot_dir"):
        RuntimeConfig(**ok, snapshot_keep_last=2)
    # valid combinations construct
    RuntimeConfig(**ok, wal_dir=str(tmp_path), wal_fsync="boundary")
    RuntimeConfig(**ok, snapshot_every=5, snapshot_dir=str(tmp_path),
                  snapshot_keep_last=2)
    with pytest.raises(ValueError, match="fsync"):
        WalWriter(str(tmp_path), 0, _codec(), 2, fsync="weekly")


def test_metrics_report_wal_counters(tmp_path):
    rt, _ = _run(tmp_path, seed=14, n_clocks=8, wal_fsync="boundary")
    m = rt.metrics()
    active = [s for s in m.shards if s.active]
    assert sum(s.wal_parts for s in active) == int(rt._parts_sent.sum())
    for s in active:
        assert s.wal_commits > 0
        assert s.wal_bytes > 0
        assert s.wal_segments >= 1
        assert s.wal_fsync_s > 0.0            # boundary policy paid fsyncs
    rt_off = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0()))
    rt_off.run(_fn(1), n_clocks=2)
    assert all(s.wal_parts == 0 and s.wal_commits == 0
               for s in rt_off.metrics().shards)
