"""Chaos / fault-injection conformance suite (tests/chaos.py harness).

Two legs, one seed space:

* **Simulator property matrix** — seeded random schedules (policy, compute
  skew, stragglers, network) driven through long runs of the executable
  spec, asserting the paper's Lemma bounds *exactly* on every observed
  maximum (no hypothesis — the generator is a plain seeded rng).

* **Runtime chaos** — the same workloads on the live runtime under free
  4-worker interleaving with seeded membership faults (add / remove /
  kill+rejoin) and, in the serving leg, SLO'd gateway reads with a seeded
  replica wedger.  Asserts (a) final state == x0 + sum(updates) == the
  membership-free spec, (b) mid-run staleness/value stamps within bound
  (the runtime's own recorded violations), (c) zero lost/duplicated
  updates by counter audit.

The quick loop runs the 30-clock smoke (``-m "chaos and not slow"``); the
nightly tier-1 suite runs the full seeded 200-clock matrix (``slow``).
"""
import numpy as np
import pytest

from repro.core import policies

from chaos import (assert_counters, assert_paper_bounds, assert_wal_recovery,
                   chaos_run, expected_final, run_sim_schedule,
                   random_schedule, x0, zipf_fn)

pytestmark = pytest.mark.chaos


# ---------------------------------------------------------------------------
# simulator leg: seeded random schedules obey the Lemma bounds exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_sim_random_schedule_bounds_smoke(seed):
    sched = random_schedule(seed)
    _, stats = run_sim_schedule(sched, n_clocks=30)
    assert_paper_bounds(sched["policy"], stats)
    assert stats.n_updates == sched["n_workers"] * 30 * 2


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(8))
def test_sim_random_schedule_bounds_full(seed):
    """200-clock runs: long enough that staleness, value-gate blocking, and
    strong-VAP queueing all actually engage (asserted non-vacuous for the
    bounded dimensions that apply)."""
    sched = random_schedule(seed)
    pol = sched["policy"]
    _, stats = run_sim_schedule(sched, n_clocks=200)
    assert_paper_bounds(pol, stats)
    assert stats.n_updates == sched["n_workers"] * 200 * 2
    if pol.clock_bounded:
        assert stats.max_observed_staleness >= 0
    if pol.value_bounded:
        assert stats.max_unsynced_mag > 0.0


# ---------------------------------------------------------------------------
# runtime leg: membership faults under free interleaving
# ---------------------------------------------------------------------------

_POLICIES = [
    ("ssp3", policies.ssp(3)),
    ("essp3", policies.essp(3)),
    ("vap", policies.vap(4.5)),
    ("cvap", policies.cvap(3, 4.5)),
    ("elastic", policies.elastic(12.0)),
]


def _assert_chaos_outcome(rt, stats, plan, seed, n_clocks):
    assert stats.violations == [], stats.violations[:5]
    fired = [r for _, r in plan.results if r == "ok"]
    assert len(fired) == len(plan.events), plan.results   # every fault fired
    assert_counters(rt)
    assert stats.n_updates == 4 * n_clocks * 2
    if rt.policy.clock_bounded:
        assert stats.max_observed_staleness <= rt.policy.staleness
    for k, ref in expected_final(seed, 4, n_clocks).items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"chaos seed={seed} master[{k}]")


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_runtime_membership_chaos_smoke(polname, pol, tmp_path):
    """Membership chaos with the durability tier on: besides the live-state
    assertions, the WAL alone must reconstruct the exact final state with
    zero lost/duplicated updates (snapshot-granularity loss is no longer
    tolerated)."""
    seed = {"ssp3": 21, "essp3": 24, "vap": 22, "cvap": 23,
            "elastic": 25}[polname]
    n_clocks = 30
    wal_dir = str(tmp_path / "wal")
    rt, stats, plan, _ = chaos_run(seed, pol, n_clocks, n_events=3,
                                   wal_dir=wal_dir)
    _assert_chaos_outcome(rt, stats, plan, seed, n_clocks)
    assert_wal_recovery(rt, seed, n_clocks, wal_dir)


def test_wal_off_cross_epoch_duplicate_dropped(monkeypatch):
    """Regression: uid dedup used to be armed only when a WAL was
    configured, so on wal-off shards a transport-level duplicate of an
    update frame landing after a membership epoch had begun was applied
    twice (the re-framed copy carries a fresh monotone seq, so FIFO checks
    cannot catch it).  The drop filter now arms permanently at the first
    EpochBeginMsg: the injected duplicate must be dropped — zero recorded
    violations, exact per-process counter audit, bitwise final state."""
    import threading

    from repro.runtime import PSRuntime
    from repro.runtime.messages import UpdateMsg

    injected = {"n": 0}
    lock = threading.Lock()
    orig = PSRuntime._send_many

    def dup_send_many(self, chan, msgs):
        orig(self, chan, msgs)
        with lock:
            if injected["n"]:
                return
            pick = next((m for m in msgs if isinstance(m, UpdateMsg)
                         and m.epoch >= 1), None)
            if pick is None:
                return
            injected["n"] = 1
            dup = UpdateMsg(pick.uid, pick.worker, pick.process, pick.ts,
                            pick.key, pick.rows.copy(), pick.delta.copy(),
                            pick.epoch)
        orig(self, chan, [dup])

    monkeypatch.setattr(PSRuntime, "_send_many", dup_send_many)
    seed = 27
    n_clocks = 30
    rt, stats, plan, _ = chaos_run(seed, policies.ssp(3), n_clocks,
                                   n_events=3)     # wal_dir=None: wal-off
    assert injected["n"] == 1, "no post-epoch update frame was ever sent"
    _assert_chaos_outcome(rt, stats, plan, seed, n_clocks)


@pytest.mark.slow
@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
@pytest.mark.parametrize("seed", [31, 32])
def test_runtime_membership_chaos_full(polname, pol, seed):
    """The full matrix: 200 free clocks, 5 seeded membership faults
    (including kill+rejoin slot re-activations), bounds asserted across
    every migration window."""
    n_clocks = 200
    rt, stats, plan, _ = chaos_run(seed, pol, n_clocks, n_events=5)
    _assert_chaos_outcome(rt, stats, plan, seed, n_clocks)
    if pol.clock_bounded:
        # asynchrony actually happened: the checks were not vacuous
        assert stats.max_observed_staleness > 0


@pytest.mark.slow
def test_runtime_membership_chaos_multiprocess():
    """Forked OS clients (shm rings) under membership faults: the epoch
    barrier crosses the real wire."""
    seed = 41
    n_clocks = 40
    rt, stats, plan, _ = chaos_run(seed, policies.ssp(3), n_clocks,
                                   transport="shm", n_events=3,
                                   timeout=150.0)
    assert stats.violations == [], stats.violations[:5]
    assert [r for _, r in plan.results] == ["ok"] * len(plan.events)
    for k, ref in expected_final(seed, 4, n_clocks).items():
        np.testing.assert_array_equal(rt.master_value(k).reshape(ref.shape),
                                      ref)


@pytest.mark.slow
@pytest.mark.parametrize("transport", ["shm", "tcp"])
@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_runtime_membership_chaos_wal_wire_full(polname, pol, transport,
                                                tmp_path):
    """Durability matrix over the real wires (shm rings / TCP sockets) ×
    SSP/VAP/CVAP with kill+rejoin faults: the per-shard WAL — written by
    the parent-side shard threads while forked clients drive load over the
    wire — must reconstruct the exact final state with zero lost or
    duplicated updates (per-process counter audit), bitwise equal to the
    membership-free expectation."""
    seed = {"ssp3": 91, "essp3": 94, "vap": 92, "cvap": 93,
            "elastic": 95}[polname]
    n_clocks = 40
    wal_dir = str(tmp_path / "wal")
    rt, stats, plan, _ = chaos_run(seed, pol, n_clocks, transport=transport,
                                   n_events=3, wal_dir=wal_dir,
                                   timeout=150.0)
    assert stats.violations == [], stats.violations[:5]
    assert [r for _, r in plan.results] == ["ok"] * len(plan.events)
    assert_counters(rt)
    assert_wal_recovery(rt, seed, n_clocks, wal_dir)


# ---------------------------------------------------------------------------
# autoscaler leg: the control loop IS the membership churn driver
# ---------------------------------------------------------------------------


def _assert_autoscale_outcome(rt, stats, seed, n_clocks, fn):
    """Bounds + exact audit + exact final state, with the autoscaler (not a
    script) churning membership under Zipf-skewed bursty load."""
    assert stats.violations == [], stats.violations[:5]
    assert_counters(rt)
    if rt.policy.clock_bounded:
        assert stats.max_observed_staleness <= rt.policy.staleness
    for k, ref in expected_final(seed, 4, n_clocks, fn=fn).items():
        np.testing.assert_array_equal(
            rt.master_value(k).reshape(ref.shape), ref,
            err_msg=f"autoscale chaos seed={seed} master[{k}]")
    # the churn was real: at least one membership op actually landed
    summary = rt.autoscaler.summary()
    assert summary.get("add_shard", 0) + summary.get("remove_shard", 0) >= 1, (
        summary, rt.autoscaler.actions)


@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_runtime_autoscaler_chaos_smoke(polname, pol):
    """Zipf-skewed bursty load concentrates rows on one slot; the
    autoscaler splits/drains shards live while the Lemma bounds and the
    zero-lost/duplicated counter audit keep holding."""
    seed = {"ssp3": 71, "essp3": 75, "vap": 72, "cvap": 73,
            "elastic": 76}[polname]
    n_clocks = 80
    fn = zipf_fn(seed)
    rt, stats, plan, _ = chaos_run(seed, pol, n_clocks, autoscale=True,
                                   fn=fn)
    assert plan is None                       # the autoscaler drives churn
    _assert_autoscale_outcome(rt, stats, seed, n_clocks, fn)


@pytest.mark.serving
def test_serving_autoscaler_chaos_smoke():
    """Autoscaler + gateway: replica scaling and fresh-read shedding under
    SLO'd reads — every served stamp stays within its request, shed reads
    surface as ReadShedError (counted, tolerated), and the bounds/audit
    hold through the churn."""
    seed = 74
    n_clocks = 80
    fn = zipf_fn(seed)
    rt, stats, plan, reader = chaos_run(seed, policies.ssp(3), n_clocks,
                                        autoscale=True, serving=True, fn=fn)
    _assert_autoscale_outcome(rt, stats, seed, n_clocks, fn)
    assert reader.bad == [], reader.bad[:5]
    assert reader.errors == [], reader.errors[:3]
    assert reader.n_reads > 0


@pytest.mark.slow
@pytest.mark.parametrize("transport", ["shm", "tcp"])
@pytest.mark.parametrize("polname,pol", _POLICIES, ids=[p[0] for p in _POLICIES])
def test_runtime_autoscaler_chaos_wire_full(polname, pol, transport):
    """The full matrix: forked OS clients on real wires (shm rings / TCP
    sockets) with the autoscaler churning membership — the epoch barrier,
    the piggybacked metrics loads, and the audit all cross the wire."""
    seed = {"ssp3": 81, "essp3": 84, "vap": 82, "cvap": 83,
            "elastic": 85}[polname]
    n_clocks = 40
    fn = zipf_fn(seed)
    rt, stats, plan, _ = chaos_run(seed, pol, n_clocks, transport=transport,
                                   autoscale=True, fn=fn, timeout=150.0)
    _assert_autoscale_outcome(rt, stats, seed, n_clocks, fn)


# ---------------------------------------------------------------------------
# serving leg: SLO stamps + wedged replicas through membership chaos
# ---------------------------------------------------------------------------


@pytest.mark.serving
def test_serving_chaos_smoke():
    seed = 51
    n_clocks = 40
    rt, stats, plan, reader = chaos_run(seed, policies.ssp(3), n_clocks,
                                        n_events=2, serving=True)
    assert stats.violations == [], stats.violations[:5]
    assert reader.bad == [], reader.bad[:5]
    assert reader.errors == [], reader.errors[:3]
    assert reader.n_reads > 0
    assert reader.replica_errors == []
    for vals in reader.final_replicas:
        for k, ref in expected_final(seed, 4, n_clocks).items():
            np.testing.assert_array_equal(vals[k].reshape(ref.shape), ref)


@pytest.mark.slow
@pytest.mark.serving
def test_serving_chaos_with_wedged_replicas_full():
    """Membership faults + a seeded replica wedger: stale replicas drop out
    of the rotation by their vector clock (stamps stay honest), and every
    recovered replica converges to the master exactly via the in-stream
    drop-and-resync re-bootstrap."""
    seed = 61
    n_clocks = 150
    rt, stats, plan, reader = chaos_run(seed, policies.ssp(3), n_clocks,
                                        n_events=4, serving=True, wedge=True,
                                        serving_transport="shm",
                                        timeout=150.0)
    assert stats.violations == [], stats.violations[:5]
    assert reader.bad == [], reader.bad[:5]
    assert reader.errors == [], reader.errors[:3]
    assert reader.n_reads > 0                 # the reader survived the run
    assert reader.replica_errors == []
    # every replica that finished un-stale (the wedger stands down at 70%
    # of the run, leaving publish cycles to resync) converged exactly
    assert reader.final_replicas, "every replica ended stale or poisoned"
    for vals in reader.final_replicas:
        for k, ref in expected_final(seed, 4, n_clocks).items():
            np.testing.assert_array_equal(vals[k].reshape(ref.shape), ref)
