"""Tests for the beyond-paper performance variants (EXPERIMENTS.md §Perf):
sequence-parallel SSD, int8-compressed gathers, bf16 state storage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, ConsistencySpec, TrainConfig, get_config,
                           reduced_config)
from repro.launch.train import run as train_run


def test_variant_configs_registered():
    assert "mamba2-130m-sp" in ARCHS
    assert "pixtral-12b-cg" in ARCHS
    assert get_config("mamba2-130m-sp").tp_strategy == "seq_ssm"
    assert get_config("pixtral-12b-cg").compress_gathers


def test_bf16_state_trains_close_to_f32():
    cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
    finals = {}
    for sd in ("float32", "bfloat16"):
        tcfg = TrainConfig(arch="x", steps=20, lr=2e-3, optimizer="adam",
                           log_every=19, state_dtype=sd,
                           consistency=ConsistencySpec(model="cvap",
                                                       staleness=3,
                                                       value_bound=0.05))
        _, hist = train_run(tcfg, cfg, mesh=None, batch_size=4, seq_len=48,
                            log=lambda *_: None)
        finals[sd] = hist[-1]["loss"]
    assert abs(finals["bfloat16"] - finals["float32"]) < 0.05, finals


def test_compressed_gather_single_device_noop():
    """At tp=1 the compress flag must be a perfect no-op."""
    from repro.models import model as M
    from repro.models.common import ShardCtx, instantiate_tree
    cfg = dataclasses.replace(reduced_config("qwen3-8b"), dtype="float32")
    cfg_c = dataclasses.replace(cfg, compress_gathers=True)
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    ids = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    x1, _, _ = M.forward(cfg, ShardCtx(), params, ids, remat=False)
    x2, _, _ = M.forward(cfg_c, ShardCtx(), params, ids, remat=False)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


@pytest.mark.slow
def test_seqpar_ssd_matches_replicated(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.launch import mesh as mesh_lib, specs as S
from repro.models.common import instantiate_tree, pspec_tree, ShardCtx
from repro.models import model as M
from jax.sharding import PartitionSpec as P

mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(reduced_config("mamba2-130m"), dtype="float32",
                          tp_strategy="seq_ssm")
defs = M.model_defs(cfg, 4)
params = jax.device_put(instantiate_tree(defs, jax.random.key(0)),
                        S.shardings(pspec_tree(defs), mesh))
ctx = ShardCtx(model_axis="model", dp_axes=("data",), tp=4)
ids = jnp.asarray(np.random.default_rng(0).integers(1, cfg.vocab_size, (4, 64)), jnp.int32)
def fwd(p, i):
    x, _, _ = M.forward(cfg, ctx, p, i, remat=False)
    return ctx.gather_seq(x)
f = jax.jit(mesh_lib.shard_map(fwd, mesh=mesh,
            in_specs=(pspec_tree(defs), P("data", None)),
            out_specs=P("data", None, None)))
xd = f(params, ids)
cfg1 = dataclasses.replace(cfg, tp_strategy="replicated")
params1 = instantiate_tree(M.model_defs(cfg1, 1), jax.random.key(0))
xl, _, _ = M.forward(cfg1, ShardCtx(), params1, ids, remat=False)
err = float(jnp.max(jnp.abs(xd - xl)))
assert err < 5e-4, err
print("SEQPAR_OK", err)
""")
    assert "SEQPAR_OK" in out


@pytest.mark.slow
def test_compressed_gathers_bounded_error(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.launch import mesh as mesh_lib, specs as S
from repro.models.common import instantiate_tree, pspec_tree, ShardCtx
from repro.models import model as M
from jax.sharding import PartitionSpec as P

mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(reduced_config("qwen3-8b"), dtype="float32",
                          compress_gathers=True)
defs = M.model_defs(cfg, 4)
params = jax.device_put(instantiate_tree(defs, jax.random.key(0)),
                        S.shardings(pspec_tree(defs), mesh))
ctx = ShardCtx(model_axis="model", dp_axes=("data",), tp=4)
ids = jnp.asarray(np.random.default_rng(0).integers(1, cfg.vocab_size, (4, 32)), jnp.int32)
def fwd(p, i):
    x, _, _ = M.forward(cfg, ctx, p, i, remat=False)
    return ctx.gather_seq(x)
f = jax.jit(mesh_lib.shard_map(fwd, mesh=mesh,
            in_specs=(pspec_tree(defs), P("data", None)),
            out_specs=P("data", None, None)))
xd = f(params, ids)
params1 = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
cfg1 = dataclasses.replace(cfg, compress_gathers=False)
xl, _, _ = M.forward(cfg1, ShardCtx(), params1, ids, remat=False)
rel = float(jnp.max(jnp.abs(xd - xl))) / (float(jnp.max(jnp.abs(xl))) + 1e-9)
assert rel < 0.05, rel   # lossy by design, bounded
print("CG_OK", rel)
""")
    assert "CG_OK" in out
