"""Theory validation: the paper's §3 claims, measured on the simulator.

* Theorem 1 — SGD under VAP with η_t = σ/√t has regret ≤ the paper's bound;
  average regret is sublinear (convergence).
* BSP Lemma — CVAP with s=0 (and no value slack) reduces exactly to BSP.
* Lemma 1 style drift accounting — the noisy view differs from the true
  sequence by bounded missing/extra mass.
"""
import numpy as np
import pytest

from repro.core import AsyncPS, NetworkModel, bsp, cvap, theory, vap

DIM = 4
P = 4


def _components(T, seed=0):
    """Convex components f_t(x) = |a_t . x - y_t| elaborated as quadratics:
    f_t(x) = 0.5*(a.x - y)^2 truncated-gradient to stay L-Lipschitz."""
    rng = np.random.default_rng(seed)
    A = rng.normal(0, 1, (T, DIM)) / np.sqrt(DIM)
    xstar = rng.normal(0, 1, DIM)
    y = A @ xstar
    return A, y, xstar


def test_theorem1_regret_bound():
    clocks = 60
    F, L = 4.0, 4.0
    v_thr = 0.05
    sigma = theory.sigma_star(F, L, v_thr, P)
    A, y, xstar = _components(P * (clocks + 1))
    regrets = []
    t_counter = [0]

    def fn(w, clock, view, rng):
        x = view.get("x")
        t = t_counter[0] = t_counter[0] + 1
        i = (clock * P + w) % len(y)
        r = A[i] @ x - y[i]
        g = np.clip(A[i] * r, -L / 2, L / 2)           # keep ||g|| <= L
        fx = 0.5 * r ** 2
        fstar = 0.5 * (A[i] @ xstar - y[i]) ** 2
        regrets.append(fx - fstar)
        eta = sigma / np.sqrt(t)
        return {"x": -eta * g}

    ps = AsyncPS(P, vap(v_thr), {"x": np.zeros(DIM)},
                 network=NetworkModel(base_delay=0.3, jitter=0.2, seed=1),
                 seed=1)
    st = ps.run(fn, clocks)
    assert st.violations == []
    R = np.cumsum(regrets)
    T = len(R)
    bound = theory.theorem1_regret_curve(T, F, L, v_thr, P)
    # the measured regret must sit below the paper's bound everywhere
    assert np.all(R <= bound + 1e-6), (R[-1], bound[-1])
    # and be sublinear (average regret decreasing) — convergence
    assert theory.regret_is_sublinear(R)


def test_regret_bound_formula_matches_terms():
    T, F, L, v, p = 1000, 2.0, 3.0, 0.1, 8
    s = theory.sigma_star(F, L, v, p)
    manual = (s * L ** 2 * np.sqrt(T) + F ** 2 * np.sqrt(T) / s
              + 2 * s * L * v * p * np.sqrt(T))
    assert np.isclose(theory.theorem1_regret_bound(T, F, L, v, p), manual)


def test_lemma1_bound_formula():
    assert theory.lemma1_bound(0.5, 9) == 2 * 0.5 * 8


def test_bsp_lemma_cvap_zero_reduces_to_bsp():
    """CVAP with s=0 produces the same iterate sequence as BSP (BSP Lemma)."""

    def make_fn():
        def fn(w, clock, view, rng):
            x = view.get("x")
            # deterministic update so trajectories are comparable
            return {"x": -0.1 * (x - (w + 1.0))}
        return fn

    views = {}
    for name, pol in [("bsp", bsp()), ("cvap0", cvap(0, 1e9))]:
        ps = AsyncPS(4, pol, {"x": np.zeros(3)},
                     network=NetworkModel(base_delay=0.2, seed=5), seed=5)
        st = ps.run(make_fn(), 12)
        assert st.violations == []
        views[name] = ps.master_value("x")
    np.testing.assert_allclose(views["bsp"], views["cvap0"], atol=1e-12)


def test_smaller_vthr_tightens_the_system():
    """The knob works: tighter value bounds strictly increase blocking (the
    consistency/throughput trade-off) and never increase replica divergence.
    (In WEAK VAP the divergence is dominated by in-transit updates, so the
    divergence effect is monotone but small — the paper's motivation for the
    strong variant.)"""
    def fn(w, clock, view, rng):
        x = view.get("x")
        return {"x": -0.05 * (2 * x - 1 + rng.normal(0, 0.5, 3))}

    res = {}
    for v_thr in (0.02, 10.0):
        ps = AsyncPS(8, vap(v_thr), {"x": np.zeros(3)},
                     network=NetworkModel(base_delay=1.0, jitter=0.5, seed=2),
                     seed=2)
        st = ps.run(fn, 25, divergence_every=0.25)
        assert st.violations == []
        res[v_thr] = st
    assert res[0.02].block_time_value > res[10.0].block_time_value
    assert res[0.02].max_divergence <= res[10.0].max_divergence + 1e-9
    assert res[0.02].sim_time > res[10.0].sim_time   # consistency costs time


def test_sqrt_decay_schedule():
    from repro.optim.schedule import sqrt_decay
    import jax.numpy as jnp
    fn = sqrt_decay(2.0)
    assert np.isclose(float(fn(jnp.asarray(0))), 2.0)
    assert np.isclose(float(fn(jnp.asarray(3))), 1.0)
