"""Autoscaler policy + control loop (PR 7 tentpole).

``Autoscaler.decide`` is a pure function of one :class:`RuntimeMetrics`
snapshot, so the policy matrix (split / drain / replica up / replica
drain-after-patience / shed hysteresis) is unit-tested on synthetic
metrics with no runtime at all.  The integration leg then runs a real
queue-mode runtime under Zipf-skewed load and asserts the loop actually
rebalances while the run's exactness guarantees hold (the full matrix —
policies x wire transports — lives in ``test_chaos.py``).
"""
import numpy as np
import pytest

from repro.core import policies
from repro.runtime import (Autoscaler, AutoscalePolicy, GatewayMetrics,
                           MembershipMetrics, PSRuntime, RunMetrics,
                           RuntimeConfig, RuntimeMetrics, ShardMetrics,
                           SnapshotMetrics)

# ---------------------------------------------------------------------------
# synthetic metrics builders
# ---------------------------------------------------------------------------


def _shard(sid, active=True, rows_per_s=0.0, lock_wait=0.0):
    return ShardMetrics(
        sid=sid, active=active, epoch=0, inbox_depth=0, parts_applied=0,
        rows_applied=0, bytes_applied=0, apply_lock_wait_s=lock_wait,
        applied_parts=[], clock_min=0, pub_pending=0, pub_drops=0,
        pub_resyncs=0, publish_lag_s=0.0, updates_per_s=rows_per_s,
        rows_per_s=rows_per_s)


def _gateway(escalation_rate=0.0, reads_per_s=100.0, n_live=1,
             shedding=False):
    return GatewayMetrics(
        n_reads=0, n_replica_reads=0, n_master_reads=0, n_escalations=0,
        n_shed=0, n_cache_hits=0, reads_by_slo={}, max_served_staleness=0,
        block_time=0.0, reads_per_replica={}, shedding_fresh=shedding,
        n_live_replicas=n_live, reads_per_s=reads_per_s,
        escalations_per_s=escalation_rate * reads_per_s,
        escalation_rate=escalation_rate)


def _metrics(shards, gateways=(), window_s=1.0):
    return RuntimeMetrics(
        t=0.0, wall_s=10.0, window_s=window_s, clock=5, transport="queue",
        metrics_enabled=True,
        run=RunMetrics(0, 0, 0, 0, 0, 0.0, 0.0, 0, 0.0, 0.0, 0.0, 0),
        membership=MembershipMetrics(epoch=0, active=tuple(
            s.sid for s in shards if s.active), n_slots=len(shards), n_ops=0),
        snapshots=SnapshotMetrics(0, 0, -1),
        shards=list(shards), gateways=list(gateways))


def _mk(policy=None):
    """A decide()-only Autoscaler: no runtime, no thread, just policy
    state (prev lock-wait, per-gateway patience counters)."""
    asc = Autoscaler.__new__(Autoscaler)
    asc.policy = policy or AutoscalePolicy()
    asc._prev_lock_wait = 0.0
    asc._gw_state = {}
    return asc


# ---------------------------------------------------------------------------
# decide(): the policy matrix on synthetic snapshots
# ---------------------------------------------------------------------------


def test_decide_splits_hot_shard():
    asc = _mk(AutoscalePolicy(split_imbalance=1.5, split_min_rows_s=100.0))
    m = _metrics([_shard(0, rows_per_s=900.0), _shard(1, rows_per_s=100.0),
                  _shard(2, active=False), _shard(3, active=False)])
    assert ("add_shard",) in asc.decide(m)


def test_decide_no_split_at_capacity_or_below_min_load():
    asc = _mk(AutoscalePolicy(split_imbalance=1.5, split_min_rows_s=100.0,
                              max_shards=2, drain_max_rows_s=0.0))
    hot = _metrics([_shard(0, rows_per_s=900.0), _shard(1, rows_per_s=100.0)])
    assert asc.decide(hot) == []                       # at capacity
    asc2 = _mk(AutoscalePolicy(split_imbalance=1.5, split_min_rows_s=1000.0,
                               drain_max_rows_s=0.0))
    cool = _metrics([_shard(0, rows_per_s=90.0), _shard(1, rows_per_s=10.0),
                     _shard(2, active=False)])
    assert asc2.decide(cool) == []                     # imbalanced but idle


def test_decide_drains_coldest_when_mean_low():
    asc = _mk(AutoscalePolicy(drain_max_rows_s=50.0, min_shards=1))
    m = _metrics([_shard(0, rows_per_s=30.0), _shard(1, rows_per_s=2.0)])
    assert ("remove_shard", 1) in asc.decide(m)
    asc2 = _mk(AutoscalePolicy(drain_max_rows_s=50.0, min_shards=2))
    assert asc2.decide(m) == []                        # respects the floor


def test_decide_scales_replicas_on_escalation_rate():
    pol = AutoscalePolicy(escalation_hi=0.15, max_replicas=3,
                          min_window_reads=5)
    asc = _mk(pol)
    m = _metrics([_shard(0, rows_per_s=10.0)],
                 [_gateway(escalation_rate=0.4, n_live=1)])
    assert ("add_replica", 0) in asc.decide(m)
    m_cap = _metrics([_shard(0, rows_per_s=10.0)],
                     [_gateway(escalation_rate=0.4, n_live=3)])
    assert ("add_replica", 0) not in _mk(pol).decide(m_cap)
    # a tiny read window is noise, never a scaling signal
    m_noise = _metrics([_shard(0, rows_per_s=10.0)],
                       [_gateway(escalation_rate=1.0, reads_per_s=1.0)])
    assert _mk(pol).decide(m_noise) == []


def test_decide_drains_replica_after_patience_calm_windows():
    pol = AutoscalePolicy(escalation_lo=0.01, drain_patience=3,
                          min_replicas=1)
    asc = _mk(pol)
    calm = _metrics([_shard(0, rows_per_s=10.0)],
                    [_gateway(escalation_rate=0.0, n_live=2)])
    assert asc.decide(calm) == []
    assert asc.decide(calm) == []
    assert ("remove_replica", 0) in asc.decide(calm)   # third calm window
    # a busy window in between resets the patience counter
    asc2 = _mk(pol)
    busy = _metrics([_shard(0, rows_per_s=10.0)],
                    [_gateway(escalation_rate=0.05, n_live=2)])
    asc2.decide(calm), asc2.decide(calm), asc2.decide(busy)
    assert asc2.decide(calm) == []
    # and the floor holds: one live replica is never drained
    asc3 = _mk(pol)
    floor = _metrics([_shard(0, rows_per_s=10.0)],
                     [_gateway(escalation_rate=0.0, n_live=1)])
    asc3.decide(floor), asc3.decide(floor)
    assert asc3.decide(floor) == []


def test_decide_shed_fresh_hysteresis():
    pol = AutoscalePolicy(shed_lock_wait_frac=0.25, drain_max_rows_s=0.0)
    asc = _mk(pol)
    hot = _metrics([_shard(0, rows_per_s=500.0, lock_wait=0.4)],
                   [_gateway()], window_s=1.0)
    assert ("shed_fresh", 0, True) in asc.decide(hot)  # 0.4/1.0 > 0.25
    # wait still growing at 0.2/window: inside the hysteresis band
    # (0.125..0.25) — neither engaged again nor released
    mid = _metrics([_shard(0, rows_per_s=500.0, lock_wait=0.6)],
                   [_gateway(shedding=True)], window_s=1.0)
    assert [d for d in asc.decide(mid) if d[0] == "shed_fresh"] == []
    # fully calm (no new wait): released only below half the threshold
    calm = _metrics([_shard(0, rows_per_s=500.0, lock_wait=0.6)],
                    [_gateway(shedding=True)], window_s=1.0)
    assert ("shed_fresh", 0, False) in asc.decide(calm)


# ---------------------------------------------------------------------------
# integration: the loop rebalances a real skewed run
# ---------------------------------------------------------------------------


def test_autoscaler_rebalances_live_runtime():
    import sys
    sys.path.insert(0, "tests")
    from chaos import chaos_autoscale_policy, expected_final, x0, zipf_fn

    import time

    seed, n_clocks = 91, 60
    fn = zipf_fn(seed)
    rt = PSRuntime(RuntimeConfig(4, policies.ssp(3), x0(), n_shards=2,
                                 threads_per_process=2, seed=seed,
                                 max_shards=4))
    rt.start(fn, n_clocks, timeout=60.0)
    # pump the control loop deterministically from the test thread (the
    # thread-driven variant is exercised by the chaos suite): one poll per
    # 10ms while the run is live, which outlives the cooldown window
    asc = Autoscaler(rt, policy=chaos_autoscale_policy())
    while rt.running and rt.completed_clock() < n_clocks:
        asc.step()
        time.sleep(0.01)
    stats = rt.wait()
    assert stats.violations == [], stats.violations[:5]
    summary = asc.summary()
    assert summary.get("add_shard", 0) + summary.get("remove_shard", 0) >= 1, (
        summary, asc.actions)
    for k, ref in expected_final(seed, 4, n_clocks, fn=fn).items():
        np.testing.assert_array_equal(rt.master_value(k).reshape(ref.shape),
                                      ref)
    # every recorded action carries an outcome; failures only ever come
    # from ops racing the quiesce, never from a raised exception
    assert all(isinstance(a.ok, bool) for a in asc.actions)
