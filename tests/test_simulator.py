"""Integration tests of the event-driven async parameter-server simulator.

These assert the PAPER's guarantees hold under adversarial conditions
(slow network + straggler): staleness bound, VAP unsynced bound, weak/strong
divergence bounds, FIFO, read-my-writes, eventual consistency — and the
headline systems claim that relaxed consistency beats BSP throughput.
"""
import numpy as np
import pytest

from repro.core import (AsyncPS, NetworkModel, bsp, cap, cvap, ssp, theory,
                        vap)

SLOW_NET = dict(base_delay=0.8, jitter=0.5, seed=3)


def sgd_update_fn(lr=0.05, noise=0.5, dim=3):
    target = np.linspace(-2, 2, dim)

    def fn(w, clock, view, rng):
        x = view.get("x")
        g = 2 * (x - target) + rng.normal(0, noise, dim)
        return {"x": -lr * g}
    return fn


def run(policy, P=8, clocks=30, straggler=True, seed=1, tpp=1):
    ps = AsyncPS(P, policy, {"x": np.zeros(3)},
                 network=NetworkModel(**SLOW_NET),
                 straggler={0: 2.0} if straggler else None,
                 threads_per_process=tpp, seed=seed)
    stats = ps.run(sgd_update_fn(), clocks, divergence_every=0.5)
    return ps, stats


def test_no_violations_any_policy():
    for pol in [bsp(), ssp(2), cap(2), vap(0.08), vap(0.08, strong=True),
                cvap(2, 0.08), cvap(2, 0.08, strong=True)]:
        _, st = run(pol)
        assert st.violations == [], (pol, st.violations)


def test_bsp_zero_staleness():
    _, st = run(bsp())
    assert st.max_observed_staleness == 0


def test_staleness_bounded_by_s():
    for s in (1, 3):
        _, st = run(cap(s))
        assert st.max_observed_staleness <= s


def test_vap_unsynced_bound_holds():
    pol = vap(0.08)
    _, st = run(pol)
    assert st.max_unsynced_mag <= max(st.max_update_mag, 0.08) + 1e-9


def test_weak_vap_divergence_bound():
    pol = vap(0.08)
    _, st = run(pol, P=8)
    bound = theory.weak_vap_divergence_bound(st.max_update_mag, 0.08, 8)
    assert st.max_divergence <= bound + 1e-9


def test_strong_vap_divergence_bound_independent_of_P():
    pol = vap(0.08, strong=True)
    _, st = run(pol, P=8, clocks=20)
    bound = theory.strong_vap_divergence_bound(st.max_update_mag, 0.08)
    assert st.max_divergence <= bound + 1e-9
    # the strong bound must be far below the weak one at P=8
    assert bound < theory.weak_vap_divergence_bound(st.max_update_mag, 0.08, 8)


def test_relaxed_consistency_faster_than_bsp():
    """The paper's headline systems claim."""
    _, st_bsp = run(bsp())
    _, st_ssp = run(ssp(3))
    _, st_vap = run(vap(0.5))
    assert st_ssp.throughput > st_bsp.throughput
    assert st_vap.throughput > st_bsp.throughput


def test_cap_blocks_less_than_bsp():
    _, st_bsp = run(bsp())
    _, st_cap = run(cap(3))
    assert st_cap.block_time_clock < st_bsp.block_time_clock


def test_strong_vap_blocks_more_than_weak():
    _, st_w = run(vap(0.08), clocks=20)
    _, st_s = run(vap(0.08, strong=True), clocks=20)
    assert st_s.block_time_value >= st_w.block_time_value


def test_eventual_consistency_and_master():
    ps, st = run(cvap(2, 0.1))
    assert st.violations == []
    total = ps.master_value("x")
    for q in range(ps.n_proc):
        np.testing.assert_allclose(ps.views[q]["x"], total, atol=1e-8)


def test_fifo_delivery_order():
    ps, st = run(cap(4), P=4, clocks=15)
    # per (sender, receiver) pair, delivery seq numbers strictly increase —
    # checked online by the simulator; a violation would be recorded
    assert not any("FIFO" in v for v in st.violations)


def test_read_my_writes():
    """A worker's view reflects its own updates immediately."""
    applied = []

    def fn(w, clock, view, rng):
        x = view.get("x")
        if w == 0 and clock > 0:
            # previous own update must be visible even if unsynchronized
            assert x[0] >= 0.99 * clock, (x, clock)
        if w == 0:
            applied.append(clock)
            return {"x": np.array([1.0, 0.0, 0.0])}
        return {"x": np.zeros(3)}

    ps = AsyncPS(4, vap(50.0), {"x": np.zeros(3)},
                 network=NetworkModel(base_delay=5.0, seed=0), seed=0)
    ps.run(fn, 5)


def test_threads_per_process_share_cache():
    ps, st = run(cap(2), P=8, tpp=2)
    assert ps.n_proc == 4
    assert st.violations == []


def test_deterministic_given_seed():
    _, s1 = run(cvap(2, 0.1), seed=7)
    _, s2 = run(cvap(2, 0.1), seed=7)
    assert s1.sim_time == s2.sim_time
    assert s1.n_messages == s2.n_messages
    assert s1.max_divergence == s2.max_divergence


def test_ssp_defers_messages_cap_does_not():
    """SSP sends only at clock boundaries; CAP pushes asap — with the same
    updates the message COUNT matches but CAP's first delivery is earlier."""
    ps_ssp, _ = run(ssp(2), P=4, clocks=10, straggler=False)
    ps_cap, _ = run(cap(2), P=4, clocks=10, straggler=False)
    t_first_ssp = min(u.t_created for u in ps_ssp.updates if u.seq == 0)
    first_ssp = min(u.t_fully_delivered for u in ps_ssp.updates)
    first_cap = min(u.t_fully_delivered for u in ps_cap.updates)
    assert first_cap <= first_ssp
