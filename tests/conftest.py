import json
import os
import re
import subprocess
import sys
from dataclasses import asdict

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
ARTIFACTS = os.path.join(REPO, "test-artifacts")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """On a chaos-suite assertion failure, dump the failing runtime's trace
    export + metrics snapshot to test-artifacts/<test>/ (CI uploads the
    directory from the chaos-smoke job) — a red chaos run ships its own
    post-mortem instead of just a seed number."""
    outcome = yield
    rep = outcome.get_result()
    if rep.when != "call" or not rep.failed or "chaos" not in item.keywords:
        return
    try:
        import chaos
    except ImportError:
        return
    rt = getattr(chaos, "LAST_RT", None)
    if rt is None:
        return
    name = re.sub(r"[^A-Za-z0-9._-]+", "_",
                  item.nodeid.split("::", 1)[-1])
    outdir = os.path.join(ARTIFACTS, name)
    os.makedirs(outdir, exist_ok=True)
    try:
        if getattr(rt, "trace_on", False):
            rt.dump_trace(os.path.join(outdir, "trace.json"))
        with open(os.path.join(outdir, "metrics.json"), "w") as f:
            json.dump(asdict(rt.metrics()), f, indent=2, default=str)
    except BaseException as e:      # artifact capture must never mask the
        with open(os.path.join(outdir, "artifact-error.txt"), "w") as f:
            f.write(repr(e))        # original failure
    else:
        rep.sections.append(
            ("chaos artifacts", f"trace + metrics written to {outdir}"))


def run_devices_subprocess(code: str, n_devices: int = 8,
                           timeout: int = 600) -> str:
    """Run `code` in a subprocess with N fake host devices.

    The dry-run flag must be set before jax initializes, so multi-device
    tests run out-of-process (the main test process keeps 1 device, per the
    brief)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def devices8():
    return lambda code, **kw: run_devices_subprocess(code, 8, **kw)
