import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices_subprocess(code: str, n_devices: int = 8,
                           timeout: int = 600) -> str:
    """Run `code` in a subprocess with N fake host devices.

    The dry-run flag must be set before jax initializes, so multi-device
    tests run out-of-process (the main test process keeps 1 device, per the
    brief)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def devices8():
    return lambda code, **kw: run_devices_subprocess(code, 8, **kw)
