"""Unit tests for the Petuum-PS table abstraction (core/tables.py) — the
storage layer the threaded runtime's server shards are built on."""
import numpy as np
import pytest

from repro.core.tables import Row, SparseRow, Table, TableGroup


# ---------------------------------------------------------------------------
# SparseRow zero-elision
# ---------------------------------------------------------------------------


def test_sparse_row_inc_elides_zeros_per_column():
    r = SparseRow()
    r.inc(2.5, col=3)
    assert r.get(3) == 2.5
    r.inc(-2.5, col=3)                 # back to zero -> entry must vanish
    assert r.get(3) == 0.0
    assert 3 not in r.cols
    assert r.cols == {}


def test_sparse_row_inc_elides_zeros_dict_delta():
    r = SparseRow()
    r.inc({0: 1.0, 1: -2.0, 5: 4.0})
    r.inc({0: -1.0, 1: 2.0, 5: 1.0})   # cancels cols 0 and 1 exactly
    assert r.cols == {5: 5.0}
    assert r.get() == {5: 5.0}
    # a delta of zero on a fresh column must not materialize an entry
    r.inc(0.0, col=7)
    assert 7 not in r.cols


# ---------------------------------------------------------------------------
# hash partitioning
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_servers", [1, 2, 3, 5])
def test_server_partition_covers_every_row_exactly_once(n_servers):
    t = Table("wt", n_cols=4)
    row_ids = [0, 1, 2, 7, 8, 13, 29, 100]
    for rid in row_ids:
        t.inc(rid, np.full(4, float(rid)))
    parts = [t.server_partition(n_servers, s) for s in range(n_servers)]
    seen = [rid for p in parts for rid in p]
    assert sorted(seen) == sorted(row_ids)          # no row lost, none twice
    for s, p in enumerate(parts):
        assert all(rid % n_servers == s for rid in p)
        for rid, row in p.items():                   # partition returns the
            assert row is t.row(rid)                 # live rows, not copies


def test_server_partition_matches_runtime_sharding():
    """The runtime's shard-row assignment is the same rule as
    Table.server_partition — one partitioning scheme everywhere."""
    from repro.runtime import PSRuntime, RuntimeConfig
    from repro.core import policies

    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), {"a": np.zeros((7, 3))}, n_shards=3))
    t = Table("a", n_cols=3)
    for r in range(7):
        t.inc(r, np.zeros(3))
    for s in range(3):
        assert sorted(rt._shard_rows["a"][s].tolist()) == sorted(
            t.server_partition(3, s))


# ---------------------------------------------------------------------------
# TableGroup
# ---------------------------------------------------------------------------


def test_table_group_duplicate_id_raises():
    g = TableGroup()
    g.create("wt", n_cols=8)
    with pytest.raises(KeyError, match="already exists"):
        g.create("wt", n_cols=8)
    # the original table survives the failed create
    assert "wt" in g
    assert g["wt"].n_cols == 8


def test_table_group_policy_map_and_iteration():
    g = TableGroup()
    g.create("wt", n_cols=4, policy="vap")
    g.create("tc", n_cols=4, sparse=True)
    assert g.policies == {"wt": "vap"}
    assert {t.table_id for t in g} == {"wt", "tc"}
    assert isinstance(g["tc"].row(0), SparseRow)
    assert isinstance(g["wt"].row(0), Row)


def test_dense_snapshot_round_trip_sparse_and_dense():
    dense = Table("d", n_cols=3)
    sparse = Table("s", n_cols=3, sparse=True)
    ref = np.zeros((4, 3))
    for rid, col, v in [(0, 1, 2.0), (2, 0, -1.5), (3, 2, 4.0)]:
        dense.row(rid).inc(v, col=col)
        sparse.inc(rid, v, col=col)
        ref[rid, col] = v
    np.testing.assert_array_equal(dense.dense_snapshot(4), ref)
    np.testing.assert_array_equal(sparse.dense_snapshot(4), ref)
