"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
tables, vector clocks, client cache."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import Table, TableGroup, ThreadCache, VectorClock
from repro.data import SyntheticLM, batches, synthetic_corpus
from repro.optim import adam, init_opt_state, momentum, sgd
from repro.optim.schedule import cosine, linear_warmup, constant


def test_sgd_direction():
    params = {"w": jnp.ones(3)}
    g = {"w": jnp.array([1.0, -2.0, 0.0])}
    st = init_opt_state(params, "sgd")
    upd, st = sgd(g, st, lr=0.1)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1, 0.2, 0.0])


def test_momentum_accumulates():
    params = {"w": jnp.zeros(1)}
    st = init_opt_state(params, "momentum")
    g = {"w": jnp.ones(1)}
    u1, st = momentum(g, st, lr=1.0, beta=0.5)
    u2, st = momentum(g, st, lr=1.0, beta=0.5)
    assert float(u2["w"][0]) == pytest.approx(-1.5)   # 1 + 0.5*1


def test_adam_matches_reference_math():
    params = {"w": jnp.zeros(1)}
    st = init_opt_state(params, "adam")
    g = {"w": jnp.full(1, 0.5)}
    upd, st = adam(g, st, lr=0.01, b1=0.9, b2=0.999, eps=1e-8)
    # first step: mhat = g, vhat = g^2 -> update = -lr * g/|g| = -lr
    assert float(upd["w"][0]) == pytest.approx(-0.01, rel=1e-4)


def test_adam_converges_quadratic():
    x = jnp.array([5.0, -3.0])
    st = init_opt_state(x, "adam")
    for _ in range(300):
        g = 2 * x
        upd, st = adam(g, st, lr=0.1)
        x = x + upd
    assert float(jnp.max(jnp.abs(x))) < 0.05


def test_schedules():
    fn = linear_warmup(1.0, 10, constant(1.0))
    assert float(fn(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(fn(jnp.asarray(20))) == pytest.approx(1.0)
    cf = cosine(1.0, 100, final_frac=0.1)
    assert float(cf(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cf(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_pipeline_deterministic_and_sharded():
    src = SyntheticLM(512, seed=3)
    b1 = next(batches(src, 4, 32, shard=0, n_shards=2))
    b2 = next(batches(src, 4, 32, shard=0, n_shards=2))
    b3 = next(batches(src, 4, 32, shard=1, n_shards=2))
    np.testing.assert_array_equal(b1["ids"], b2["ids"])   # deterministic
    assert not np.array_equal(b1["ids"], b3["ids"])       # disjoint shards
    assert b1["ids"].shape == (4, 32)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["ids"][:, 1:])
    assert b1["ids"].max() < 512 and b1["ids"].min() >= 0


def test_pipeline_has_structure():
    """Bigram structure must make the corpus compressible (non-uniform)."""
    src = SyntheticLM(256, seed=0)
    toks = src.sample_tokens(5000, stream=0)
    _, counts = np.unique(toks, return_counts=True)
    freq = counts / counts.sum()
    entropy = -(freq * np.log(freq)).sum()
    assert entropy < 0.9 * np.log(256)


def test_lda_corpus():
    c = synthetic_corpus(n_docs=20, vocab_size=100, n_topics=5, doc_len=50)
    assert c.n_docs == 20
    assert all(d.max() < 100 for d in c.docs)
    assert c.n_tokens > 20 * 10


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}, "lst": [jnp.zeros(2)]}
    d = str(tmp_path)
    save_checkpoint(d, 5, tree, metadata={"note": "x"})
    save_checkpoint(d, 9, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(d) == 9
    restored, step = restore_checkpoint(d, tree)
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) + 1)
    restored5, _ = restore_checkpoint(d, tree, step=5)
    np.testing.assert_allclose(np.asarray(restored5["b"]["c"]),
                               np.asarray(tree["b"]["c"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.zeros(4)})


def test_vector_clock():
    vc = VectorClock(3)
    vc.tick(0), vc.tick(0), vc.tick(1)
    assert vc.min() == 0 and vc.max() == 2
    with pytest.raises(ValueError):
        vc.set(0, 0)


def test_tables_dense_sparse():
    g = TableGroup()
    t = g.create("wt", n_cols=4)
    t.inc(7, np.ones(4))
    t.inc(7, 2.0, col=1)
    np.testing.assert_allclose(t.get(7), [1, 3, 1, 1])
    s = g.create("sparse", n_cols=0, sparse=True)
    s.inc(0, 1.5, col=9)
    s.inc(0, -1.5, col=9)       # zero-removal
    assert s.get(0) == {}
    assert "wt" in g
    part = t.server_partition(n_servers=2, server=1)
    assert all(rid % 2 == 1 for rid in part)


def test_thread_cache_read_my_writes():
    class FakeView:
        def get(self, key):
            return np.zeros(3)
    c = ThreadCache(FakeView())
    c.inc("x", np.array([1.0, 0, 0]))
    np.testing.assert_allclose(c.get("x"), [1, 0, 0])   # own write visible
    c.inc("x", np.array([0, 2.0, 0]))
    np.testing.assert_allclose(c.get("x"), [1, 2, 0])
    out = c.flush()
    np.testing.assert_allclose(out["x"], [1, 2, 0])     # coalesced
    assert c.flush() == {}
