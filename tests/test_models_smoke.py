"""Per-architecture smoke tests (required by the brief): a REDUCED variant of
each assigned family runs one forward and one train step on CPU; output
shapes and finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ConsistencySpec, TrainConfig, reduced_config
from repro.launch import steps
from repro.launch.state import init_train_state
from repro.models import model as M
from repro.models.common import ShardCtx, instantiate_tree

ARCH_IDS = sorted(ARCHS)


def _cfg(name):
    cfg = reduced_config(name)
    return dataclasses.replace(cfg, dtype="float32")


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s + 1)), jnp.int32)
    batch = {"ids": ids[:, :-1], "labels": ids[:, 1:]}
    if cfg.frontend is not None:
        batch["extra_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.frontend.n_embeds, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    batch = _batch(cfg)
    x, _, aux = M.forward(cfg, ctx, params, batch["ids"],
                          extra_emb=batch.get("extra_emb"), remat=False)
    assert x.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(x).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite_and_decreases(arch):
    cfg = _cfg(arch)
    tcfg = TrainConfig(arch=arch, optimizer="adam", lr=1e-3, warmup_steps=0,
                       consistency=ConsistencySpec(model="bsp"))
    state = init_train_state(cfg, tcfg, tp=1, dp=1, key=jax.random.key(0))
    step = steps.make_train_step(cfg, tcfg, mesh=None, donate=False)
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses        # overfits one batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_unroll_matches_scan(arch):
    cfg = _cfg(arch)
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(1))
    batch = _batch(cfg, seed=3)
    x_scan, _, _ = M.forward(cfg, ctx, params, batch["ids"],
                             extra_emb=batch.get("extra_emb"), remat=False)
    x_unroll, _, _ = M.forward(cfg, ctx, params, batch["ids"],
                               extra_emb=batch.get("extra_emb"), remat=False,
                               unroll=True)
    np.testing.assert_allclose(np.asarray(x_scan), np.asarray(x_unroll),
                               atol=2e-5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_matches_no_remat_gradients(arch):
    cfg = _cfg(arch)
    ctx = ShardCtx()
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(2))
    batch = _batch(cfg, seed=4)

    def loss(p, remat):
        l, _ = M.lm_loss(cfg, ctx, p, batch["ids"], batch["labels"],
                         extra_emb=batch.get("extra_emb"), remat=remat)
        return l

    g1 = jax.grad(lambda p: loss(p, True))(params)
    g2 = jax.grad(lambda p: loss(p, False))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)
