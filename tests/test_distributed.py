"""Multi-device tests (8 fake host devices, out-of-process so the main test
session keeps 1 device as the brief requires)."""
import pytest

# every test here compiles SPMD programs in an 8-device subprocess — minutes,
# not seconds.  Quick loop: -m "not slow"; tier-1 stays the full suite.
pytestmark = pytest.mark.slow


def test_forward_parity_dist_vs_local(devices8):
    """Distributed (tp=2, dp=4) forward == single-device, all strategies."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.launch import mesh as mesh_lib, specs as S
from repro.models.common import instantiate_tree, pspec_tree, ShardCtx
from repro.models import model as M
from jax.sharding import PartitionSpec as P

mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
for arch in ["qwen3-8b", "gemma2-2b", "mamba2-130m", "recurrentgemma-9b",
             "deepseek-v2-lite-16b", "olmoe-1b-7b"]:
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    defs = M.model_defs(cfg, 2)
    params = jax.device_put(instantiate_tree(defs, jax.random.key(0)),
                            S.shardings(pspec_tree(defs), mesh))
    ctx = ShardCtx(model_axis="model", dp_axes=("data",), tp=2)
    ids = jnp.asarray(np.random.default_rng(0).integers(1, cfg.vocab_size, (8, 16)), jnp.int32)
    def fwd(p, ids):
        x, _, _ = M.forward(cfg, ctx, p, ids, remat=False)
        return ctx.gather_seq(x) if cfg.tp_strategy in ("head", "seq") else x
    f = jax.jit(mesh_lib.shard_map(fwd, mesh=mesh,
                in_specs=(pspec_tree(defs), P("data", None)),
                out_specs=P("data", None, None)))
    xd = f(params, ids)
    params1 = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    xl, _, _ = M.forward(cfg, ShardCtx(), params1, ids, remat=False)
    err = float(jnp.max(jnp.abs(xd - xl)))
    assert err < 2e-4, (arch, err)
    print(arch, "OK", err)
""")
    assert out.count("OK") == 6


def test_train_step_first_loss_parity(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config, ConsistencySpec, TrainConfig
from repro.launch import mesh as mesh_lib, steps, specs as S
from repro.launch.state import init_train_state, init_local_state, add_dp_axis

mesh = mesh_lib.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
tcfg = TrainConfig(arch="olmo-1b", optimizer="adam", lr=1e-3, warmup_steps=0,
                   consistency=ConsistencySpec(model="bsp"))
rng = np.random.default_rng(0)
ids = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)), jnp.int32)
batch = {"ids": ids[:, :-1], "labels": ids[:, 1:]}
state = init_train_state(cfg, tcfg, tp=2, dp=4, key=jax.random.key(0))
state_spec = S.resolve_tree(S.train_state_pspecs(cfg, tcfg, 2), ("pod", "data"))
state = jax.device_put(state, S.shardings(state_spec, mesh))
fn = steps.make_train_step(cfg, tcfg, mesh, donate=False)
_, md = fn(state, batch)

st1 = add_dp_axis(init_local_state(cfg, tcfg, tp=1, key=jax.random.key(0)), 1)
fn1 = steps.make_train_step(cfg, tcfg, None, donate=False)
_, ml = fn1(st1, batch)
err = abs(float(md["loss"]) - float(ml["loss"]))
assert err < 2e-4, (float(md["loss"]), float(ml["loss"]))
print("OK", err)
""")
    assert "OK" in out


def test_bsp_replicas_stay_identical_vap_bounded(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config, ConsistencySpec, TrainConfig
from repro.launch import mesh as mesh_lib, steps, specs as S
from repro.launch.state import init_train_state
from repro.core import policies
from repro.core.sync import vap_invariant_ok

mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
rng = np.random.default_rng(0)
def batch():
    ids = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)), jnp.int32)
    return {"ids": ids[:, :-1], "labels": ids[:, 1:]}

for model, s, v in [("bsp", 0, 0.0), ("cvap", 3, 0.02)]:
    tcfg = TrainConfig(arch="olmo-1b", optimizer="adam", lr=1e-3, warmup_steps=0,
                       consistency=ConsistencySpec(model=model, staleness=s, value_bound=v))
    state = init_train_state(cfg, tcfg, tp=2, dp=4, key=jax.random.key(0))
    spec = S.resolve_tree(S.train_state_pspecs(cfg, tcfg, 2), ("data",))
    state = jax.device_put(state, S.shardings(spec, mesh))
    fn = steps.make_train_step(cfg, tcfg, mesh, donate=False)
    for i in range(5):
        state, m = fn(state, batch())
    # replica divergence: max over leaves of per-dp spread
    div = max(float(jnp.max(jnp.abs(x - x[0:1]))) for x in jax.tree.leaves(state.params))
    if model == "bsp":
        assert div < 1e-5, div
        print("BSP identical OK", div)
    else:
        pol = policies.from_spec(tcfg.consistency)
        sync0 = jax.tree.map(lambda x: x[0], state.sync)
        assert bool(vap_invariant_ok(pol, sync0)), "VAP invariant violated"
        print("CVAP bounded OK", div)
""")
    assert "BSP identical OK" in out and "CVAP bounded OK" in out


def test_serve_parity(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config, InputShape
from repro.launch import mesh as mesh_lib, steps, specs as S
from repro.models.common import instantiate_tree, pspec_tree, ShardCtx
from repro.models import model as M

mesh = mesh_lib.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ["gemma2-2b", "musicgen-medium", "mamba2-130m"]:
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    defs = M.model_defs(cfg, 2)
    params = jax.device_put(instantiate_tree(defs, jax.random.key(0)),
                            S.shardings(pspec_tree(defs), mesh))
    shape = InputShape("p", seq_len=16, global_batch=8, mode="prefill")
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 16)), jnp.int32)
    batch = {"ids": ids}
    if cfg.frontend:
        batch["extra_emb"] = jnp.asarray(rng.normal(0,.01,(8, cfg.frontend.n_embeds, cfg.d_model)), jnp.float32)
    nxt, caches = steps.make_prefill_step(cfg, mesh, shape)(params, batch)
    nxt2, _ = steps.make_serve_step(cfg, mesh, shape)(params, caches,
        {"ids": nxt[:, None], "pos": jnp.full((8,), 16, jnp.int32)})
    params1 = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    ctx1 = ShardCtx()
    l1, c1 = M.prefill(cfg, ctx1, params1, ids, capacity=16, extra_emb=batch.get("extra_emb"))
    n1 = jnp.argmax(l1, -1).astype(jnp.int32)
    l2, _ = M.decode_step(cfg, ctx1, params1, n1[:, None], jnp.full((8,), 16, jnp.int32), c1)
    n2 = jnp.argmax(l2, -1)
    assert bool(jnp.all(nxt == n1)) and bool(jnp.all(nxt2 == n2)), arch
    print(arch, "OK")
""")
    assert out.count("OK") == 3


def test_hierarchical_and_compressed_sync(devices8):
    """Beyond-paper options lower and run on a pod×data×model mesh."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config, ConsistencySpec, TrainConfig
from repro.launch import mesh as mesh_lib, steps, specs as S
from repro.launch.state import init_train_state
mesh = mesh_lib.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
rng = np.random.default_rng(0)
ids = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 17)), jnp.int32)
batch = {"ids": ids[:, :-1], "labels": ids[:, 1:]}
tcfg = TrainConfig(arch="olmo-1b", optimizer="adam", lr=1e-3, warmup_steps=0,
                   consistency=ConsistencySpec(model="cap", staleness=1),
                   quantize_sync=True, hierarchical_sync=2)
state = init_train_state(cfg, tcfg, tp=2, dp=4, key=jax.random.key(0))
spec = S.resolve_tree(S.train_state_pspecs(cfg, tcfg, 2), ("pod", "data"))
state = jax.device_put(state, S.shardings(spec, mesh))
fn = steps.make_train_step(cfg, tcfg, mesh, donate=False)
losses = []
for i in range(6):
    state, m = fn(state, batch)
    losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("OK", losses[0], losses[-1])
""")
    assert "OK" in out


def test_gradient_scale_calibration(devices8):
    """The universal grad rule — (psum if replicated else id)/tp — must make
    distributed per-leaf gradients match single-device gradients at ratio 1.0
    for every TP strategy (this caught a tp× seed-multiplicity bug)."""
    out = devices8(_GRAD_CAL_CODE)
    assert out.count("RATIO_OK") == 4, out


_GRAD_CAL_CODE = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.launch import mesh as mesh_lib, specs as S
from repro.models.common import instantiate_tree, pspec_tree, ShardCtx, ParamDef
from repro.models import model as M
from jax.sharding import PartitionSpec as P
import jax.tree_util as jtu

mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
tp = 4
for arch, strategy in [("olmo-1b", None), ("gemma2-2b", None),
                       ("mamba2-130m", None), ("mamba2-130m", "seq_ssm")]:
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    if strategy: cfg = dataclasses.replace(cfg, tp_strategy=strategy)
    defs = M.model_defs(cfg, tp)
    params = jax.device_put(instantiate_tree(defs, jax.random.key(0)),
                            S.shardings(pspec_tree(defs), mesh))
    ctx = ShardCtx(model_axis="model", dp_axes=("data",), tp=tp)
    rep_mask = jax.tree.map(lambda d: "model" not in (d.shard or ()), defs,
                            is_leaf=lambda x: isinstance(x, ParamDef))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 32)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    def loss_d(p, i, l):
        return M.lm_loss(cfg, ctx, p, i, l, remat=False)[0]
    def grad_fn(p, i, l):
        g = jax.grad(loss_d)(p, i, l)
        g = jax.tree.map(lambda x, rep: (jax.lax.psum(x, "model") if rep else x) / tp,
                         g, rep_mask)
        return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
    g = jax.jit(mesh_lib.shard_map(grad_fn, mesh=mesh,
                in_specs=(pspec_tree(defs), P("data", None), P("data", None)),
                out_specs=pspec_tree(defs)))(params, ids, labels)
    params1 = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))
    def loss_l(p):
        return M.lm_loss(cfg, ShardCtx(), p, ids, labels, remat=False)[0]
    gl = jax.grad(loss_l)(params1)
    flat_l = {jtu.keystr(p): np.asarray(x) for p, x in jtu.tree_flatten_with_path(gl)[0]}
    for path, leaf in jtu.tree_flatten_with_path(g)[0]:
        k = jtu.keystr(path)
        a = np.asarray(jax.device_get(leaf)); b = flat_l.get(k)
        if b is None or a.shape != b.shape or np.abs(b).max() < 1e-7: continue
        r = float((a * b).sum() / (b * b).sum())
        assert abs(r - 1) < 5e-3, (arch, strategy, k, r)
    print("RATIO_OK", arch, strategy)
"""
