"""End-to-end tracing tier (repro.runtime.trace).

Four claims under test:

* **Exact reconciliation** — with ``sample=1.0`` and no ring drops, the
  trace is not an approximation: after quiesce, ``send_part`` events equal
  the authoritative ``rt._parts_sent`` total and ``apply_part`` events
  equal the per-shard ``applied_parts`` audit — over queue, shm and tcp
  alike (proc-mode rings ship back over the existing ProcDone pipe).

* **Perfetto export is well-formed** — ``rt.dump_trace`` writes valid
  Chrome trace-event JSON whose update lifelines span client -> shard
  (``send_part`` flow-start / ``apply_part`` flow-end on the same id) and
  shard -> replica (``publish_part`` / ``ingest_part``).

* **The audit APIs name the culprit** — a deliberately wedged replica
  forces an escalation and ``rt.explain_read`` names the exact lagging
  ``(shard, proc)`` vector-clock cell the gateway measured.

* **Timestamp discipline** — the runtime's hot paths use monotonic clocks
  only (no ``time.time()`` anywhere in the runtime package), so events
  from forked children land on the parent's timeline.
"""
import json
import os

import numpy as np
import pytest

from repro.core import policies
from repro.runtime import (PSRuntime, ReadGateway, RuntimeConfig, TraceConfig,
                           explain_read)
from repro.runtime import trace as trace_mod


def _x0():
    return {"a": np.zeros((8, 4)), "b": np.ones(6)}


def _fn(seed):
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock))
        return {"a": r.integers(-2, 3, size=(8, 4)).astype(float),
                "b": r.integers(-2, 3, size=6).astype(float)}
    return fn


def _run(transport, n_workers=2, n_clocks=6, **kw):
    rt = PSRuntime(RuntimeConfig(n_workers, policies.ssp(2), _x0(),
                                 n_shards=2, transport=transport, **kw))
    rt.start(_fn(7), n_clocks, timeout=60.0)
    stats = rt.wait()
    return rt, stats


# ---------------------------------------------------------------------------
# config normalization
# ---------------------------------------------------------------------------


def test_trace_config_normalization():
    norm = trace_mod.normalize_trace
    assert norm(None) is None
    assert norm(False) is None
    assert norm(True) == TraceConfig()
    assert norm(0.25).sample == 0.25
    assert norm({"sample": 0.5, "capacity": 1024}) == TraceConfig(0.5, 1024)
    cfg = TraceConfig(sample=0.1)
    assert norm(cfg) is cfg
    with pytest.raises(ValueError):
        norm(0.0)                          # sample out of (0, 1]
    with pytest.raises(ValueError):
        norm(1.5)
    with pytest.raises(ValueError):
        norm({"sample": 1.0, "capacity": 16})   # ring too small
    with pytest.raises(ValueError):
        norm({"bogus": 1})
    with pytest.raises(ValueError):
        norm("yes")
    # RuntimeConfig validates eagerly at construction
    with pytest.raises(ValueError):
        RuntimeConfig(2, policies.ssp(1), _x0(), trace=2.0)


def test_trace_off_by_default():
    rt, _ = _run("queue")
    assert rt._trace is None and not rt.trace_on
    with pytest.raises(RuntimeError, match="tracing is off"):
        rt.dump_trace("/dev/null")
    # explain_read stays usable without tracing: it is a pure function of
    # the ReadResult stamps
    with ReadGateway(rt, n_replicas=1) as gw:
        info = rt.explain_read(gw.read("a", slo=None))
    assert info["source"].startswith(("replica", "master", "cache"))


# ---------------------------------------------------------------------------
# exact reconciliation with the PR-7 counter audit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["queue", "shm", "tcp"])
def test_trace_reconciles_exactly_after_quiesce(transport):
    rt, stats = _run(transport, trace=True)
    hub = rt._trace
    assert hub.dropped() == 0
    counts = hub.counts()
    sent = int(rt._parts_sent.sum())
    applied = sum(int(s.applied_parts.sum()) for s in rt.shards)
    # zero lost / zero duplicated update parts, now visible per-event: at
    # sample=1.0 every part's send and its post-dedup apply were recorded
    assert counts.get(trace_mod.EV_SEND, 0) == sent
    assert counts.get(trace_mod.EV_APPLY_PART, 0) == applied == sent
    # every layer recorded: client flush + clock, shard batch/apply
    for kind in (trace_mod.EV_FLUSH, trace_mod.EV_CLOCK,
                 trace_mod.EV_SHARD_BATCH, trace_mod.EV_APPLY):
        assert counts.get(kind, 0) > 0, trace_mod._NAMES[kind]
    if transport in ("shm", "tcp"):
        # wire events recorded on both the write and the decode side
        assert counts.get(trace_mod.EV_WIRE_WRITE, 0) > 0
        assert counts.get(trace_mod.EV_WIRE_DECODE, 0) > 0
        # forked/threaded client rings were adopted into the parent hub
        procs = {r["proc"] for r in hub.all_rings()}
        assert any(p.startswith("client-") for p in procs), procs
    # the metrics tree reports the tracing tier
    m = rt.metrics()
    assert m.trace_enabled and m.trace_dropped == 0


def test_trace_sampling_subsets_lifelines():
    rt, _ = _run("queue", trace={"sample": 0.25})
    counts = rt._trace.counts()
    sent_all = int(rt._parts_sent.sum())
    sent_traced = counts.get(trace_mod.EV_SEND, 0)
    # sampled lifelines are a strict subset, but send and apply agree
    # exactly on WHICH uids were sampled (deterministic uid hash)
    assert sent_traced < sent_all
    assert counts.get(trace_mod.EV_APPLY_PART, 0) == sent_traced
    # unsampled spans (flush, apply, batch) still record at full rate
    assert counts.get(trace_mod.EV_APPLY, 0) > 0


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------


def test_dump_trace_is_valid_chrome_json_with_lifelines(tmp_path):
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2,
                                 transport="queue", trace=True))
    # subscribe BEFORE the run so deltas stream to the replica and the
    # shard->replica lifelines exist in the export
    with ReadGateway(rt, n_replicas=1) as gw:
        rt.start(_fn(7), 6, timeout=60.0)
        rt.wait()
        gw.read("a", slo=0)
        path = tmp_path / "trace.json"
        info = rt.dump_trace(str(path))
    assert info["path"] == str(path) and info["dropped"] == 0
    doc = json.loads(path.read_text())     # valid JSON, Perfetto-loadable
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    slices = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 1.0 and e["ts"] >= 0.0 for e in slices)
    # one process_name per proc label, one thread_name per ring
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    # update lifelines: every flow start ("s") binds a flow end ("f") on
    # the same id — client->shard (send/apply) and shard->replica
    # (publish/ingest) both present
    starts = {e["id"] for e in evs if e["ph"] == "s"}
    ends = {e["id"] for e in evs if e["ph"] == "f"}
    bound = starts & ends
    assert bound, "no bound lifelines in the export"
    update_ids = {i for i in bound if not (i >> 62)}
    publish_ids = {i for i in bound if i >> 62}
    assert update_ids, "no client->shard lifeline"
    assert publish_ids, "no shard->replica lifeline"
    assert all(e.get("bp") == "e" for e in evs if e["ph"] == "f")


# ---------------------------------------------------------------------------
# consistency audit trails
# ---------------------------------------------------------------------------


def test_explain_read_names_the_lagging_pair(tmp_path):
    """A deliberately wedged replica forces an escalation; explain_read
    names the exact (shard slot, process) vector-clock cell that trailed
    the master frontier furthest."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2,
                                 transport="queue", trace=True))
    gw = ReadGateway(rt, n_replicas=1, transport="shm")
    rset = gw.replicas
    rset.wedge(0)                          # stop draining before any delta
    rt.start(_fn(3), 6, timeout=60.0)
    rt.wait()
    try:
        res = gw.read("a", slo=0, timeout=0.4)
        assert res.escalated and res.source == "master"
        # the run quiesced and the replica is still wedged: both vcs are
        # frozen, so the gateway's measurement is exactly reproducible
        rep = rset.replicas[0]
        gap = rset.master_vc() - rep.vc
        s, p = np.unravel_index(int(gap.argmax()), gap.shape)
        expect = (int(s), int(p))
        info = rt.explain_read(res)
        assert info["escalated"] and info["lagging"] == expect
        assert info["vc_gap"] == max(int(gap.max()), 0) > 0
        assert f"shard {expect[0]}" in info["summary"]
        assert f"process {expect[1]}" in info["summary"]
        # the escalation and the park both left trace events
        counts = rt._trace.counts()
        assert counts.get(trace_mod.EV_ESCALATE, 0) >= 1
        assert counts.get(trace_mod.EV_READ, 0) >= 1
        # module-level helper agrees with the method
        assert explain_read(res) == info
    finally:
        rset.wedge(0, wedged=False)
        gw.close()


def test_explain_block_attributes_stalls():
    rt, stats = _run("queue", trace=True)
    info = rt.explain_block()
    assert info["n_blocks"] == len(list(
        rt._trace.events((trace_mod.EV_BLOCK_CLOCK,
                          trace_mod.EV_BLOCK_VALUE))))
    # recorded block time is bounded by the stats' own accounting (spans
    # only exist when tracing saw the wait happen)
    assert info["clock_blocked_s"] <= stats.block_time_clock + 0.5
    if info["by_straggler"]:
        assert info["straggler"] in range(rt.n_proc)
        assert "straggler" in info["summary"]
    # filtered views only shrink
    one = rt.explain_block(process=0)
    assert one["n_blocks"] <= info["n_blocks"]


def test_staleness_timeline_reconstructs_replica_lag():
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(2), _x0(), n_shards=2,
                                 transport="queue", trace=True))
    with ReadGateway(rt, n_replicas=1) as gw:
        rt.start(_fn(7), 6, timeout=60.0)
        rt.wait()
        gw.read("a", slo=0)
        tl = rt.staleness_timeline(0)
    assert tl["shard"] == 0
    assert tl["bound"] == rt.policy.staleness  # ssp: clock-bounded
    assert tl["points"], "no replica_vc adoptions recorded for shard 0"
    for t_s, rid, lag in tl["points"]:
        assert t_s >= 0.0 and rid >= 0 and lag >= 0
    assert tl["max_staleness"] == max(p[2] for p in tl["points"])
    # points are time-ordered (sorted on the shared monotonic timeline)
    ts = [p[0] for p in tl["points"]]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# timestamp discipline
# ---------------------------------------------------------------------------


def test_runtime_package_uses_monotonic_clocks_only():
    """Events from forked children must land on the parent's timeline:
    CLOCK_MONOTONIC is system-wide on Linux, wall clocks are not — so no
    runtime module may call time.time()."""
    import repro.runtime as pkg
    root = os.path.dirname(pkg.__file__)
    offenders = []
    for dirpath, _dirs, files in os.walk(root):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                if "time.time(" in f.read():
                    offenders.append(os.path.relpath(path, root))
    assert not offenders, f"wall-clock use on runtime paths: {offenders}"
