"""Snapshot/restore of the PS runtime's master shard state.

The failover story: updates are additive, so a server killed after clock
``a`` and restarted from its snapshot must land on exactly the state of an
uninterrupted run — asserted against the simulator as the spec.
"""
import numpy as np
import pytest

from repro.core import AsyncPS, NetworkModel, policies
from repro.runtime import PSRuntime, RuntimeConfig, load_snapshot, save_snapshot, snapshot_params


def _x0():
    return {"a": np.arange(32, dtype=float).reshape(8, 4) / 2.0,
            "b": np.ones(5)}


def _sched_fn(seed, shift=0):
    def fn(w, clock, view, rng):
        r = np.random.default_rng((seed, w, clock + shift))
        return {"a": r.integers(-3, 4, size=(8, 4)).astype(float),
                "b": r.integers(-3, 4, size=5).astype(float)}
    return fn


def test_snapshot_resume_equals_uninterrupted_run():
    """Run 6 clocks, snapshot, resume a fresh runtime for 6 more: final
    master == simulator's 12-clock final state (kill/rejoin semantics)."""
    sim = AsyncPS(4, policies.ssp(2), _x0(), threads_per_process=2, seed=0,
                  network=NetworkModel(seed=0))
    sim.run(_sched_fn(0), 12)

    rt_a = PSRuntime(RuntimeConfig(4, policies.ssp(2), _x0(), n_shards=2,
                     threads_per_process=2, seed=0))
    rt_a.run(_sched_fn(0), 6, timeout=60)
    snap = rt_a.snapshot()

    rt_b = PSRuntime(RuntimeConfig(4, policies.ssp(2), _x0(), n_shards=2,
                     threads_per_process=2, seed=0, restore_from=snap))
    st = rt_b.run(_sched_fn(0, shift=6), 6, timeout=60)
    assert st.violations == []
    for k, ref in sim.views[0].items():
        np.testing.assert_array_equal(rt_b.master_value(k).reshape(ref.shape),
                                      ref, err_msg=f"resumed master[{k}]")


def test_snapshot_file_roundtrip(tmp_path):
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    rt.run(_sched_fn(1), 4, timeout=60)
    snap = rt.snapshot()
    path = tmp_path / "shards.npz"
    save_snapshot(path, snap)
    loaded = load_snapshot(path)
    assert loaded["n_shards"] == 2
    assert loaded["shapes"] == {"a": (8, 4), "b": (5,)}
    for sid in range(2):
        for key in ("a", "b"):
            np.testing.assert_array_equal(
                loaded["shards"][sid][key]["values"],
                snap["shards"][sid][key]["values"])
    # and the assembled params equal the quiesced master
    params = snapshot_params(loaded)
    for k in params:
        np.testing.assert_array_equal(params[k], rt.master_value(k))


def test_killed_shard_rejoins_from_snapshot():
    """A replacement shard adopts the snapshot partition via load_state."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2))
    rt.run(_sched_fn(2), 5, timeout=60)
    snap = rt.snapshot()

    rt2 = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2))
    for key in rt2.shards[1].dense:           # "the shard process was killed"
        rt2.shards[1].dense[key][...] = np.nan
    rt2.shards[0].load_state(snap["shards"][0])
    rt2.shards[1].load_state(snap["shards"][1])
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt2.master_value(k), rt.master_value(k))


def test_restore_repartitions_across_different_n_shards():
    """restore_from reassembles the master, so the shard count may change
    between the killed and the resumed server."""
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    rt.run(_sched_fn(3), 4, timeout=60)
    snap = rt.snapshot()
    rt3 = PSRuntime(RuntimeConfig(3, policies.bsp(), _x0(), n_shards=3,
                    threads_per_process=1, restore_from=snap))
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt3.master_value(k), rt.master_value(k))


def test_restore_rejects_mismatched_shapes_and_keys():
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    rt.run(_sched_fn(4), 2, timeout=60)
    snap = rt.snapshot()
    with pytest.raises(ValueError, match="keys"):
        PSRuntime(RuntimeConfig(2, policies.bsp(), {"a": np.zeros((8, 4))}, n_shards=2,
                  restore_from=snap))
    with pytest.raises(ValueError, match="shape"):
        PSRuntime(RuntimeConfig(2, policies.bsp(),
                  {"a": np.zeros((8, 5)), "b": np.zeros(5)}, n_shards=2,
                  restore_from=snap))
    bad = {**snap, "version": 99}
    with pytest.raises(ValueError, match="version"):
        PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2, restore_from=bad))


def test_periodic_snapshots_on_clock_boundaries(tmp_path):
    """PSRuntime(snapshot_every=k): the shard thread that moves the applied
    frontier across a multiple of k takes a snapshot (boundary-triggered),
    stamps it with the per-shard vector clocks, and persists it."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2, seed=6,
                   snapshot_every=3, snapshot_dir=str(tmp_path)))
    st = rt.run(_sched_fn(6), 9, timeout=60)
    assert st.violations == []
    clocks = [c for c, _ in rt.snapshots]
    assert clocks, "no periodic snapshot was taken"
    assert clocks == sorted(set(clocks)), "snapshot clocks must be monotone"
    assert clocks[-1] == 9, "the final boundary (all clocks applied) fires"
    # vc stamping: each snapshot carries per-shard applied vector clocks
    latest = rt.latest_snapshot()
    assert latest is not None and latest["n_proc"] == 2
    assert len(latest["clock_vcs"]) == 2
    assert all(int(vc.min()) == 8 for vc in latest["clock_vcs"])
    assert latest["clock"] == 9
    # persisted to disk, and the vc survives the npz round-trip
    files = sorted(tmp_path.glob("snap_c*.npz"))
    assert len(files) == len(clocks)
    loaded = load_snapshot(files[-1])
    for vc_disk, vc_mem in zip(loaded["clock_vcs"], latest["clock_vcs"]):
        np.testing.assert_array_equal(vc_disk, vc_mem)
    assert loaded["clock"] == 9 and loaded["n_proc"] == 2
    # a periodic snapshot is restorable like any other
    rt2 = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=3, restore_from=latest))
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt2.master_value(k), rt.master_value(k))


def test_shard_load_state_rejects_wrong_partition():
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    snap = rt.snapshot()
    with pytest.raises(ValueError, match="partition"):
        rt.shards[0].load_state(snap["shards"][1])


# ---------------------------------------------------------------------------
# re-partition edge cases (ISSUE 5 satellites)
# ---------------------------------------------------------------------------


def test_restore_shrinks_to_one_shard():
    """Everything funnels onto a single shard: the degenerate partition."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=3))
    rt.run(_sched_fn(7), 4, timeout=60)
    snap = rt.snapshot()
    rt1 = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=1,
                    restore_from=snap))
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt1.master_value(k), rt.master_value(k))
    # and the shrunken runtime still runs clean
    st = rt1.run(_sched_fn(7, shift=4), 3, timeout=60)
    assert st.violations == []


def test_restore_grows_with_empty_key_ranges():
    """8 shards for a 5-row key: three shards own zero rows of "b" — empty
    dense blocks must restore, apply, snapshot, and read back cleanly."""
    rt = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=2))
    rt.run(_sched_fn(8), 4, timeout=60)
    snap = rt.snapshot()
    rt8 = PSRuntime(RuntimeConfig(2, policies.bsp(), _x0(), n_shards=8,
                    restore_from=snap))
    assert sum(rt8.partition.rows_of("b", s).size for s in range(8)) == 5
    assert any(rt8.partition.rows_of("b", s).size == 0 for s in range(8))
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt8.master_value(k), rt.master_value(k))
    st = rt8.run(_sched_fn(8, shift=4), 3, timeout=60)
    assert st.violations == []
    snap8 = rt8.snapshot()
    assert snap8["n_shards"] == 8
    for k in ("a", "b"):
        np.testing.assert_array_equal(
            snapshot_params(snap8)[k], rt8.master_value(k))


def test_restore_under_different_n_proc():
    """A snapshot from a 2-process run restores into a 3-process runtime:
    master values re-partition exactly; the vc seed degrades conservatively
    (conservative_vc falls back to the all -1 vector clock)."""
    from repro.runtime import conservative_vc

    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2,
                   threads_per_process=1))
    rt.run(_sched_fn(9), 5, timeout=60)
    snap = rt.snapshot()
    assert snap["n_proc"] == 2
    rt3 = PSRuntime(RuntimeConfig(3, policies.ssp(1), _x0(), n_shards=2,
                    threads_per_process=1, restore_from=snap))
    assert rt3.n_proc == 3
    for k in ("a", "b"):
        np.testing.assert_array_equal(rt3.master_value(k), rt.master_value(k))
    vc = conservative_vc(snap, n_shards=2, n_proc=3)
    assert vc.shape == (2, 3) and (vc == -1).all()
    st = rt3.run(_sched_fn(9, shift=5), 3, timeout=60)
    assert st.violations == []


def test_tampered_vc_snapshot_refused():
    """A snapshot whose vector-clock stamps were corrupted must be refused
    with a clear error — a bad vc would let a serving replica stamp stale
    values as fresh."""
    rt = PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2))
    rt.run(_sched_fn(10), 4, timeout=60)
    snap = rt.snapshot()

    wrong_shape = {**snap, "clock_vcs": [vc[:1] for vc in snap["clock_vcs"]]}
    with pytest.raises(ValueError, match="malformed"):
        PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2,
                  restore_from=wrong_shape))

    wrong_dtype = {**snap,
                   "clock_vcs": [vc.astype(float) for vc in snap["clock_vcs"]]}
    with pytest.raises(ValueError, match="malformed"):
        PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2,
                  restore_from=wrong_dtype))

    huge = [vc.copy() for vc in snap["clock_vcs"]]
    huge[0][0] = 1 << 50
    with pytest.raises(ValueError, match="tampered"):
        PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2,
                  restore_from={**snap, "clock_vcs": huge}))

    off_by_one = [vc + 1 for vc in snap["clock_vcs"]]   # frontier shifted:
    # the stamped clock no longer matches the vcs' implied frontier
    with pytest.raises(ValueError, match="contradicts"):
        PSRuntime(RuntimeConfig(2, policies.ssp(1), _x0(), n_shards=2,
                  restore_from={**snap, "clock_vcs": off_by_one}))

    # the same validation guards the serving-tier bootstrap path
    from repro.runtime import conservative_vc
    with pytest.raises(ValueError, match="malformed"):
        conservative_vc(wrong_shape, n_shards=2, n_proc=2)
