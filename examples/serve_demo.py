"""Serving demo: prefill a batch of prompts, then batched greedy decode with
ring KV caches — the same prefill/serve steps the multi-pod dry-run lowers.

    PYTHONPATH=src python examples/serve_demo.py [--arch gemma2-2b]

With ``--ps``, serve reads from the *live parameter server* instead: workers
stream SGD-style updates through the sharded runtime under a
bounded-asynchronous policy while the main thread plays a serving client,
issuing reads through the **read-replica gateway**
(:mod:`repro.runtime.serving`) under a per-read staleness SLO and reporting
latency, measured staleness, and escalations as the table converges.

    PYTHONPATH=src python examples/serve_demo.py --ps [--policy ssp3] \
        [--replicas 2] [--slo 3]

``--slo`` is the per-read contract: an integer ``k`` means "at most ``k``
clocks behind the master's applied vector clock" (the gateway serves from
the cheapest replica whose vector clock qualifies, parks on a doorbell when
none does, and escalates to the locked master shards at the deadline);
``fresh`` sends every read to the master.  Every response is stamped with
the staleness actually measured against the live vector clock, so the
histogram printed at the end is of *observed* staleness, not requested.
``--replicas 0`` bypasses the gateway and reads the live master shards
directly (the pre-serving-tier behavior, useful as a baseline).

Running the runtime across processes
------------------------------------

``--transport`` picks where the client processes live:

* ``queue`` (default) — worker threads inside this interpreter;
* ``proc`` / ``shm`` / ``tcp`` — every client process is a real forked OS
  process; per-row updates travel as batched multi-row frames over
  shared-memory rings (``shm``, the ``proc`` default) or loopback sockets
  (``tcp``), and the GIL no longer couples workers to each other or to the
  serving tier.

The replica publish streams ride the matching serving transport (queue ->
in-process channels, proc/shm -> shm rings + doorbells, tcp -> loopback
sockets); the same frames and FIFO seq assertions as the write path.

``--trace out.json`` records the whole run with the end-to-end tracing
tier (:mod:`repro.runtime.trace`) and exports Chrome trace-event JSON on
exit — open the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` to see every layer as its own track, with update
lifelines arcing client -> shard -> replica and reads/escalations on the
gateway track.
"""
import argparse
import dataclasses
import time

import numpy as np


def run_ps_demo(args) -> None:
    from repro.core import bsp, cvap, ssp, vap
    from repro.runtime import FRESH, PSRuntime, ReadGateway, RuntimeConfig

    policy = {"bsp": bsp(), "ssp3": ssp(3), "vap": vap(0.05),
              "cvap": cvap(3, 0.05)}[args.policy]
    dim, n_workers, n_clocks = 256, args.workers, args.clocks
    rng = np.random.default_rng(0)
    A = rng.normal(0, 1, (128, dim)) / np.sqrt(dim)
    y = A @ rng.normal(0, 1, dim)

    def update_fn(w, clock, view, wrng):
        x = view.get("x")
        i = wrng.integers(0, len(y), 16)
        g = (A[i].T @ (A[i] @ x - y[i])) / len(i)
        return {"x": -0.2 * g}

    slo = args.slo if args.slo == FRESH else int(args.slo)
    serving = {"queue": "queue", "proc": "shm", "shm": "shm",
               "tcp": "tcp"}[args.transport]
    rt = PSRuntime(RuntimeConfig(n_workers, policy, {"x": np.zeros(dim)}, n_shards=2,
                   threads_per_process=1, seed=0, transport=args.transport,
                   trace=bool(args.trace) or None))
    print(f"serving from live PS runtime: {n_workers} workers, "
          f"policy {policy.kind}, {n_clocks} clocks, "
          f"transport {args.transport}, {args.replicas} replicas "
          f"({serving} publish streams), slo {slo!r}")
    rt.start(update_fn, n_clocks, timeout=300)
    gw = (ReadGateway(rt, n_replicas=args.replicas, transport=serving)
          if args.replicas > 0 else None)
    lat, stale, esc = [], [], 0
    t_next = time.perf_counter()
    while rt.running:
        t0 = time.perf_counter()
        if gw is None:
            x = rt.read("x")               # locked live master read
        else:
            res = gw.read("x", slo=slo, timeout=5.0)
            x, _ = res.value, stale.append(res.staleness)
            esc += res.escalated
        lat.append(time.perf_counter() - t0)
        if time.perf_counter() >= t_next:
            obj = float(0.5 * np.mean((A @ x - y) ** 2))
            print(f"  t+{len(lat):5d} reads  objective {obj:.5f}")
            t_next = time.perf_counter() + 0.5
        time.sleep(1e-3)
    stats = rt.wait()
    x_final = (gw.read("x", slo=0, timeout=10).value if gw is not None
               else rt.read("x"))
    q = np.quantile(np.asarray(lat), [0.5, 0.95]) if lat else [0.0, 0.0]
    obj = float(0.5 * np.mean((A @ x_final - y) ** 2))
    print(f"done: {stats.n_updates} updates in {stats.sim_time:.2f}s "
          f"({stats.n_updates / stats.sim_time:.0f} upd/s), "
          f"final objective {obj:.5f}")
    print(f"reads: {len(lat)} served, p50 {q[0]*1e6:.0f}us, "
          f"p95 {q[1]*1e6:.0f}us; violations: {len(stats.violations)}")
    if gw is not None:
        hist = np.bincount(np.asarray(stale, dtype=int) if stale else [0])
        print(f"staleness observed (clocks->reads): "
              f"{dict(enumerate(hist.tolist()))}; escalations {esc}; "
              f"per-replica {gw.stats.reads_per_replica}")
        gw.close()
    if args.trace:
        info = rt.dump_trace(args.trace)
        print(f"trace: {info['events']} events -> {info['path']} "
              f"({info['dropped']} dropped; open in Perfetto / "
              f"chrome://tracing)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ps", action="store_true",
                    help="serve reads from the live threaded PS runtime")
    ap.add_argument("--policy", default="ssp3",
                    choices=["bsp", "ssp3", "vap", "cvap"])
    ap.add_argument("--transport", default="queue",
                    choices=["queue", "proc", "shm", "tcp"],
                    help="queue = threads in-process; proc/shm/tcp = forked "
                         "client processes over the wire (see docstring)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--clocks", type=int, default=150)
    ap.add_argument("--replicas", type=int, default=2,
                    help="read replicas behind the gateway (0 = read the "
                         "locked master shards directly, no serving tier)")
    ap.add_argument("--slo", default="3",
                    help='per-read staleness SLO: an integer k (clocks '
                         'behind the master vector clock) or "fresh"')
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the run with the end-to-end tracing tier "
                         "and export Perfetto-loadable Chrome trace JSON "
                         "here on exit (--ps mode)")
    args = ap.parse_args()
    if args.ps:
        run_ps_demo(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, InputShape, reduced_config
    from repro.launch import steps
    from repro.models import model as M
    from repro.models.common import instantiate_tree

    if args.arch not in ARCHS:
        ap.error(f"unknown arch {args.arch!r} (choose from "
                 f"{', '.join(sorted(ARCHS))})")

    cfg = dataclasses.replace(reduced_config(args.arch), dtype="float32")
    print(f"serving reduced {args.arch}: {cfg.n_layers}L d{cfg.d_model}")
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))

    shape = InputShape("demo", seq_len=args.prompt_len + args.gen,
                       global_batch=args.batch, mode="prefill")
    prefill_fn = steps.make_prefill_step(cfg, None, shape)
    serve_fn = steps.make_serve_step(cfg, None, shape)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    batch = {"ids": prompts}
    if cfg.frontend is not None:
        batch["extra_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.frontend.n_embeds,
                                 cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    nxt, caches = prefill_fn(params, batch)
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0

    generated = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for j in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + j, jnp.int32)
        nxt, caches = serve_fn(params, caches, {"ids": nxt[:, None], "pos": pos})
        generated.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, 1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode  {args.gen - 1} steps: {t_decode*1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / t_decode:.0f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"seq {i}: prompt …{np.asarray(prompts[i, -6:]).tolist()} -> "
              f"generated {gen[i, :10].tolist()}…")


if __name__ == "__main__":
    main()
