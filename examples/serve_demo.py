"""Serving demo: prefill a batch of prompts, then batched greedy decode with
ring KV caches — the same prefill/serve steps the multi-pod dry-run lowers.

    PYTHONPATH=src python examples/serve_demo.py [--arch gemma2-2b]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, InputShape, reduced_config
from repro.launch import steps
from repro.models import model as M
from repro.models.common import instantiate_tree


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCHS))
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced_config(args.arch), dtype="float32")
    print(f"serving reduced {args.arch}: {cfg.n_layers}L d{cfg.d_model}")
    params = instantiate_tree(M.model_defs(cfg, 1), jax.random.key(0))

    shape = InputShape("demo", seq_len=args.prompt_len + args.gen,
                       global_batch=args.batch, mode="prefill")
    prefill_fn = steps.make_prefill_step(cfg, None, shape)
    serve_fn = steps.make_serve_step(cfg, None, shape)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    batch = {"ids": prompts}
    if cfg.frontend is not None:
        batch["extra_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.frontend.n_embeds,
                                 cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    nxt, caches = prefill_fn(params, batch)
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0

    generated = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for j in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + j, jnp.int32)
        nxt, caches = serve_fn(params, caches, {"ids": nxt[:, None], "pos": pos})
        generated.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, 1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode  {args.gen - 1} steps: {t_decode*1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / t_decode:.0f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"seq {i}: prompt …{np.asarray(prompts[i, -6:]).tolist()} -> "
              f"generated {gen[i, :10].tolist()}…")


if __name__ == "__main__":
    main()
