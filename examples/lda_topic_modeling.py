"""The paper's evaluation (§5): LDA topic modeling on the asynchronous
parameter server, comparing consistency models on the same corpus.

Reproduces the shape of the paper's results: relaxed consistency (VAP/CAP)
finishes the same number of Gibbs sweeps in less simulated wall time than
BSP, at comparable model quality — and the strong-scaling curve approaches
linear (Fig. 5).

    PYTHONPATH=src python examples/lda_topic_modeling.py
"""
import numpy as np

from repro.apps import lda
from repro.core import NetworkModel, bsp, cap, vap
from repro.data import synthetic_corpus


def main() -> None:
    corpus = synthetic_corpus(n_docs=48, vocab_size=150, n_topics=6,
                              doc_len=60, seed=0)
    print(f"corpus: {corpus.n_docs} docs, {corpus.n_tokens} tokens, "
          f"vocab {corpus.vocab_size} (20News-shaped, scaled down)")

    print("\n--- consistency models, 8 workers, straggler ×2 ---")
    print(f"{'policy':10s} {'sim_time':>9s} {'LL start':>10s} {'LL final':>10s}")
    for name, pol in [("bsp", bsp()), ("cap_s2", cap(2)), ("vap", vap(30.0))]:
        lls, stats = lda.run_lda(
            corpus, n_topics=6, policy=pol, n_workers=8, n_clocks=6,
            seed=0, network=NetworkModel(base_delay=0.4, jitter=0.3, seed=1),
            straggler={0: 2.0}, collect_stats=True)
        print(f"{name:10s} {stats.sim_time:9.1f} {lls[0]:10.0f} {lls[-1]:10.0f}"
              f"   (blocked: clock {stats.block_time_clock:.0f}s,"
              f" value {stats.block_time_value:.0f}s)")

    print("\n--- strong scaling under VAP (paper Fig. 5) ---")
    for P in (4, 8, 16):
        lls, stats = lda.run_lda(
            corpus, n_topics=6, policy=vap(30.0), n_workers=P, n_clocks=4,
            seed=0, network=NetworkModel(base_delay=0.15, jitter=0.1, seed=0),
            collect_stats=True)
        thr = corpus.n_tokens * 4 / stats.sim_time
        print(f"P={P:3d}: {thr:8.0f} tokens/s  (ideal x{P / 4:.0f} over P=4)")


if __name__ == "__main__":
    main()
