"""Compare all five consistency models end-to-end on LM training — the
SPMD layer (drifting replicas + triggered delta all-reduce) on one device,
plus the simulator's throughput story for the same policies.

    PYTHONPATH=src python examples/consistency_comparison.py
"""
import dataclasses

import numpy as np

from repro.configs import ConsistencySpec, TrainConfig, reduced_config
from repro.core import AsyncPS, NetworkModel, bsp, cap, cvap, ssp, vap
from repro.launch.train import run

POLICIES = [
    ("bsp", "bsp", 0, 0.0),
    ("ssp(3)", "ssp", 3, 0.0),
    ("cap(3)", "cap", 3, 0.0),
    ("vap(.05)", "vap", 0, 0.05),
    ("cvap(3,.05)", "cvap", 3, 0.05),
]


def lm_comparison() -> None:
    print("--- LM training under each consistency model (CPU, reduced olmo) ---")
    cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")
    print(f"{'policy':14s} {'loss@0':>8s} {'loss@40':>8s} {'sync epochs':>12s}")
    for label, model, s, v in POLICIES:
        tcfg = TrainConfig(arch="olmo-1b", steps=40, lr=2e-3, optimizer="adam",
                           log_every=39,
                           consistency=ConsistencySpec(model=model,
                                                       staleness=s,
                                                       value_bound=v))
        state, hist = run(tcfg, cfg, mesh=None, batch_size=8, seq_len=64,
                          log=lambda *_: None)
        syncs = int(np.asarray(state.sync.sync_count).reshape(-1)[0])
        print(f"{label:14s} {hist[0]['loss']:8.3f} {hist[-1]['loss']:8.3f} "
              f"{syncs:12d}")


def throughput_comparison() -> None:
    print("\n--- async PS simulator: throughput under slow net + straggler ---")
    target = np.linspace(-1, 1, 4)

    def fn(w, clock, view, rng):
        x = view.get("x")
        return {"x": -0.1 * (x - target) + rng.normal(0, 0.02, 4)}

    print(f"{'policy':14s} {'clocks/s':>9s} {'divergence':>11s} {'staleness':>10s}")
    for label, pol in [("bsp", bsp()), ("ssp(3)", ssp(3)), ("cap(3)", cap(3)),
                       ("vap(.05)", vap(0.05)), ("cvap(3,.05)", cvap(3, 0.05))]:
        ps = AsyncPS(8, pol, {"x": np.zeros(4)},
                     network=NetworkModel(base_delay=0.6, jitter=0.4, seed=3),
                     straggler={0: 2.0}, seed=1)
        st = ps.run(fn, 30, divergence_every=1.0)
        print(f"{label:14s} {st.throughput:9.3f} {st.max_divergence:11.4f} "
              f"{st.max_observed_staleness:10d}")


if __name__ == "__main__":
    lm_comparison()
    throughput_comparison()
