"""Quickstart: train a small LM under a bounded-asynchronous consistency
model, watch the sync epochs fire, checkpoint the synchronized state.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

from repro.checkpoint import latest_step
from repro.configs import ConsistencySpec, TrainConfig, reduced_config
from repro.launch.train import run


def main() -> None:
    # the reduced OLMo variant runs on CPU; swap for get_config("olmo-1b")
    # and a production mesh on real hardware
    cfg = dataclasses.replace(reduced_config("olmo-1b"), dtype="float32")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(
            arch="olmo-1b",
            steps=60,
            lr=2e-3,
            optimizer="adam",
            log_every=10,
            # the paper's CVAP: sync when 4 steps pass OR any replica's
            # unsynchronized updates exceed 0.05 — whichever first
            consistency=ConsistencySpec(model="cvap", staleness=4,
                                        value_bound=0.05),
            checkpoint_dir=ckpt_dir,
        )
        _, history = run(tcfg, cfg, mesh=None, batch_size=8, seq_len=64)
        print(f"\nfinal loss: {history[-1]['loss']:.4f} "
              f"(from {history[0]['loss']:.4f})")
        print(f"checkpoint written at step {latest_step(ckpt_dir)}")
        assert history[-1]["loss"] < history[0]["loss"]


if __name__ == "__main__":
    main()
