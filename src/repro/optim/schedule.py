"""Learning-rate schedules (pure functions of the i32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int, base):
    base_fn = base

    def fn(step):
        t = step.astype(jnp.float32)
        scale = jnp.minimum(1.0, (t + 1.0) / max(warmup, 1))
        return scale * base_fn(step)
    return fn


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(
            lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))),
            jnp.float32)
    return fn


def sqrt_decay(sigma: float):
    """The paper's Theorem-1 step size η_t = σ/√t (t is 1-based)."""
    def fn(step):
        return jnp.asarray(sigma, jnp.float32) / jnp.sqrt(step.astype(jnp.float32) + 1.0)
    return fn
