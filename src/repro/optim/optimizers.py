"""Optimizers from scratch (no optax): SGD, momentum, Adam.

Each optimizer produces an *update* (the paper's ``u``) from gradients; the
consistency layer (repro.core.sync) applies it locally and decides when to
synchronize.  Optimizer state is per-replica, like the parameters — the
paper's asynchronous workers each run their own optimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    mu: PyTree                    # first moment (momentum/adam) or empty
    nu: PyTree                    # second moment (adam) or empty
    count: jnp.ndarray            # i32 step counter


def init_opt_state(params: PyTree, kind: str, dtype=None) -> OptState:
    """dtype: storage dtype for the moments (bf16 halves optimizer HBM)."""
    zeros = lambda: jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), params)
    empty = jax.tree.map(lambda x: jnp.zeros((), x.dtype), params)
    if kind == "sgd":
        return OptState(mu=empty, nu=empty, count=jnp.zeros((), jnp.int32))
    if kind == "momentum":
        return OptState(mu=zeros(), nu=empty, count=jnp.zeros((), jnp.int32))
    if kind == "adam":
        return OptState(mu=zeros(), nu=zeros(), count=jnp.zeros((), jnp.int32))
    raise ValueError(f"unknown optimizer {kind!r}")


def sgd(grads: PyTree, state: OptState, lr, **_) -> Tuple[PyTree, OptState]:
    upd = jax.tree.map(lambda g: -lr * g, grads)
    return upd, dataclasses.replace(state, count=state.count + 1)


def momentum(grads: PyTree, state: OptState, lr, beta: float = 0.9,
             **_) -> Tuple[PyTree, OptState]:
    mu = jax.tree.map(lambda m, g: beta * m + g, state.mu, grads)
    upd = jax.tree.map(lambda m: -lr * m, mu)
    return upd, dataclasses.replace(state, mu=mu, count=state.count + 1)


def adam(grads: PyTree, state: OptState, lr, b1: float = 0.9,
         b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, params: PyTree = None,
         ) -> Tuple[PyTree, OptState]:
    cnt = state.count + 1
    t = cnt.astype(jnp.float32)
    # compute in the grad dtype (f32), store back in the moment dtype
    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: (b2 * v.astype(g.dtype)
                      + (1 - b2) * jnp.square(g)).astype(v.dtype),
        state.nu, grads)
    bc1 = 1 - jnp.power(b1, t)
    bc2 = 1 - jnp.power(b2, t)

    def u(m, v, p=None):
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        step = -(lr * (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps))
        if weight_decay and p is not None:
            step = step - lr * weight_decay * p
        return step

    if weight_decay and params is not None:
        upd = jax.tree.map(u, mu, nu, params)
    else:
        upd = jax.tree.map(u, mu, nu)
    return upd, OptState(mu=mu, nu=nu, count=cnt)


def optimizer_update(kind: str) -> Callable:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[kind]
