from repro.optim.optimizers import (OptState, adam, init_opt_state, momentum,
                                    optimizer_update, sgd)
from repro.optim.schedule import constant, cosine, linear_warmup, sqrt_decay

__all__ = ["OptState", "adam", "constant", "cosine", "init_opt_state",
           "linear_warmup", "momentum", "optimizer_update", "sgd",
           "sqrt_decay"]
