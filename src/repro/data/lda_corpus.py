"""Synthetic LDA corpus generator (20News-scale; paper Table 1).

Documents are drawn from a ground-truth LDA model so that a correct
collapsed-Gibbs implementation measurably recovers structure (rising
log-likelihood), and different consistency models can be compared on the
same corpus.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class LDACorpus:
    docs: List[np.ndarray]          # token id arrays
    vocab_size: int
    n_topics_true: int

    @property
    def n_docs(self) -> int:
        return len(self.docs)

    @property
    def n_tokens(self) -> int:
        return int(sum(len(d) for d in self.docs))


def synthetic_corpus(n_docs: int = 200, vocab_size: int = 1000,
                     n_topics: int = 10, doc_len: int = 120,
                     alpha: float = 0.1, beta: float = 0.01,
                     seed: int = 0) -> LDACorpus:
    rng = np.random.default_rng(seed)
    topics = rng.dirichlet(np.full(vocab_size, beta + 0.05), size=n_topics)
    docs = []
    for _ in range(n_docs):
        theta = rng.dirichlet(np.full(n_topics, alpha + 0.05))
        n = max(10, int(rng.poisson(doc_len)))
        zs = rng.choice(n_topics, size=n, p=theta)
        ws = np.array([rng.choice(vocab_size, p=topics[z]) for z in zs],
                      dtype=np.int32)
        docs.append(ws)
    return LDACorpus(docs=docs, vocab_size=vocab_size, n_topics_true=n_topics)
