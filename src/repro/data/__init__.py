from repro.data.lda_corpus import LDACorpus, synthetic_corpus
from repro.data.pipeline import SyntheticLM, batches

__all__ = ["LDACorpus", "SyntheticLM", "batches", "synthetic_corpus"]
