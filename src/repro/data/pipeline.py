"""Synthetic LM data pipeline.

A seeded, deterministic token source with document structure and a Zipfian
unigram-with-Markov-bigram mixture — enough statistical structure that a
language model's loss decreases measurably over a few hundred steps, which
is what the end-to-end examples and the consistency-comparison benchmark
need.  Batches are produced per data-parallel shard (worker-sharded
iterators) with background thread prefetch.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

EOD = 0


class SyntheticLM:
    """Deterministic synthetic corpus sampler."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 doc_len_mean: int = 512, bigram_tables: int = 64):
        self.vocab = vocab_size
        self.seed = seed
        self.doc_len_mean = doc_len_mean
        rng = np.random.default_rng(seed)
        # Zipf unigram distribution
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks ** 1.1)
        self.unigram /= self.unigram.sum()
        # low-rank bigram structure: each token has a "successor cluster"
        self.n_clusters = bigram_tables
        self.tok_cluster = rng.integers(0, bigram_tables, size=vocab_size)
        self.cluster_tokens = [
            rng.choice(vocab_size, size=max(8, vocab_size // bigram_tables),
                       p=self.unigram, replace=True)
            for _ in range(bigram_tables)
        ]

    def sample_tokens(self, n: int, stream: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + stream)
        out = np.empty(n, dtype=np.int32)
        i = 0
        while i < n:
            doc_len = max(8, int(rng.exponential(self.doc_len_mean)))
            tok = int(rng.choice(self.vocab, p=self.unigram))
            for _ in range(min(doc_len, n - i)):
                out[i] = tok
                i += 1
                if rng.random() < 0.7:   # bigram continuation
                    cl = self.tok_cluster[tok]
                    tok = int(rng.choice(self.cluster_tokens[cl]))
                else:
                    tok = int(rng.choice(self.vocab, p=self.unigram))
            if i < n:
                out[i] = EOD
                i += 1
        return out


def batches(source: SyntheticLM, batch: int, seq_len: int, shard: int = 0,
            n_shards: int = 1, prefetch: int = 2,
            ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'ids': (batch, seq), 'labels': (batch, seq)} for this shard.

    Streams are partitioned by shard so data-parallel replicas see disjoint
    data (each PS worker computes on its own partition, as in the paper)."""

    def produce(q: queue.Queue):
        step = 0
        while True:
            ids = np.stack([
                source.sample_tokens(seq_len + 1,
                                     stream=(step * batch + i) * n_shards + shard)
                for i in range(batch)
            ])
            q.put({"ids": ids[:, :-1], "labels": ids[:, 1:].copy()})
            step += 1

    q: queue.Queue = queue.Queue(maxsize=prefetch)
    t = threading.Thread(target=produce, args=(q,), daemon=True)
    t.start()
    while True:
        yield q.get()
