"""Mamba-2 SSD chunked-scan Pallas kernel.

Grid: (batch, heads, chunks) with the CHUNK axis innermost — the inter-chunk
state (head_dim × d_state, f32) carries across chunks in VMEM scratch, so
the sequential recurrence never leaves the chip.  Within a chunk, the
intra-chunk quadratic term runs on the MXU: (cs × ds)·(ds × cs) score block,
decay-masked, times the (cs × hd) inputs — all dims 128-aligned at the
production chunk size 256 / d_state 128 / head_dim 64.

B/C blocks are fetched at GROUP granularity through the index map
(ih // heads_per_group) — no head broadcast is ever materialized in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(dA: jnp.ndarray) -> jnp.ndarray:
    """(cs,) -> (cs, cs): sum_{i=s+1..m} dA_i below/on diagonal, -inf above."""
    cs = dA.shape[0]
    c = jnp.cumsum(dA)
    d = c[:, None] - c[None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(mask, d, -jnp.inf)


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, init_ref,
            y_ref, fin_ref, state_ref, *, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # (cs, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                # (cs,)
    B = B_ref[0, :, 0, :].astype(jnp.float32)               # (cs, ds)
    C = C_ref[0, :, 0, :].astype(jnp.float32)               # (cs, ds)
    A = A_ref[0].astype(jnp.float32)                        # scalar

    dA = dt * A
    a_cum = jnp.cumsum(dA)                                  # (cs,)
    xdt = x * dt[:, None]

    # intra-chunk quadratic part (MXU)
    L = jnp.exp(_segsum(dA))                                # (cs, cs)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * L
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    carry = state_ref[...]                                  # (hd, ds)
    y += jax.lax.dot_general(C, carry, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32
                             ) * jnp.exp(a_cum)[:, None]

    y_ref[...] = y[None, :, None, :].astype(y_ref.dtype)

    # state update: decay old state through the chunk, add this chunk's mass
    decay = jnp.exp(a_cum[-1] - a_cum)                      # (cs,)
    add = jax.lax.dot_general(xdt * decay[:, None], B,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (hd, ds)
    state_ref[...] = carry * jnp.exp(a_cum[-1]) + add

    @pl.when(ic == nc - 1)
    def _fin():
        fin_ref[...] = state_ref[...][None, None]


def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                    initial_state: Optional[jnp.ndarray] = None,
                    interpret: bool = False,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ref.ssd_chunked (B/C at group granularity)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    l_orig = l
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l += pad
    nc = l // chunk
    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    grid = (b, h, nc)
    y, fin = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, hg=hg: (ib, ic, ih // hg, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, hg=hg: (ib, ic, ih // hg, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), A.astype(jnp.float32), B, C, init)
    return y[:, :l_orig], fin
