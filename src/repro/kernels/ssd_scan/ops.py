"""Public SSD-scan op: dispatches Pallas kernel vs jnp reference."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import pallas_mode
from repro.kernels.ssd_scan import ref


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, chunk: int,
             initial_state: Optional[jnp.ndarray] = None,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mode = pallas_mode()
    if mode in ("on", "interpret"):
        from repro.kernels.ssd_scan import kernel
        return kernel.ssd_scan_pallas(x, dt, A, B, C, chunk,
                                      initial_state=initial_state,
                                      interpret=(mode == "interpret"))
    return ref.ssd_chunked(x, dt, A, B, C, chunk, initial_state=initial_state)


ssd_step = jax.jit(ref.ssd_step)
