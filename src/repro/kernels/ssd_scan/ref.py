"""Pure-jnp oracle for the Mamba-2 SSD chunked scan (arXiv:2405.21060 §6).

State-space duality: within a chunk the recurrence is computed as a masked
quadratic attention-like product; across chunks states are passed through a
sequential decay recurrence.

Memory discipline (this path is also what the CPU dry-run lowers, so its
buffers land in the roofline memory analysis):
  * B/C stay at GROUP granularity — never `repeat`ed to heads;
  * bulk tensors stay in the input dtype (bf16 in production), only the
    decay/cumsum bookkeeping is f32;
  * einsums are pairwise with the (b, h, nc, cs, cs) score block as the
    largest intermediate (the Pallas kernel tiles this same structure).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., l) -> (..., l, l) with out[m, s] = sum_{i=s+1..m} x_i (s<=m),
    -inf above the diagonal."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                max_score_bytes: int = 128 * 2**20,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    x:  (b, l, h, p)     inputs per head
    dt: (b, l, h)        discretization steps (post-softplus, f32)
    A:  (h,)             negative decay rates
    B:  (b, l, g, n)     input projections at group granularity (h % g == 0)
    C:  (b, l, g, n)     output projections
    Returns y (b, l, h, p) and final_state (b, h, p, n) (f32).

    When the (b, h, nc, cs, cs) score block would exceed ``max_score_bytes``,
    the batch is processed in slices with ``lax.map`` (the Pallas kernel
    tiles the same structure in VMEM; this keeps the jnp path's compiled
    footprint comparable).
    """
    b, l, h, p = x.shape
    score_bytes = b * h * l * chunk * x.dtype.itemsize
    if score_bytes > max_score_bytes and b > 1:
        bb = max(1, int(b * max_score_bytes / score_bytes))
        while b % bb:
            bb -= 1
        if bb < b:
            xs_ = x.reshape(b // bb, bb, l, h, p)
            dts = dt.reshape(b // bb, bb, l, h)
            Bs = B.reshape(b // bb, bb, l, *B.shape[2:])
            Cs = C.reshape(b // bb, bb, l, *C.shape[2:])
            inits = (None if initial_state is None
                     else initial_state.reshape(b // bb, bb, *initial_state.shape[1:]))

            def fn(args):
                if initial_state is None:
                    xb, db, Bb, Cb = args
                    return ssd_chunked(xb, db, A, Bb, Cb, chunk,
                                       max_score_bytes=2**62)
                xb, db, Bb, Cb, ib = args
                return ssd_chunked(xb, db, A, Bb, Cb, chunk, initial_state=ib,
                                   max_score_bytes=2**62)

            args = ((xs_, dts, Bs, Cs) if initial_state is None
                    else (xs_, dts, Bs, Cs, inits))
            ys, sts = lax.map(fn, args)
            return (ys.reshape(b, l, h, p),
                    sts.reshape(b, h, p, sts.shape[-1]))
    g = B.shape[2]
    hg = h // g
    n = B.shape[-1]
    dt_c = x.dtype        # bulk compute dtype
    f32 = jnp.float32

    l_orig = l
    if l % chunk:
        # zero-pad: dt=0 ⇒ decay=1 and zero input, so padded steps are no-ops
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc, cs_ = l // chunk, chunk

    xdt = (x.astype(f32) * dt.astype(f32)[..., None]).astype(dt_c)   # dt·x
    dA = dt.astype(f32) * A.astype(f32)[None, None, :]               # (b, l, h)

    xc = xdt.reshape(b, nc, cs_, h, p)
    Bc = B.astype(dt_c).reshape(b, nc, cs_, g, n)
    Cc = C.astype(dt_c).reshape(b, nc, cs_, g, n)
    dAc = dA.reshape(b, nc, cs_, h).transpose(0, 3, 1, 2)            # (b, h, nc, cs)
    A_cum = jnp.cumsum(dAc, axis=-1)                                 # f32

    # 1. intra-chunk (quadratic): group-level CBᵀ, head-level decay mask
    L = jnp.exp(segsum(dAc)).astype(dt_c)                            # (b, h, nc, m, s)
    cb = jnp.einsum("bcmgn,bcsgn->bgcms", Cc, Bc,
                    preferred_element_type=f32).astype(dt_c)         # (b, g, nc, m, s)
    scores = (cb.reshape(b, g, 1, nc, cs_, cs_)
              * L.reshape(b, g, hg, nc, cs_, cs_)).reshape(b, h, nc, cs_, cs_)
    Y_diag = jnp.einsum("bhcms,bcshp->bcmhp", scores, xc,
                        preferred_element_type=f32).astype(dt_c)

    # 2. per-chunk output states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum).astype(dt_c)     # (b, h, nc, cs)
    xdec = (xc * decay_states.transpose(0, 2, 3, 1)[..., None])      # (b,nc,cs,h,p)
    xdec_g = xdec.reshape(b, nc, cs_, g, hg, p)
    states = jnp.einsum("bcsgn,bcsghp->bcghpn", Bc, xdec_g,
                        preferred_element_type=f32)                   # f32
    states = states.reshape(b, nc, h, p, n)

    # 3. inter-chunk recurrence (sequential over chunks, f32 state)
    chunk_decay = jnp.exp(A_cum[..., -1])                            # (b, h, nc) f32
    init = (jnp.zeros((b, h, p, n), f32) if initial_state is None
            else initial_state.astype(f32))

    def step(carry, inp):
        s_c, decay_c = inp                                           # (b,h,p,n), (b,h)
        new = s_c + decay_c[..., None, None] * carry
        return new, carry                                            # emit state ENTERING chunk

    final_state, states_prev = lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    states_prev = states_prev.transpose(1, 0, 2, 3, 4).astype(dt_c)  # (b, nc, h, p, n)

    # 4. inter-chunk contribution to outputs
    state_decay = jnp.exp(A_cum).astype(dt_c)                        # (b, h, nc, cs)
    Ch = Cc.reshape(b, nc, cs_, g, 1, n)
    sp = states_prev.reshape(b, nc, g, hg, p, n)
    Y_off = jnp.einsum("bcmgon,bcghpn->bcmghp", Ch, sp,
                       preferred_element_type=f32).reshape(b, nc, cs_, h, p)
    Y_off = (Y_off * state_decay.transpose(0, 2, 3, 1)[..., None]).astype(dt_c)

    y = (Y_diag + Y_off).reshape(b, l, h, p)[:, :l_orig]
    return y.astype(x.dtype), final_state


def ssd_step(h_state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
             A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step.

    h_state: (b, h, p, n); x_t: (b, h, p); dt_t: (b, h); B_t/C_t: (b, h, n)
    Returns (y_t (b, h, p), new_state).
    """
    f32 = jnp.float32
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])          # (b, h)
    inp = (dt_t.astype(f32)[..., None] * x_t.astype(f32))            # (b, h, p)
    new = (h_state.astype(f32) * dA[..., None, None]
           + inp[..., None] * B_t.astype(f32)[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new, C_t.astype(f32))
    return y.astype(x_t.dtype), new
