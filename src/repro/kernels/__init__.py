"""Pallas TPU kernels for the compute hot-spots.

Each kernel lives in its own subpackage with three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper; dispatches kernel vs reference
  ref.py    — pure-jnp oracle the kernel is validated against

Dispatch (ops.py): the Pallas kernel runs on TPU, or anywhere when
``REPRO_PALLAS=interpret`` is set (tests validate the kernel body on CPU via
``interpret=True``); otherwise the jnp reference runs — which is what the
CPU dry-run lowers and the roofline reads.
"""
import os

_PROBED: str = ""


def pallas_mode() -> str:
    """'off' | 'interpret' | 'on'."""
    env = os.environ.get("REPRO_PALLAS", "").lower()
    if env in ("interpret", "on", "off"):
        return env
    # the backend probe is cached: this sits on the PS apply/flush hot path
    # (ps_kernels=True calls it per batch), and the first call pays the
    # whole jax import — the answer cannot change within a process
    global _PROBED
    if not _PROBED:
        import jax
        _PROBED = "on" if jax.default_backend() == "tpu" else "off"
    return _PROBED
