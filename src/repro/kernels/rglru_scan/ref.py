"""Pure-jnp oracle for the RG-LRU linear recurrence (arXiv:2402.19427).

Generic diagonal linear recurrence  h_t = a_t ⊙ h_{t-1} + b_t, computed with
an associative scan (log-depth) — the kernel computes the same thing with a
sequential blocked pass over sequence tiles.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def linear_recurrence(a: jnp.ndarray, b: jnp.ndarray,
                      initial: Optional[jnp.ndarray] = None,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (batch, l, w); h_t = a_t h_{t-1} + b_t.  Returns (h, h_last)."""
    f32 = jnp.float32
    a32, b32 = a.astype(f32), b.astype(f32)
    if initial is not None:
        # fold the initial state into the first step's additive term
        b32 = b32.at[:, 0].add(a32[:, 0] * initial.astype(f32))

    def combine(left, right):
        (al, bl), (ar, br) = left, right
        return al * ar, ar * bl + br

    ah, bh = lax.associative_scan(combine, (a32, b32), axis=1)
    return bh.astype(b.dtype), bh[:, -1]


def rglru(x: jnp.ndarray, r_gate: jnp.ndarray, i_gate: jnp.ndarray,
          a_param: jnp.ndarray, initial: Optional[jnp.ndarray] = None,
          c: float = 8.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The RG-LRU: log a_t = −c·softplus(Λ)·r_t;
       h_t = a_t h_{t-1} + sqrt(1−a_t²)·(i_t ⊙ x_t).

    x, r_gate, i_gate: (b, l, w); a_param Λ: (w,).  Returns (h, h_last)."""
    f32 = jnp.float32
    log_a = -c * jax.nn.softplus(a_param.astype(f32))[None, None, :] * r_gate.astype(f32)
    a = jnp.exp(log_a)
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i_gate.astype(f32) * x.astype(f32))
    h, h_last = linear_recurrence(a.astype(f32), gated, initial=initial)
    return h.astype(x.dtype), h_last


def rglru_step(h: jnp.ndarray, x_t: jnp.ndarray, r_t: jnp.ndarray,
               i_t: jnp.ndarray, a_param: jnp.ndarray, c: float = 8.0,
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step: h (b, w); x_t/r_t/i_t (b, w)."""
    f32 = jnp.float32
    log_a = -c * jax.nn.softplus(a_param.astype(f32))[None, :] * r_t.astype(f32)
    a = jnp.exp(log_a)
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i_t.astype(f32) * x_t.astype(f32))
    new = a * h.astype(f32) + gated
    return new.astype(x_t.dtype), new
