"""RG-LRU linear-recurrence Pallas kernel:  h_t = a_t ⊙ h_{t-1} + b_t.

Grid: (batch, width_blocks, seq_blocks) with the SEQUENCE axis innermost —
TPU grids iterate sequentially, so the running state lives in a VMEM scratch
accumulator that carries across seq blocks.  The width axis sits in vector
lanes (128-aligned blocks); the within-block time loop is a fori over
SEQ_BLK steps of pure VPU work.

The wrapper computes the RG-LRU gates (a_t, gated input) in jnp — they are
element-wise projections the surrounding matmuls already pay for — and the
kernel owns the sequential recurrence, which is the part XLA handles badly
(a log-depth associative scan materializes O(l) intermediates; the kernel
streams them through one VMEM tile).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

W_BLK = 128
SEQ_BLK = 128


def _kernel(a_ref, b_ref, init_ref, out_ref, h_ref):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_ref[...] = init_ref[...].astype(jnp.float32)

    def step(t, h):
        # jax 0.4.37's interpret-mode discharge rules choke on bare int
        # indices mixed with dynamic slices — keep every axis a (d)slice
        a_t = pl.load(a_ref, (pl.dslice(0, 1), pl.dslice(t, 1),
                              pl.dslice(None)))[0, 0].astype(jnp.float32)
        b_t = pl.load(b_ref, (pl.dslice(0, 1), pl.dslice(t, 1),
                              pl.dslice(None)))[0, 0].astype(jnp.float32)
        h = a_t * h + b_t
        pl.store(out_ref, (pl.dslice(0, 1), pl.dslice(t, 1), pl.dslice(None)),
                 h[None, None].astype(out_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, a_ref.shape[1], step, h_ref[...][0])
    h_ref[...] = h[None]


def linear_recurrence_pallas(a: jnp.ndarray, b: jnp.ndarray,
                             initial: Optional[jnp.ndarray] = None,
                             interpret: bool = False,
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (batch, l, w).  Returns (h (batch, l, w), h_last (batch, w))."""
    bsz, l, w = a.shape
    pad_l = (-l) % SEQ_BLK
    pad_w = (-w) % W_BLK
    if pad_l or pad_w:
        # a=1, b=0 padding keeps the state constant through padded steps
        a = jnp.pad(a, ((0, 0), (0, pad_l), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_l), (0, pad_w)))
    lp, wp = l + pad_l, w + pad_w
    init = (jnp.zeros((bsz, wp), jnp.float32) if initial is None
            else jnp.pad(initial.astype(jnp.float32), ((0, 0), (0, pad_w))))

    grid = (bsz, wp // W_BLK, lp // SEQ_BLK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, SEQ_BLK, W_BLK), lambda ib, iw, il: (ib, il, iw)),
            pl.BlockSpec((1, SEQ_BLK, W_BLK), lambda ib, iw, il: (ib, il, iw)),
            pl.BlockSpec((1, W_BLK), lambda ib, iw, il: (ib, iw)),
        ],
        out_specs=pl.BlockSpec((1, SEQ_BLK, W_BLK),
                               lambda ib, iw, il: (ib, il, iw)),
        out_shape=jax.ShapeDtypeStruct((bsz, lp, wp), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, W_BLK), jnp.float32)],
        interpret=interpret,
    )(a, b, init)
    # padded steps (a=1, b=0) leave the state unchanged, so the final padded
    # row equals the last valid state
    h_last = out[:, lp - 1, :w].astype(jnp.float32)
    return out[:, :l, :w], h_last


def rglru_pallas(x: jnp.ndarray, r_gate: jnp.ndarray, i_gate: jnp.ndarray,
                 a_param: jnp.ndarray, initial: Optional[jnp.ndarray] = None,
                 interpret: bool = False, c: float = 8.0,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    f32 = jnp.float32
    log_a = -c * jax.nn.softplus(a_param.astype(f32))[None, None, :] * r_gate.astype(f32)
    a = jnp.exp(log_a)
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i_gate.astype(f32) * x.astype(f32))
    h, h_last = linear_recurrence_pallas(a, gated, initial=initial,
                                         interpret=interpret)
    return h.astype(x.dtype), h_last
