"""Public RG-LRU op: dispatches Pallas kernel vs jnp reference."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import pallas_mode
from repro.kernels.rglru_scan import ref


@jax.jit
def rglru(x: jnp.ndarray, r_gate: jnp.ndarray, i_gate: jnp.ndarray,
          a_param: jnp.ndarray, initial: Optional[jnp.ndarray] = None,
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mode = pallas_mode()
    if mode in ("on", "interpret"):
        from repro.kernels.rglru_scan import kernel
        return kernel.rglru_pallas(x, r_gate, i_gate, a_param, initial=initial,
                                   interpret=(mode == "interpret"))
    return ref.rglru(x, r_gate, i_gate, a_param, initial=initial)


rglru_step = jax.jit(ref.rglru_step)
