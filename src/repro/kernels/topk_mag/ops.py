"""Public magnitude-ordering op: dispatches Pallas kernel vs numpy.

`magnitude_order` is the runtime entry used by the worker flush when
PSRuntime(ps_kernels=True).  All paths implement the same contract —
descending by magnitude, ties in first-occurrence order — so the flush
ships updates in exactly the order the seed Python sort produced.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import pallas_mode


def magnitude_order(mags: np.ndarray) -> np.ndarray:
    """Indices ordering mags descending, ties stable; mags non-negative."""
    mode = pallas_mode()
    if mode == "off" or mags.shape[0] <= 1:
        return np.argsort(-mags, kind="stable")
    import jax.numpy as jnp
    if mode in ("on", "interpret"):
        from repro.kernels.topk_mag import kernel
        out = kernel.topk_mag_pallas(jnp.asarray(mags, jnp.float32),
                                     interpret=(mode == "interpret"))
    else:
        from repro.kernels.topk_mag import ref
        out = ref.magnitude_order(jnp.asarray(mags, jnp.float32))
    return np.asarray(out)
