"""Public magnitude-ordering op: dispatches Pallas kernel vs numpy.

`magnitude_order` is the runtime entry used by the worker flush when
PSRuntime(ps_kernels=True).  All paths implement the same contract —
descending by magnitude, ties in first-occurrence order — so the flush
ships updates in exactly the order the seed Python sort produced.

The kernel/ref paths order in f32 (TPU lanes), but the flush magnitudes
are f64 and magnitudes distinct in f64 can collapse to one f32 value;
left alone that would ship updates in a different order than the numpy
path `np.argsort(-mags, kind="stable")` and break bitwise simulator
conformance.  The f32 cast is monotone, so every such collision is a
contiguous run of the coarse order — `_refine_f32_ties` re-sorts each
run by the exact f64 magnitudes to restore full parity.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import pallas_mode


def _refine_f32_ties(order: np.ndarray, m64: np.ndarray,
                     m32: np.ndarray) -> np.ndarray:
    """Exact-f64 fixup of an f32-coarse descending order.

    Within an equal-f32 run the kernel emits first-occurrence (ascending
    index) order, so a stable descending argsort of the run's f64 values
    reproduces `np.argsort(-m64, kind="stable")` bitwise: strict f64
    differences reorder the run, true f64 ties keep index order.
    """
    coarse = m32[order]
    starts = np.flatnonzero(np.r_[True, coarse[1:] != coarse[:-1]])
    ends = np.r_[starts[1:], coarse.shape[0]]
    for s, e in zip(starts, ends):
        if e - s > 1:
            run = order[s:e]
            order[s:e] = run[np.argsort(-m64[run], kind="stable")]
    return order


def magnitude_order(mags: np.ndarray) -> np.ndarray:
    """Indices ordering mags descending, ties stable; mags non-negative."""
    mode = pallas_mode()
    m64 = np.ascontiguousarray(mags, dtype=np.float64)
    if mode == "off" or m64.shape[0] <= 1:
        return np.argsort(-m64, kind="stable")
    import jax.numpy as jnp
    m32 = m64.astype(np.float32)
    if mode in ("on", "interpret"):
        from repro.kernels.topk_mag import kernel
        out = kernel.topk_mag_pallas(jnp.asarray(m32),
                                     interpret=(mode == "interpret"))
    else:
        from repro.kernels.topk_mag import ref
        out = ref.magnitude_order(jnp.asarray(m32))
    order = np.array(out, dtype=np.int64)   # writable copy: refined in place
    return _refine_f32_ties(order, m64, m32)
