"""Largest-|Δ|-first selection Pallas kernel for the flush hot path.

The worker flush ranks pending per-key deltas by max-|Δ| so the biggest
updates ship first.  This kernel emits the full descending ordering via k
rounds of argmax-and-mask: each round takes the flat argmax lane, records it
with a one-hot iota write (no dynamic lane stores — TPU lanes can't be
indexed dynamically), then masks that lane to -inf.  Ties resolve to the
first occurrence, matching np.argsort(-mags, kind="stable").

Layout: magnitudes live in row 0 of an (8, L) f32 tile (sublane hygiene);
rows 1..7 and lane padding are filled below any real magnitude so the flat
argmax always lands in row 0 and equals the lane index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 128


def _kernel(m_ref, out_ref, *, k):
    lane = jax.lax.broadcasted_iota(jnp.int32, m_ref.shape, 1)

    def body(j, carry):
        mags, out = carry
        idx = jnp.argmax(mags).astype(jnp.int32)  # flat == lane (row 0 wins)
        out = jnp.where(lane == j, idx, out)
        mags = jnp.where(lane == idx, -jnp.inf, mags)
        return mags, out

    _, out = jax.lax.fori_loop(
        0, k, body, (m_ref[...], jnp.zeros(m_ref.shape, jnp.int32)))
    out_ref[...] = out


def topk_mag_pallas(mags: jnp.ndarray, k: int | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Indices of the k largest entries of mags, descending, ties stable.

    mags: (n,) non-negative f32 magnitudes.  k defaults to n (full order).
    """
    n = mags.shape[0]
    k = n if k is None else int(k)
    if n == 0 or k == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-n) % LANES
    L = n + pad
    row0 = jnp.pad(mags.astype(jnp.float32), (0, pad), constant_values=-1.0)
    # Pad rows sit strictly below any real magnitude (>= 0), so they are
    # only ever selected after every real lane — and k <= n forbids that.
    m = jnp.full((SUBLANES, L), -jnp.inf, jnp.float32).at[0].set(row0)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((SUBLANES, L), jnp.int32),
        interpret=interpret,
    )(m)
    return out[0, :k]
