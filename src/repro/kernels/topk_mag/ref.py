"""Pure-jnp oracle for largest-|Δ|-first selection.

jnp.argsort is stable by default, so sorting on -mags yields the descending
order with ties kept in first-occurrence order — the same contract as the
seed Python sort (`key=lambda: -max|Δ|`) and the kernel's argmax-and-mask.
"""
from __future__ import annotations

import jax.numpy as jnp


def magnitude_order(mags: jnp.ndarray) -> jnp.ndarray:
    """Indices ordering mags descending; ties stable (first occurrence)."""
    return jnp.argsort(-mags).astype(jnp.int32)
