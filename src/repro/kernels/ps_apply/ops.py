"""Public PS dense-block apply op: dispatches Pallas kernel vs numpy.

`scatter_add_inplace` is the runtime entry used by ServerShard._flush_updates
when PSRuntime(ps_kernels=True).  With pallas off it is exactly the seed
`np.add.at` path; with pallas on/interpret it routes through the kernel,
which accumulates duplicate rows in the same submission order, so the final
state stays bitwise equal to the simulator either way.  Shard state is f64;
the jax path runs under enable_x64 so no precision is lost in transit.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import pallas_mode


def _jax_scatter_add(dense: np.ndarray, rows: np.ndarray,
                     delta: np.ndarray, mode: str) -> np.ndarray:
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        if mode in ("on", "interpret"):
            from repro.kernels.ps_apply import kernel
            out = kernel.scatter_add_pallas(
                jnp.asarray(dense), jnp.asarray(rows, jnp.int32),
                jnp.asarray(delta), interpret=(mode == "interpret"))
        else:
            from repro.kernels.ps_apply import ref
            out = ref.scatter_add(jnp.asarray(dense),
                                  jnp.asarray(rows, jnp.int32),
                                  jnp.asarray(delta))
        return np.asarray(out)


def scatter_add_inplace(dense: np.ndarray, rows: np.ndarray,
                        delta: np.ndarray) -> None:
    """Accumulate delta[i] into dense[rows[i]] in place (np.add.at order)."""
    mode = pallas_mode()
    if mode == "off" or rows.shape[0] == 0:
        np.add.at(dense, rows, delta)
        return
    n, r = rows.shape[0], dense.shape[0]
    # Pad N up to a power of two with no-op rows targeting the kernel's
    # dummy row R, so jit retraces are bounded to O(log max-batch) shapes.
    npad = max(8, 1 << (n - 1).bit_length())
    if npad != n:
        rows_p = np.full(npad, r, np.int32)
        rows_p[:n] = rows
        delta_p = np.zeros((npad, dense.shape[1]), dense.dtype)
        delta_p[:n] = delta
    else:
        rows_p, delta_p = rows.astype(np.int32), delta
    dense[...] = _jax_scatter_add(dense, rows_p, delta_p, mode)
