"""Pure-jnp oracle for the PS segment scatter-add apply.

The shard applies a coalesced batch by `np.add.at(dense, rows, delta)`:
duplicate rows accumulate.  `.at[...].add` is jnp's equivalent; XLA may
reassociate duplicate-row sums, so exact-order parity is asserted against
the Pallas kernel (which replays submission order), not against this ref.
"""
from __future__ import annotations

import jax.numpy as jnp


def scatter_add(dense: jnp.ndarray, rows: jnp.ndarray,
                delta: jnp.ndarray) -> jnp.ndarray:
    """Returns dense with delta[i] accumulated into row rows[i]."""
    return dense.at[rows].add(delta.astype(dense.dtype))
