"""Segment scatter-add Pallas kernel for the PS dense-block apply.

The shard hot path lands a coalesced batch of (rows, delta) updates into a
dense block with `np.add.at(dense, rows, delta)`.  This kernel performs the
same accumulation on-chip: row indices live in SMEM, the dense block and the
delta batch are tiled along lanes, and a sequential fori_loop adds delta row
i into dense row rows[i] in submission order — the same order `np.add.at`
uses — so duplicate rows accumulate bitwise-identically to the numpy path.

Conventions:
  * rows may contain the sentinel index R (== dense.shape[0]); the wrapper
    appends a dedicated zero "dummy" row at index R so padded entries land
    there and never touch real state.
  * Every pl.load/pl.store axis is a pl.dslice — jax 0.4.37's interpret-mode
    discharge rules choke on bare int indices mixed with dynamic slices
    (same workaround as kernels/rglru_scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128


def _kernel(rows_ref, delta_ref, dense_ref, out_ref):
    out_ref[...] = dense_ref[...]
    n, w = delta_ref.shape

    def body(i, carry):
        # All dslice starts must share the loop index dtype: under x64 the
        # implicit 0 of dslice(None) widens to int64 while SMEM rows stay
        # int32, and dynamic_slice rejects mixed index types.
        r = rows_ref[i].astype(i.dtype)
        zero = jnp.zeros((), i.dtype)
        cur = pl.load(out_ref, (pl.dslice(r, 1), pl.dslice(zero, w)))
        d = pl.load(delta_ref, (pl.dslice(i, 1), pl.dslice(zero, w)))
        pl.store(out_ref, (pl.dslice(r, 1), pl.dslice(zero, w)), cur + d)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


def scatter_add_pallas(dense: jnp.ndarray, rows: jnp.ndarray,
                       delta: jnp.ndarray, interpret: bool = False,
                       ) -> jnp.ndarray:
    """Returns dense with delta[i] added into row rows[i], np.add.at order.

    dense: (R, C); rows: (N,) int in [0, R] (R = no-op dummy); delta: (N, C).
    """
    R, C = dense.shape
    N = rows.shape[0]
    if N == 0 or R == 0:
        return dense
    dtype = dense.dtype
    # Dedicated dummy row at index R: padding entries accumulate there and
    # the row is sliced away on return, so real rows stay untouched.
    dense_p = jnp.concatenate([dense, jnp.zeros((1, C), dtype)], axis=0)
    rpad = (-(R + 1)) % SUBLANES
    cpad = (-C) % LANES
    dense_p = jnp.pad(dense_p, ((0, rpad), (0, cpad)))
    delta_p = jnp.pad(delta.astype(dtype), ((0, 0), (0, cpad)))
    rows_i = rows.astype(jnp.int32)
    npad = (-N) % SUBLANES
    if npad:
        rows_i = jnp.concatenate([rows_i, jnp.full((npad,), R, jnp.int32)])
        delta_p = jnp.pad(delta_p, ((0, npad), (0, 0)))
    Rp, Cp, Np = R + 1 + rpad, C + cpad, N + npad

    out = pl.pallas_call(
        _kernel,
        grid=(Cp // LANES,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((Np, LANES), lambda j: (0, j)),
            pl.BlockSpec((Rp, LANES), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((Rp, LANES), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), dtype),
        interpret=interpret,
    )(rows_i, delta_p, dense_p)
    return out[:R, :C]
