"""Fused VAP accumulate-and-bound Pallas kernel.

One HBM pass computes  p' = p + u,  δ' = δ + u,  and the per-block ‖δ'‖∞
(reduced to a scalar by the wrapper).  The VAP/CVAP trigger runs this over
every parameter every step, so fusing the three reads is the paper-technique
hot-spot (DESIGN.md §7).

Tiling: the flattened parameter is padded to (rows, LANES) with rows a
multiple of SUBLANES; each grid step owns an (8, 1024) VMEM tile —
8 sublanes × 1024 lanes = 8 f32 vregs per operand, comfortably within VMEM
at 3 inputs + 2 outputs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 1024
TILE = SUBLANES * LANES


def _kernel(p_ref, d_ref, u_ref, po_ref, do_ref, m_ref):
    u = u_ref[...]
    nd = d_ref[...] + u
    po_ref[...] = p_ref[...] + u
    do_ref[...] = nd
    m_ref[0, 0] = jnp.max(jnp.abs(nd.astype(jnp.float32)))


def vap_accum_pallas(params: jnp.ndarray, delta: jnp.ndarray,
                     update: jnp.ndarray, interpret: bool = False,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    shape, dtype = params.shape, params.dtype
    n = params.size
    pad = (-n) % TILE
    flat = [jnp.pad(x.reshape(-1), (0, pad)) for x in (params, delta, update)]
    rows = (n + pad) // LANES
    p2, d2, u2 = (x.reshape(rows, LANES) for x in flat)
    nblk = rows // SUBLANES

    tile = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    out_p, out_d, out_m = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[tile, tile, tile],
        out_specs=[tile, tile, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), dtype),
            jax.ShapeDtypeStruct((rows, LANES), dtype),
            jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p2, d2, u2)
    new_p = out_p.reshape(-1)[:n].reshape(shape)
    new_d = out_d.reshape(-1)[:n].reshape(shape)
    return new_p, new_d, jnp.max(out_m)
