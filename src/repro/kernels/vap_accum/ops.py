"""Public fused VAP accumulate op: dispatches Pallas kernel vs reference."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import pallas_mode
from repro.kernels.vap_accum import ref

PyTree = Any


@jax.jit
def vap_accum(params: jnp.ndarray, delta: jnp.ndarray, update: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    mode = pallas_mode()
    if mode in ("on", "interpret"):
        from repro.kernels.vap_accum import kernel
        return kernel.vap_accum_pallas(params, delta, update,
                                       interpret=(mode == "interpret"))
    return ref.vap_accum(params, delta, update)


def vap_accum_tree(params: PyTree, delta: PyTree, update: PyTree,
                   ) -> Tuple[PyTree, PyTree, jnp.ndarray]:
    """Fused pass over a whole pytree; returns the global ‖δ‖∞."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_d = jax.tree.leaves(delta)
    flat_u = jax.tree.leaves(update)
    out_p, out_d, maxes = [], [], []
    for p, d, u in zip(flat_p, flat_d, flat_u):
        np_, nd_, m_ = vap_accum(p, d, u)
        out_p.append(np_)
        out_d.append(nd_)
        maxes.append(m_)
    gmax = jnp.max(jnp.stack(maxes)) if maxes else jnp.zeros((), jnp.float32)
    return (jax.tree.unflatten(treedef, out_p),
            jax.tree.unflatten(treedef, out_d), gmax)
