"""Pure-jnp oracle for the fused VAP accumulate-and-bound pass.

The VAP/CVAP trigger must, every step and for every parameter:
  params ← params + u;  δ ← δ + u;  m = ‖δ+u‖∞
A naive implementation reads each tensor three times; the kernel fuses the
three into one HBM pass (this is the paper-technique hot-spot: the value
bound is priced on every parameter touch).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def vap_accum(params: jnp.ndarray, delta: jnp.ndarray, update: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (params + u, delta + u, max|delta + u| as f32 scalar)."""
    new_p = params + update
    new_d = delta + update
    m = jnp.max(jnp.abs(new_d)).astype(jnp.float32)
    return new_p, new_d, m
