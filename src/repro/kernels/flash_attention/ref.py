"""Pure-jnp oracle for the flash-attention kernel.

Dense masked attention in f32 — deliberately the simplest correct thing.
Matches the model-side chunked core (repro.models.attention.attention_core);
tests assert ref == chunked core == Pallas kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_pos: jnp.ndarray, k_pos: jnp.ndarray,
              window: Optional[int] = None,
              cap: Optional[float] = None) -> jnp.ndarray:
    """q: (b, sq, kvh, G, dh); k, v: (b, skv, kvh, dh_{k,v});
    q_pos: (b, sq) or (sq,); k_pos: (b, skv) or (skv,)."""
    b, sq = q.shape[:2]
    skv = k.shape[1]
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (b, sq))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (b, skv))
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    m = k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window is not None:
        m &= k_pos[:, None, None, None, :] > (q_pos[:, None, None, :, None] - window)
    s = jnp.where(m, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(m.any(-1, keepdims=True), w, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)
