"""Public flash-attention op: dispatches Pallas kernel vs reference."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import pallas_mode
from repro.kernels.flash_attention import ref


@partial(jax.jit, static_argnames=("window", "cap"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                    window: Optional[int] = None,
                    cap: Optional[float] = None) -> jnp.ndarray:
    mode = pallas_mode()
    if mode in ("on", "interpret"):
        from repro.kernels.flash_attention import kernel
        return kernel.flash_attention_pallas(
            q, k, v, q_pos, k_pos, window=window, cap=cap,
            interpret=(mode == "interpret"))
    return ref.attention(q, k, v, q_pos, k_pos, window=window, cap=cap)
