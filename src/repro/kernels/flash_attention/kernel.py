"""Flash-attention Pallas kernel: online-softmax blocked attention.

Grid: (batch·q_heads, q_blocks, k_blocks) — the k axis is innermost, so the
running max / denominator / accumulator live in VMEM scratch and carry
across k blocks (TPU grids are sequential).  Per grid step the VMEM working
set is q (BLK_Q × dh) + k/v (BLK_K × dh) + acc (BLK_Q × dh f32) + the
(BLK_Q × BLK_K) score tile — ≲ 1 MiB at the default 128/512 blocks, and all
matmul dims are 128-aligned for the MXU.

Supports: causal masking by absolute positions, sliding windows, logit
softcap, GQA (kv head = q head // group), separate v head dim (MLA).
Out-of-window k blocks are skipped with ``pl.when`` on the block position
bounds — this is where the TPU kernel beats the jnp oracle's banded-chunk
approximation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK_Q = 128
BLK_K = 512
NEG_INF = -1e30


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, cap: Optional[float],
            window: Optional[int], nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qp_ref[...]                       # (BLK_Q,) absolute q positions
    kp = kp_ref[...]                       # (BLK_K,) absolute k positions

    # block-level skip: any (q, k) pair in range?
    q_lo, q_hi = jnp.min(qp), jnp.max(qp)
    k_lo = jnp.min(kp)
    may_attend = k_lo <= q_hi
    if window is not None:
        k_hi = jnp.max(kp)
        may_attend &= k_hi > (q_lo - window)

    @pl.when(may_attend)
    def _block():
        q = q_ref[0].astype(jnp.float32)                     # (BLK_Q, dh)
        k = k_ref[0].astype(jnp.float32)                     # (BLK_K, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = jnp.tanh(s / cap) * cap
        mask = kp[None, :] <= qp[:, None]
        if window is not None:
            mask &= kp[None, :] > (qp[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (BLK_Q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                     # (BLK_K, dv)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[...] = o[None].astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                           window: Optional[int] = None,
                           cap: Optional[float] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (b, sq, kvh, G, dh); k, v: (b, skv, kvh, dh_{k,v});
    q_pos: (sq,) or (b, sq) — must be batch-independent for the kernel, so
    only (sq,) is accepted; k_pos: (skv,)."""
    if q_pos.ndim != 1 or k_pos.ndim != 1:
        raise ValueError("flash kernel expects shared (sq,)/(skv,) positions")
    b, sq, kvh, G, dh = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / np.sqrt(dh)

    blk_q = min(BLK_Q, max(8, sq))
    blk_k = min(BLK_K, max(128, skv))
    pad_q = (-sq) % blk_q
    pad_k = (-skv) % blk_k
    SENT = np.int32(2**30)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=SENT)
    sqp, skp = sq + pad_q, skv + pad_k

    # fold heads: q -> (BH, sqp, dh) with BH = b*kvh*G; k index = BH // G
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kvh * G, sqp, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dv)

    nq, nk = sqp // blk_q, skp // blk_k
    grid = (b * kvh * G, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, cap=cap, window=window, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q,), lambda ih, iq, ik: (iq,)),
            pl.BlockSpec((blk_k,), lambda ih, iq, ik: (ik,)),
            pl.BlockSpec((1, blk_q, dh), lambda ih, iq, ik: (ih, iq, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda ih, iq, ik: (ih // G, ik, 0)),
            pl.BlockSpec((1, blk_k, dv), lambda ih, iq, ik: (ih // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dv), lambda ih, iq, ik: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh * G, sqp, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), k_pos.astype(jnp.int32), qf, kf, vf)

    out = out.reshape(b, kvh, G, sqp, dv).transpose(0, 3, 1, 2, 4)
    return out[:, :sq]
