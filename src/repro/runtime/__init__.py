"""Threaded asynchronous parameter-server runtime (Petuum-PS style).

Third implementation of the paper's consistency models, alongside the
event-driven simulator (:mod:`repro.core.server`, the executable spec) and
the SPMD sync layer (:mod:`repro.core.sync`).  All three share the Policy /
Consistency Controller split and are differentially tested against each other
in ``tests/test_runtime_conformance.py``.
"""
from repro.runtime.messages import (AckMsg, Channel, ClockMarker, ClockMsg,
                                    DeliverMsg, FullyDelivered, UpdateMsg)
from repro.runtime.runtime import ClientProcess, PSRuntime, RuntimeViewHandle
from repro.runtime.shard import ServerShard

__all__ = [
    "AckMsg", "Channel", "ClientProcess", "ClockMarker", "ClockMsg",
    "DeliverMsg", "FullyDelivered", "PSRuntime", "RuntimeViewHandle",
    "ServerShard", "UpdateMsg",
]
