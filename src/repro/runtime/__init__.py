"""Asynchronous parameter-server runtime (Petuum-PS style).

Third implementation of the paper's consistency models, alongside the
event-driven simulator (:mod:`repro.core.server`, the executable spec) and
the SPMD sync layer (:mod:`repro.core.sync`).  All three share the Policy /
Consistency Controller split and are differentially tested against each other
in ``tests/test_runtime_conformance.py``.

Runs worker threads in-process (``transport="queue"``) or real forked
client processes over loopback sockets / shared-memory rings
(``transport="tcp" | "shm" | "proc"`` — see :mod:`repro.runtime.transport`),
with snapshot/restore of the master shard state in
:mod:`repro.runtime.snapshot`.
"""
import logging as _logging

from repro.runtime.autoscale import (AutoscaleAction, AutoscalePolicy,
                                     Autoscaler)
from repro.runtime.config import RuntimeConfig
from repro.runtime.membership import (INF_CLOCK, MembershipEvent,
                                      MembershipManager, MembershipPlan,
                                      Partition)
from repro.runtime.messages import (AckBatchMsg, AckMsg, Channel, ClockMarker,
                                    ClockMsg, DeliverMsg, EpochAckMsg,
                                    EpochBeginMsg, EpochMsg, FullyDelivered,
                                    InstallMsg, ProcDoneMsg, ReplicaDeltaMsg,
                                    ReplicaFinMsg, ReplicaStateMsg,
                                    ReplicaVcMsg, ShardFinMsg, SubscribeMsg,
                                    UnsubscribeMsg, UpdateMsg)
from repro.runtime.metrics import (GatewayMetrics, MembershipMetrics,
                                   MetricsHub, ProcessMetrics, ReplicaMetrics,
                                   RunMetrics, RuntimeMetrics, ShardMetrics,
                                   SnapshotMetrics)
from repro.runtime.runtime import (TRANSPORTS, ClientProcess, PSRuntime,
                                   RuntimeViewHandle)
from repro.runtime.serving import (FRESH, ReadGateway, ReadResult,
                                   ReadShedError, Replica, ReplicaSet,
                                   SERVING_TRANSPORTS)
from repro.runtime.shard import ServerShard, UidDedup
from repro.runtime.snapshot import (conservative_vc, load_snapshot,
                                    recover_to_vc, save_snapshot,
                                    snapshot_params, take_snapshot,
                                    validate_vcs)
from repro.runtime.trace import (TraceConfig, TraceHub, dump_chrome_trace,
                                 explain_block, explain_read,
                                 staleness_timeline)
from repro.runtime.transport import (FifoAssert, FrameDecoder, ShmRing,
                                     WireChannel, encode_frame, require_tso)
from repro.runtime.wal import (WalWriter, prune_segments, read_segment,
                               wal_segments)

__all__ = [
    "AckBatchMsg", "AckMsg", "AutoscaleAction", "AutoscalePolicy",
    "Autoscaler", "Channel", "ClientProcess", "ClockMarker",
    "ClockMsg", "DeliverMsg", "EpochAckMsg", "EpochBeginMsg", "EpochMsg",
    "FRESH", "FifoAssert", "FrameDecoder", "FullyDelivered",
    "GatewayMetrics", "INF_CLOCK", "InstallMsg", "MembershipEvent",
    "MembershipManager", "MembershipMetrics", "MembershipPlan",
    "MetricsHub", "PSRuntime", "Partition", "ProcDoneMsg",
    "ProcessMetrics", "ReadGateway", "ReadResult", "ReadShedError",
    "Replica", "ReplicaDeltaMsg", "ReplicaFinMsg", "ReplicaMetrics",
    "ReplicaSet", "ReplicaStateMsg", "ReplicaVcMsg", "RunMetrics",
    "RuntimeConfig", "RuntimeMetrics", "RuntimeViewHandle",
    "SERVING_TRANSPORTS", "ServerShard", "ShardFinMsg", "ShardMetrics",
    "ShmRing", "SnapshotMetrics", "SubscribeMsg", "TRANSPORTS",
    "TraceConfig", "TraceHub", "UidDedup", "UnsubscribeMsg", "UpdateMsg",
    "WalWriter", "WireChannel",
    "conservative_vc", "dump_chrome_trace", "encode_frame", "explain_block",
    "explain_read", "load_snapshot", "prune_segments",
    "read_segment", "recover_to_vc", "require_tso", "save_snapshot",
    "snapshot_params", "staleness_timeline", "take_snapshot", "validate_vcs",
    "wal_segments",
]

# library logging etiquette: the "repro.runtime" hierarchy emits structured
# degradation warnings (replica poisoned/stale, publish drops, shed on/off,
# shm stale-cursor retries, membership op timeouts, WAL torn tails); a
# NullHandler keeps them silent unless the application configures logging.
_logging.getLogger("repro.runtime").addHandler(_logging.NullHandler())
