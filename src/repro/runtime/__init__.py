"""Asynchronous parameter-server runtime (Petuum-PS style).

Third implementation of the paper's consistency models, alongside the
event-driven simulator (:mod:`repro.core.server`, the executable spec) and
the SPMD sync layer (:mod:`repro.core.sync`).  All three share the Policy /
Consistency Controller split and are differentially tested against each other
in ``tests/test_runtime_conformance.py``.

Runs worker threads in-process (``transport="queue"``) or real forked
client processes over loopback sockets / shared-memory rings
(``transport="tcp" | "shm" | "proc"`` — see :mod:`repro.runtime.transport`),
with snapshot/restore of the master shard state in
:mod:`repro.runtime.snapshot`.
"""
from repro.runtime.messages import (AckMsg, Channel, ClockMarker, ClockMsg,
                                    DeliverMsg, FullyDelivered, ProcDoneMsg,
                                    ShardFinMsg, UpdateMsg)
from repro.runtime.runtime import (TRANSPORTS, ClientProcess, PSRuntime,
                                   RuntimeViewHandle)
from repro.runtime.shard import ServerShard
from repro.runtime.snapshot import (load_snapshot, save_snapshot,
                                    snapshot_params, take_snapshot)
from repro.runtime.transport import (FifoAssert, FrameDecoder, ShmRing,
                                     WireChannel, encode_frame)

__all__ = [
    "AckMsg", "Channel", "ClientProcess", "ClockMarker", "ClockMsg",
    "DeliverMsg", "FifoAssert", "FrameDecoder", "FullyDelivered",
    "PSRuntime", "ProcDoneMsg", "RuntimeViewHandle", "ServerShard",
    "ShardFinMsg", "ShmRing", "TRANSPORTS", "UpdateMsg", "WireChannel",
    "encode_frame", "load_snapshot", "save_snapshot", "snapshot_params",
    "take_snapshot",
]
