"""Sampled end-to-end tracing of updates and reads, with Perfetto export
and consistency audit trails.

The metrics tier (PR 7) answers *how much*: counters and windowed rates per
shard / process / replica / gateway.  This tier answers *why* and *where*:
every layer records fixed-size events into per-thread bounded ring buffers
— client outbox flush and clock/value blocking, wire write/decode, shard
dequeue/apply/lock-wait, WAL append/group-commit, serving publish, replica
ingest, gateway park/escalate/serve — so one update's life from ``Inc()``
to replica visibility is reconstructible after the fact.

Design rules (the same discipline as :mod:`repro.runtime.metrics`):

* **Per-thread, lock-free, bounded.**  Each recording thread owns one ring
  (``deque(maxlen=capacity)``, drop-oldest, drops counted); the hub's lock
  is taken only at ring *registration*, never on the append path.  Events
  are fixed-shape 6-tuples ``(kind, t0_ns, dur_ns, a, b, c)``.
* **Monotonic only.**  Timestamps are ``time.monotonic_ns()`` —
  ``CLOCK_MONOTONIC`` is system-wide on Linux, so events recorded in forked
  client processes land on the same timeline as the parent's shard events.
* **Near-zero when off.**  Every instrumentation site is gated on a plain
  ``rt.trace_on`` attribute read (one branch), exactly like
  ``rt.metrics_on``; with ``RuntimeConfig(trace=None)`` (the default) no
  ring is ever allocated.
* **No wire-format change.**  Spans are joined on identifiers the wire
  already carries: ``(proc, uid)`` for update parts, per-channel ``seq``
  for publish->ingest, ``(shard, clock)`` for commits.  Proc-mode rings
  ship to the parent in the existing quiesce payload over the ProcDone
  pipe.

``dump_chrome_trace`` exports the merged rings as Chrome trace-event JSON
(one track per thread per process, update lifelines as flow events) —
load the file at https://ui.perfetto.dev.  The audit helpers
(:func:`explain_read`, :func:`explain_block`, :func:`staleness_timeline`)
turn the same event log + the gateway's vc measurements into "name the
straggler" answers; they are surfaced as methods on ``PSRuntime``.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------------------
# event kinds — (kind, t0_ns, dur_ns, a, b, c); arg meaning per kind below
# ---------------------------------------------------------------------------

EV_BLOCK_CLOCK = 0    # span    a=proc  b=worker    c=straggler proc (-1 ?)
EV_BLOCK_VALUE = 1    # span    a=proc  b=worker    c=clock
EV_FLUSH = 2          # span    a=proc  b=clock     c=n_parts
EV_SEND = 3           # point   a=proc  b=uid       c=key        (flow ->)
EV_CLOCK = 4          # point   a=proc  b=clock
EV_WIRE_WRITE = 5     # span    a=n_msgs            c=channel name
EV_WIRE_DECODE = 6    # span    a=n_msgs            c=reader name
EV_SHARD_BATCH = 7    # span    a=shard b=n_msgs
EV_LOCK_WAIT = 8      # span    a=shard
EV_APPLY = 9          # span    a=shard b=n_parts   c=n_rows
EV_APPLY_PART = 10    # point   a=proc  b=uid       c=shard      (flow <-)
EV_WAL_APPEND = 11    # span    a=shard b=n_parts
EV_WAL_COMMIT = 12    # span    a=shard b=clock
EV_PUBLISH = 13       # span    a=shard b=clock     c=n_replicas
EV_PUBLISH_PART = 14  # point   a=shard b=seq       c=replica    (flow ->)
EV_INGEST = 15        # span    a=replica b=n_msgs
EV_INGEST_PART = 16   # point   a=shard b=seq       c=replica    (flow <-)
EV_REPLICA_VC = 17    # point   a=replica b=shard   c=staleness
EV_READ = 18          # span    a=slo (-1 any, -2 fresh) b=staleness c=source
EV_PARK = 19          # span    a=gateway           c=key
EV_ESCALATE = 20      # point   a=gateway           c=key
EV_EPOCH = 21         # point   a=epoch b=n_active

_NAMES = {
    EV_BLOCK_CLOCK: "block_clock", EV_BLOCK_VALUE: "block_value",
    EV_FLUSH: "outbox_flush", EV_SEND: "send_part", EV_CLOCK: "clock",
    EV_WIRE_WRITE: "wire_write", EV_WIRE_DECODE: "wire_decode",
    EV_SHARD_BATCH: "shard_batch", EV_LOCK_WAIT: "lock_wait",
    EV_APPLY: "apply", EV_APPLY_PART: "apply_part",
    EV_WAL_APPEND: "wal_append", EV_WAL_COMMIT: "wal_commit",
    EV_PUBLISH: "publish", EV_PUBLISH_PART: "publish_part",
    EV_INGEST: "ingest", EV_INGEST_PART: "ingest_part",
    EV_REPLICA_VC: "replica_vc", EV_READ: "read", EV_PARK: "park",
    EV_ESCALATE: "escalate", EV_EPOCH: "epoch",
}
_ARGS = {
    EV_BLOCK_CLOCK: ("proc", "worker", "straggler"),
    EV_BLOCK_VALUE: ("proc", "worker", "clock"),
    EV_FLUSH: ("proc", "clock", "n_parts"),
    EV_SEND: ("proc", "uid", "key"),
    EV_CLOCK: ("proc", "clock", ""),
    EV_WIRE_WRITE: ("n_msgs", "", "channel"),
    EV_WIRE_DECODE: ("n_msgs", "", "reader"),
    EV_SHARD_BATCH: ("shard", "n_msgs", ""),
    EV_LOCK_WAIT: ("shard", "", ""),
    EV_APPLY: ("shard", "n_parts", "n_rows"),
    EV_APPLY_PART: ("proc", "uid", "shard"),
    EV_WAL_APPEND: ("shard", "n_parts", ""),
    EV_WAL_COMMIT: ("shard", "clock", ""),
    EV_PUBLISH: ("shard", "clock", "n_replicas"),
    EV_PUBLISH_PART: ("shard", "seq", "replica"),
    EV_INGEST: ("replica", "n_msgs", ""),
    EV_INGEST_PART: ("shard", "seq", "replica"),
    EV_REPLICA_VC: ("replica", "shard", "staleness"),
    EV_READ: ("slo", "staleness", "source"),
    EV_PARK: ("gateway", "", "key"),
    EV_ESCALATE: ("gateway", "", "key"),
    EV_EPOCH: ("epoch", "n_active", ""),
}
# points render as 1us slices so Perfetto can bind their flow events
_POINT_KINDS = frozenset((EV_SEND, EV_CLOCK, EV_APPLY_PART,
                          EV_PUBLISH_PART, EV_INGEST_PART, EV_REPLICA_VC,
                          EV_ESCALATE, EV_EPOCH))

SLO_ANY = -1          # EV_READ a-field encoding of slo=None
SLO_FRESH = -2        # ... and of slo="fresh"


@dataclass(frozen=True)
class TraceConfig:
    """Normalized tracing knobs (``RuntimeConfig(trace=...)`` accepts
    ``True`` for defaults, a float sample rate, or a ``{"sample":,
    "capacity":}`` dict)."""
    sample: float = 1.0       # update-lifeline sampling rate in (0, 1]
    capacity: int = 1 << 15   # events per thread ring (drop-oldest)


def normalize_trace(spec) -> Optional[TraceConfig]:
    """``RuntimeConfig.trace`` -> ``TraceConfig`` or None (off)."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return TraceConfig()
    if isinstance(spec, TraceConfig):
        cfg = spec
    elif isinstance(spec, (int, float)) and not isinstance(spec, bool):
        cfg = TraceConfig(sample=float(spec))
    elif isinstance(spec, dict):
        unknown = set(spec) - {"sample", "capacity"}
        if unknown:
            raise ValueError(f"unknown trace keys {sorted(unknown)}; "
                             f"choose from ['capacity', 'sample']")
        cfg = TraceConfig(**spec)
    else:
        raise ValueError(f"trace must be None/True, a sample rate in (0, 1], "
                         f"a dict, or a TraceConfig — got {spec!r}")
    if not (0.0 < cfg.sample <= 1.0):
        raise ValueError(f"trace sample rate must be in (0, 1], "
                         f"got {cfg.sample}")
    if cfg.capacity < 256:
        raise ValueError(f"trace ring capacity must be >= 256, "
                         f"got {cfg.capacity}")
    return cfg


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


class _Ring:
    """One thread's bounded event buffer: single-writer append, drop-oldest
    with an explicit drop counter (the reconciliation tests assert zero)."""

    __slots__ = ("name", "cap", "buf", "dropped")

    def __init__(self, name: str, cap: int):
        self.name = name
        self.cap = cap
        self.buf: deque = deque(maxlen=cap)
        self.dropped = 0

    def add(self, ev: tuple) -> None:
        if len(self.buf) == self.cap:
            self.dropped += 1
        self.buf.append(ev)


class TraceHub:
    """Per-runtime (and, forked, per-client-process) event sink.

    Each thread lazily registers one :class:`_Ring` (the only locked step);
    ``span``/``point`` then append tuples with no shared state.  ``export``
    materializes every local ring; ``adopt`` merges rings shipped from a
    forked client over the quiesce pipe."""

    def __init__(self, cfg: TraceConfig, proc_label: str = "server"):
        self.cfg = cfg
        self.proc_label = proc_label
        self._uid_thr = int(cfg.sample * float(1 << 32))
        self._rings: List[_Ring] = []
        self._frozen: List[dict] = []
        self._lock = threading.Lock()
        self._tls = threading.local()

    # -- hot path ----------------------------------------------------------

    def _ring(self) -> _Ring:
        r = getattr(self._tls, "ring", None)
        if r is None:
            r = _Ring(threading.current_thread().name, self.cfg.capacity)
            self._tls.ring = r
            with self._lock:
                self._rings.append(r)
        return r

    def sampled(self, uid: int) -> bool:
        """Deterministic uid hash: the client's send and the shard's apply
        sample the same lifelines with no coordination."""
        return ((uid * 2654435761) & 0xFFFFFFFF) < self._uid_thr

    def span(self, kind: int, t0_ns: int, a=0, b=0, c=0) -> None:
        self._ring().add((kind, t0_ns, time.monotonic_ns() - t0_ns, a, b, c))

    def point(self, kind: int, a=0, b=0, c=0) -> None:
        self._ring().add((kind, time.monotonic_ns(), 0, a, b, c))

    # -- collection --------------------------------------------------------

    def export(self) -> List[dict]:
        """Materialize this process's rings (picklable: ships over the
        ProcDone pipe at quiesce)."""
        with self._lock:
            rings = list(self._rings)
        return [{"proc": self.proc_label, "thread": r.name,
                 "dropped": r.dropped, "events": list(r.buf)}
                for r in rings]

    def adopt(self, exported: Iterable[dict]) -> None:
        with self._lock:
            self._frozen.extend(exported)

    def all_rings(self) -> List[dict]:
        with self._lock:
            frozen = list(self._frozen)
        return self.export() + frozen

    def events(self, kinds=None) -> Iterable[tuple]:
        want = None if kinds is None else frozenset(kinds)
        for ring in self.all_rings():
            for ev in ring["events"]:
                if want is None or ev[0] in want:
                    yield ev

    def counts(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for ev in self.events():
            out[ev[0]] = out.get(ev[0], 0) + 1
        return out

    def dropped(self) -> int:
        return sum(r["dropped"] for r in self.all_rings())


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto-loadable)
# ---------------------------------------------------------------------------


def _update_flow_id(proc: int, uid: int) -> int:
    return (int(proc) << 44) | (int(uid) & ((1 << 44) - 1))


def _publish_flow_id(shard: int, replica: int, seq: int) -> int:
    return ((1 << 62) | (int(shard) << 52) | (int(replica) << 44)
            | (int(seq) & ((1 << 44) - 1)))


def dump_chrome_trace(hub: TraceHub, path: str) -> dict:
    """Write the merged event log as Chrome trace-event JSON.

    One pid per process label (parent shards = ``server``, each forked
    client = ``client-pN``), one tid per recording thread.  Update
    lifelines ride flow events: ``send_part`` -> ``apply_part`` joined on
    ``(proc, uid)``, ``publish_part`` -> ``ingest_part`` joined on the
    publish channel's ``(shard, replica, seq)`` — the shard track is the
    shared middle hop, so a lifeline reads client -> shard -> replica.
    Returns ``{"events": n, "dropped": n, "path": path}``."""
    rings = hub.all_rings()
    pids: Dict[str, int] = {}
    out: List[dict] = []
    base_ns = min((ev[1] for r in rings for ev in r["events"]), default=0)

    for tid, ring in enumerate(rings, start=1):
        pid = pids.setdefault(ring["proc"], len(pids) + 1)
        out.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": ring["thread"]}})
        for ev in ring["events"]:
            kind, t0, dur, a, b, c = ev
            ts = (t0 - base_ns) / 1000.0
            names = _ARGS[kind]
            args = {k: v for k, v in zip(names, (a, b, c)) if k != ""}
            rec = {"ph": "X", "name": _NAMES[kind], "cat": "ps",
                   "ts": ts, "dur": max(dur / 1000.0, 1.0),
                   "pid": pid, "tid": tid, "args": args}
            out.append(rec)
            flow = None
            if kind == EV_SEND:
                flow = ("s", _update_flow_id(a, b))
            elif kind == EV_APPLY_PART:
                flow = ("f", _update_flow_id(a, b))
            elif kind == EV_PUBLISH_PART:
                flow = ("s", _publish_flow_id(a, c, b))
            elif kind == EV_INGEST_PART:
                flow = ("f", _publish_flow_id(a, c, b))
            if flow is not None:
                ph, fid = flow
                frec = {"ph": ph, "id": fid, "name": "lifeline",
                        "cat": "lifeline", "ts": ts, "pid": pid, "tid": tid}
                if ph == "f":
                    frec["bp"] = "e"
                out.append(frec)
    for label, pid in pids.items():
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": label}})
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return {"events": sum(len(r["events"]) for r in rings),
            "dropped": hub.dropped(), "path": path}


# ---------------------------------------------------------------------------
# consistency audit trails
# ---------------------------------------------------------------------------


def explain_read(result) -> dict:
    """Why did this read land where it did?  Pure function of the
    :class:`~repro.runtime.serving.gateway.ReadResult` audit stamps: names
    the exact lagging ``(shard, proc)`` pair and the vector-clock gap that
    disqualified the best replica (forcing a park/escalation), or reports
    the replica hit."""
    lagging = None
    if getattr(result, "lag_shard", -1) >= 0:
        lagging = (int(result.lag_shard), int(result.lag_proc))
    gap = int(getattr(result, "vc_gap", 0))
    if result.source == "master" and result.escalated and lagging:
        summary = (f"escalated to master: shard {lagging[0]} had applied "
                   f"only through clock vc[{lagging[1]}] of process "
                   f"{lagging[1]} on the laggiest replica — {gap} clock(s) "
                   f"behind the master frontier, above the requested "
                   f"slo={result.slo!r}")
    elif result.source == "master":
        summary = (f"served by the master (slo={result.slo!r}; "
                   f"no qualifying replica consulted or fresh requested)")
    elif result.source == "cache":
        summary = (f"cache hit re-measured at staleness "
                   f"{result.staleness} <= slo={result.slo!r}")
    else:
        summary = (f"replica served at measured staleness "
                   f"{result.staleness} <= slo={result.slo!r}")
    return {"source": result.source, "escalated": bool(result.escalated),
            "staleness": int(result.staleness), "slo": result.slo,
            "waited_s": float(result.waited_s), "lagging": lagging,
            "vc_gap": gap, "summary": summary}


def explain_block(hub: TraceHub, process: Optional[int] = None,
                  worker: Optional[int] = None) -> dict:
    """Attribute a worker's clock/value stalls to the straggler it waited
    on, from the recorded ``block_clock`` / ``block_value`` spans."""
    by_straggler: Dict[int, float] = {}
    clock_s = value_s = 0.0
    n = 0
    for kind, _t0, dur, a, b, c in hub.events((EV_BLOCK_CLOCK,
                                               EV_BLOCK_VALUE)):
        if process is not None and a != process:
            continue
        if worker is not None and b != worker:
            continue
        n += 1
        if kind == EV_BLOCK_CLOCK:
            clock_s += dur / 1e9
            if c >= 0:
                by_straggler[c] = by_straggler.get(c, 0.0) + dur / 1e9
        else:
            value_s += dur / 1e9
    straggler = (max(by_straggler, key=by_straggler.get)
                 if by_straggler else None)
    who = (f"process {process}" if process is not None else "all processes")
    if straggler is not None:
        summary = (f"{who} spent {clock_s:.3f}s clock-blocked "
                   f"(+{value_s:.3f}s value-blocked) over {n} stall(s); "
                   f"the dominant straggler holding the frontier was "
                   f"process {straggler} "
                   f"({by_straggler[straggler]:.3f}s attributed)")
    else:
        summary = (f"{who} recorded {n} stall(s): {clock_s:.3f}s "
                   f"clock-blocked, {value_s:.3f}s value-blocked")
    return {"n_blocks": n, "clock_blocked_s": clock_s,
            "value_blocked_s": value_s, "straggler": straggler,
            "by_straggler": by_straggler, "summary": summary}


def staleness_timeline(hub: TraceHub, shard: int,
                       bound: Optional[int] = None) -> dict:
    """Measured master−replica staleness over time for one shard, from the
    ``replica_vc`` adoption events, against the policy bound (None for
    value-only policies).  Points are ``(t_s, replica, staleness)`` with
    ``t_s`` relative to the first recorded event."""
    evs = sorted(hub.events((EV_REPLICA_VC,)), key=lambda e: e[1])
    base = evs[0][1] if evs else 0
    points: List[Tuple[float, int, int]] = [
        ((t0 - base) / 1e9, int(a), int(c))
        for _k, t0, _d, a, b, c in evs if b == shard]
    return {"shard": int(shard), "bound": bound,
            "max_staleness": max((p[2] for p in points), default=0),
            "points": points}
