"""Wire protocol of the threaded parameter-server runtime.

All cross-thread communication goes through :class:`Channel` objects — FIFO
per (sender, receiver) pair, mirroring the simulator's per-channel delivery
ordering (``server.py`` ``_last_sched`` / ``_last_seq_seen``).  A channel
stamps every message with a per-channel sequence number under its lock so the
receiver can *assert* FIFO delivery instead of assuming it; violations are
recorded in ``RunStats.violations`` exactly like the simulator does.

Message flow (client process p, server shard s):

    p -> s : UpdateMsg   one hash-partitioned row-slice of an Inc
             ClockMsg    process p completed period `clock`
             AckMsg      a DeliverMsg was applied at p
             AckBatchMsg coalesced acks: one frame per (client, shard, flush)
    s -> p : DeliverMsg  propagate an update part to a peer process cache
             ClockMarker shard-side echo of a peer's ClockMsg (frontier)
             FullyDelivered
                         every peer acked an update part — the origin
                         worker's unsynchronized accumulator may shrink

Elastic membership (epoch protocol, :mod:`repro.runtime.membership`):

    mgr -> p : EpochMsg     announce a new epoch (rides an active shard's
                            FIFO channel); the client swaps its router
    p -> s   : EpochAckMsg  barrier: FIFO-after the client's last old-epoch
                            Update/Clock on this channel
    mgr -> s : EpochBeginMsg / InstallMsg
                            in-parent control (shards never leave the
                            parent): pending partition / re-partitioned
                            dense blocks + conservative vc seed

Serving tier (read replica r, see :mod:`repro.runtime.serving`):

    r -> s : SubscribeMsg / UnsubscribeMsg
                         control messages carrying the shard->replica publish
                         channel; always sent in-process (the shards and the
                         serving tier both live in the parent), so holding a
                         live channel object in the message is safe
    s -> r : ReplicaStateMsg
                         in-stream bootstrap: the shard's current dense
                         partition in the snapshot payload format, stamped
                         with the shard's applied vector clock
             ReplicaDeltaMsg
                         coalesced row deltas applied by the shard since the
                         last publish cycle (rows may repeat: apply-additive)
             ReplicaVcMsg
                         the shard's applied per-process vector clock; FIFO
                         after every delta it covers, so a replica holding
                         vc[p] = c has applied all of p's updates ts <= c
             ReplicaFinMsg
                         unsubscribe acknowledged: nothing further will be
                         published on this channel
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

import numpy as np

SHUTDOWN = None  # sentinel put on an inbox to stop its thread


@dataclass
class UpdateMsg:
    uid: int                 # unique id of this update *part*
    worker: int              # global worker-thread id
    process: int             # origin client process
    ts: int                  # clock timestamp (0-based period index)
    key: str
    rows: np.ndarray         # row ids of the (R, C) key matrix in this part
    delta: np.ndarray        # (len(rows), C) row deltas
    epoch: int = 0           # membership epoch the sender routed under
    seq: int = -1

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class ClockMsg:
    process: int
    clock: int               # period just completed by `process`
    epoch: int = 0           # membership epoch at send time
    load: object = None      # optional (LOAD_LEN,) float64 counter snapshot
    seq: int = -1            # (repro.runtime.metrics): the process's load,
                             # taken at this boundary, piggybacked on the
                             # control message it already sends — control
                             # frames stay pickled on every wire, so the
                             # array rides along under queue/shm/tcp alike


@dataclass
class AckMsg:
    uid: int
    process: int             # acking process
    seq: int = -1


@dataclass
class AckBatchMsg:
    """All acks of one (client, shard) flush in a single message: the uids
    travel as one int64 buffer instead of one AckMsg per delivered part."""
    uids: np.ndarray         # int64 uids of the DeliverMsgs applied
    process: int             # acking process
    seq: int = -1


@dataclass
class DeliverMsg:
    uid: int
    worker: int
    process: int             # origin process
    shard: int
    ts: int
    key: str
    rows: np.ndarray
    delta: np.ndarray
    seq: int = -1

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class ClockMarker:
    process: int             # origin process whose period completed
    shard: int
    clock: int
    epoch: int = 0           # sender shard's epoch at send (stale-marker
    seq: int = -1            # filter across slot re-activations)


@dataclass
class FullyDelivered:
    uid: int
    worker: int
    key: str
    rows: np.ndarray
    delta: np.ndarray
    shard: int
    seq: int = -1


# ---------------------------------------------------------------------------
# serving-tier messages (read replicas, repro.runtime.serving)
# ---------------------------------------------------------------------------


@dataclass
class SubscribeMsg:
    """A read replica subscribes to a shard's publish stream.  ``channel``
    is the live shard->replica publish channel (Channel or WireChannel) —
    subscribe control always travels in-process (never over a wire), so the
    object reference is valid at the shard.  With ``want_state`` the shard
    first sends a :class:`ReplicaStateMsg` (in-stream bootstrap), then every
    subsequent delta, all FIFO on the same channel."""
    replica: int
    channel: object
    want_state: bool = True
    seq: int = -1


@dataclass
class UnsubscribeMsg:
    """Stop publishing to this replica; the shard answers with a final
    :class:`ReplicaFinMsg` on the publish channel (FIFO-last), after which
    the serving tier may safely tear the channel down."""
    replica: int
    seq: int = -1


@dataclass
class ReplicaStateMsg:
    """In-stream bootstrap: the shard's dense partition at subscribe time,
    in the snapshot payload format (``{key: {"rows", "values"}}``, exactly
    :meth:`ServerShard.state`), stamped with the shard's applied vector
    clock.  The replica scatters the rows into its full-key buffers — the
    same re-partition path :func:`repro.runtime.snapshot.assemble_master`
    uses — and adopts the stamp as its per-shard vector clock."""
    shard: int
    state: dict              # {key: {"rows": int64, "values": (n, C)}}
    clock_vc: np.ndarray     # (n_proc,) applied frontier at snapshot point
    seq: int = -1
    # membership epoch of the cut: the replica stamps the covered rows so
    # late-arriving older-epoch deltas (already folded into this state by
    # the migration reassembly) can be recognized and dropped
    epoch: int = -1


@dataclass
class ReplicaDeltaMsg:
    """Row deltas the shard applied since its last publish cycle, coalesced
    per key (rows may repeat across source parts: apply with np.add.at)."""
    shard: int
    key: str
    rows: np.ndarray         # global row ids
    delta: np.ndarray        # (len(rows), C)
    seq: int = -1
    epoch: int = -1          # membership epoch the publisher applied under

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class ReplicaVcMsg:
    """The shard's applied per-process vector clock.  Sent FIFO after every
    delta it covers: a replica whose vc for this shard is ``c`` at entry
    ``p`` has applied every update of process p timestamped <= c that
    touches this shard's rows."""
    shard: int
    clock_vc: np.ndarray     # (n_proc,)
    seq: int = -1


@dataclass
class ReplicaFinMsg:
    """Unsubscribe acknowledged: nothing further on this publish channel."""
    shard: int
    seq: int = -1


# ---------------------------------------------------------------------------
# elastic membership (epoch protocol, repro.runtime.membership)
# ---------------------------------------------------------------------------


@dataclass
class EpochMsg:
    """Membership announce, manager -> every client (rides a designated
    active shard's FIFO channel; ``shard`` names it for the FIFO assert).
    The client swaps its key->shard router to ``(epoch, active)`` atomically
    w.r.t. its own sends, then acks on every involved channel."""
    epoch: int
    active: tuple            # active slot ids of the new epoch
    shard: int               # channel owner (the announce rides its FIFO)
    seq: int = -1


@dataclass
class EpochAckMsg:
    """Client -> shard epoch barrier: FIFO-after the client's last
    old-epoch Update/Clock on this channel.  A shard holding acks from
    every process will never see another old-epoch update."""
    process: int
    epoch: int
    seq: int = -1


@dataclass
class EpochBeginMsg:
    """Manager -> shard (in-parent only, never pickled): the pending epoch's
    partition.  Enqueued before the client announce, so it always precedes
    the first ack in the shard's inbox."""
    epoch: int
    part: object             # membership.Partition
    seq: int = -1


@dataclass
class InstallMsg:
    """Manager -> shard (in-parent only): adopt the new partition.
    ``blocks`` is the slot's re-partitioned dense state ({key: (n, C)}), or
    None for a retiring slot; ``seed_vc`` is the conservative applied-vc
    seed (element-wise min over the handoff contributors)."""
    epoch: int
    part: object             # membership.Partition
    blocks: object           # Optional[Dict[str, np.ndarray]]
    seed_vc: np.ndarray
    seq: int = -1


@dataclass
class ProcDoneMsg:
    """Client process finished all its clocks: no more Update/Clock msgs
    (acks for in-flight deliveries may still follow).  Multi-process quiesce,
    leg 1: every shard counts these."""
    process: int
    epoch: int = 0           # client's epoch at send (held + replayed like
    seq: int = -1            # updates if it races a pending install)


@dataclass
class ShardFinMsg:
    """Shard has seen ProcDone from every process and drained its pending
    and queued deliveries: nothing further will be sent on this channel.
    Multi-process quiesce, leg 2: a client that has collected the fin of
    every shard holds its complete final state."""
    shard: int
    seq: int = -1


@dataclass
class Channel:
    """FIFO edge into a receiver's inbox, stamping per-channel seq numbers.

    The stamp and the enqueue happen under one lock so the sequence numbers
    are monotone in *queue order* even with multiple sender threads sharing
    the channel (all workers of a process send on the same proc->shard edge).
    """

    name: str
    inbox: queue.Queue
    _seq: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def send(self, msg) -> None:
        with self._lock:
            msg.seq = self._seq
            self._seq += 1
            self.inbox.put(msg)

    def send_many(self, msgs) -> None:
        """Stamp and enqueue a batch atomically w.r.t. other senders."""
        with self._lock:
            for m in msgs:
                m.seq = self._seq
                self._seq += 1
                self.inbox.put(m)


def group_by_channel(pairs):
    """[(chan, msg), ...] -> [(chan, [msgs...]), ...], preserving each
    channel's message order (the unit senders batch into one frame)."""
    by = {}
    for chan, msg in pairs:
        by.setdefault(id(chan), (chan, []))[1].append(msg)
    return list(by.values())


def pump_inbox(inbox: queue.Queue, handle_batch, cap: int = 256) -> None:
    """Drain an inbox in coalesced batches (shared by shard and client comm
    loops): block for one message, greedily grab up to ``cap``, hand the
    batch to ``handle_batch`` (returns True on shutdown), mark all done."""
    while True:
        batch = [inbox.get()]
        try:
            while len(batch) < cap:
                batch.append(inbox.get_nowait())
        except queue.Empty:
            pass
        shutdown = handle_batch(batch)
        for _ in batch:
            inbox.task_done()
        if shutdown:
            return
