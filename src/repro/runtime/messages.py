"""Wire protocol of the threaded parameter-server runtime.

All cross-thread communication goes through :class:`Channel` objects — FIFO
per (sender, receiver) pair, mirroring the simulator's per-channel delivery
ordering (``server.py`` ``_last_sched`` / ``_last_seq_seen``).  A channel
stamps every message with a per-channel sequence number under its lock so the
receiver can *assert* FIFO delivery instead of assuming it; violations are
recorded in ``RunStats.violations`` exactly like the simulator does.

Message flow (client process p, server shard s):

    p -> s : UpdateMsg   one hash-partitioned row-slice of an Inc
             ClockMsg    process p completed period `clock`
             AckMsg      a DeliverMsg was applied at p
    s -> p : DeliverMsg  propagate an update part to a peer process cache
             ClockMarker shard-side echo of a peer's ClockMsg (frontier)
             FullyDelivered
                         every peer acked an update part — the origin
                         worker's unsynchronized accumulator may shrink
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

import numpy as np

SHUTDOWN = None  # sentinel put on an inbox to stop its thread


@dataclass
class UpdateMsg:
    uid: int                 # unique id of this update *part*
    worker: int              # global worker-thread id
    process: int             # origin client process
    ts: int                  # clock timestamp (0-based period index)
    key: str
    rows: np.ndarray         # row ids of the (R, C) key matrix in this part
    delta: np.ndarray        # (len(rows), C) row deltas
    seq: int = -1

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class ClockMsg:
    process: int
    clock: int               # period just completed by `process`
    seq: int = -1


@dataclass
class AckMsg:
    uid: int
    process: int             # acking process
    seq: int = -1


@dataclass
class DeliverMsg:
    uid: int
    worker: int
    process: int             # origin process
    shard: int
    ts: int
    key: str
    rows: np.ndarray
    delta: np.ndarray
    seq: int = -1

    @property
    def nbytes(self) -> int:
        return int(self.delta.nbytes)


@dataclass
class ClockMarker:
    process: int             # origin process whose period completed
    shard: int
    clock: int
    seq: int = -1


@dataclass
class FullyDelivered:
    uid: int
    worker: int
    key: str
    rows: np.ndarray
    delta: np.ndarray
    shard: int
    seq: int = -1


@dataclass
class Channel:
    """FIFO edge into a receiver's inbox, stamping per-channel seq numbers.

    The stamp and the enqueue happen under one lock so the sequence numbers
    are monotone in *queue order* even with multiple sender threads sharing
    the channel (all workers of a process send on the same proc->shard edge).
    """

    name: str
    inbox: queue.Queue
    _seq: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def send(self, msg) -> None:
        with self._lock:
            msg.seq = self._seq
            self._seq += 1
            self.inbox.put(msg)
