"""Server shard of the threaded PS runtime (paper §4.1).

Each shard is one thread owning a hash partition of every key's rows, stored
in real :class:`repro.core.tables.Table` objects (row ``r`` of a key lives on
shard ``r % n_shards`` — the same rule as ``Table.server_partition``).  The
shard applies incoming update parts to its tables (the master copy), then
propagates them to every peer process cache, echoes client clock messages as
:class:`ClockMarker` (the delivery frontier the clock bound blocks on), and
tracks acks so the origin worker's unsynchronized accumulator can shrink only
once an update really is visible everywhere — the paper's definition of a
*synchronized* update.

Strong-VAP (paper §2, "half-synchronized" updates): before starting a
delivery the shard consults :func:`controller.strong_delivery_gate`; gated
updates queue FIFO per key and are released as acks free half-sync budget,
mirroring ``server.py`` ``_try_start_delivery`` / ``_on_deliver``.  As in the
simulator, a queued update is *not* counted against the clock frontier — the
marker echo is immediate — so the two bounds compose identically in both
implementations.
"""
from __future__ import annotations

import queue
import threading
from collections import defaultdict, deque
from typing import Dict, Tuple

import numpy as np

from repro.core import controller
from repro.core.tables import Table
from repro.runtime.messages import (SHUTDOWN, AckMsg, ClockMarker, ClockMsg,
                                    DeliverMsg, FullyDelivered, UpdateMsg)


class ServerShard:
    def __init__(self, rt, sid: int):
        self.rt = rt
        self.sid = sid
        self.inbox: queue.Queue = queue.Queue()
        # master state: one Table per key, holding only this shard's rows
        self.tables: Dict[str, Table] = {}
        for key, x0 in rt._x0.items():
            t = Table(f"{key}@shard{sid}", n_cols=x0.shape[1], dtype=np.float64)
            for r in rt._shard_rows[key][sid]:
                t.inc(int(r), x0[r].copy())
            self.tables[key] = t
        # strong-VAP: per-key magnitude of half-synchronized updates
        self.halfsync: Dict[str, np.ndarray] = {
            key: np.zeros_like(x0) for key, x0 in rt._x0.items()}
        # uid -> (msg, remaining acks)
        self.pending: Dict[int, Tuple[UpdateMsg, int]] = {}
        # per-key FIFO of updates waiting on the strong delivery gate
        self.queued: Dict[str, deque] = defaultdict(deque)
        self._last_seq = defaultdict(lambda: -1)   # per origin process
        self.thread = threading.Thread(
            target=self._loop, name=f"ps-shard-{sid}", daemon=True)

    # ------------------------------------------------------------------ loop
    def _loop(self) -> None:
        while True:
            msg = self.inbox.get()
            if msg is SHUTDOWN:
                self.inbox.task_done()
                return
            try:
                self._handle(msg)
            except BaseException as e:            # surface into wait()
                self.rt._record_error(e)
            finally:
                self.inbox.task_done()
                self.rt._msg_done()

    def _handle(self, msg) -> None:
        rt = self.rt
        if rt.check:
            sender = getattr(msg, "process", None)
            if sender is not None:
                last = self._last_seq[sender]
                if msg.seq != last + 1:
                    rt._violation(f"FIFO violation: proc {sender}->shard "
                                  f"{self.sid} seq {msg.seq} after {last}")
                self._last_seq[sender] = msg.seq

        if isinstance(msg, UpdateMsg):
            self._on_update(msg)
        elif isinstance(msg, AckMsg):
            self._on_ack(msg)
        elif isinstance(msg, ClockMsg):
            # echo the period-completed marker to every peer.  All of the
            # process's period-<=clock updates precede this message on the
            # same FIFO channel, so their DeliverMsgs are already enqueued
            # ahead of the markers sent here.
            for q in range(rt.n_proc):
                if q != msg.process:
                    rt._send(rt._chan_sp[self.sid][q],
                             ClockMarker(msg.process, self.sid, msg.clock))
        else:
            raise TypeError(f"shard {self.sid}: unexpected message {msg!r}")

    # --------------------------------------------------------------- updates
    def _on_update(self, msg: UpdateMsg) -> None:
        rt = self.rt
        table = self.tables[msg.key]
        for i, r in enumerate(msg.rows):
            table.inc(int(r), msg.delta[i])
        if rt.n_proc == 1:
            # no peers to propagate to: the update is synchronized already
            rt._send(rt._chan_sp[self.sid][msg.process],
                     FullyDelivered(msg.uid, msg.worker, msg.key, msg.rows,
                                    msg.delta, self.sid))
            return
        if self.queued[msg.key] or not controller.strong_delivery_gate(
                rt.policy, self.halfsync[msg.key][msg.rows], msg.delta):
            self.queued[msg.key].append(msg)
            return
        self._start_delivery(msg)

    def _start_delivery(self, msg: UpdateMsg) -> None:
        rt = self.rt
        hs = self.halfsync[msg.key]
        hs[msg.rows] += np.abs(msg.delta)
        if rt.check:
            mx = float(np.max(hs[msg.rows])) if msg.rows.size else 0.0
            with rt._slock:
                rt.stats.max_halfsync_mag = max(rt.stats.max_halfsync_mag, mx)
        n = 0
        for q in range(rt.n_proc):
            if q == msg.process:
                continue
            rt._send(rt._chan_sp[self.sid][q],
                     DeliverMsg(msg.uid, msg.worker, msg.process, self.sid,
                                msg.ts, msg.key, msg.rows, msg.delta))
            n += 1
        with rt._slock:
            rt.stats.n_messages += n
            rt.stats.bytes_sent += msg.nbytes * n
        self.pending[msg.uid] = (msg, n)

    def _on_ack(self, ack: AckMsg) -> None:
        rt = self.rt
        msg, remaining = self.pending[ack.uid]
        remaining -= 1
        if remaining > 0:
            self.pending[ack.uid] = (msg, remaining)
            return
        del self.pending[ack.uid]
        hs = self.halfsync[msg.key]
        res = hs[msg.rows] - np.abs(msg.delta)
        hs[msg.rows] = np.where(np.abs(res) < 1e-12, 0.0, res)
        rt._send(rt._chan_sp[self.sid][msg.process],
                 FullyDelivered(msg.uid, msg.worker, msg.key, msg.rows,
                                msg.delta, self.sid))
        # freed half-sync budget: release queued deliveries for this key FIFO
        dq = self.queued.get(msg.key)
        while dq:
            nxt = dq[0]
            if controller.strong_delivery_gate(
                    rt.policy, self.halfsync[nxt.key][nxt.rows], nxt.delta):
                dq.popleft()
                self._start_delivery(nxt)
            else:
                break

    # ------------------------------------------------------------- snapshots
    def rows_snapshot(self, key: str) -> Dict[int, np.ndarray]:
        """Owned rows of `key` (call only when the runtime is quiesced)."""
        return {rid: row.get() for rid, row in self.tables[key].rows()}
