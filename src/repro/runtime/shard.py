"""Server shard of the PS runtime (paper §4.1).

Each shard is one thread owning a hash partition of every key's rows (row
``r`` of a key lives on shard ``r % n_shards`` — the same rule as
``Table.server_partition``), held as one **dense contiguous numpy block per
key** so a batch of row updates applies as a single vectorized
``np.add.at`` over the concatenated row indices instead of a Python loop of
``Table.inc`` calls (numpy releases the GIL inside the fancy-index kernels,
which is what lets shard threads keep up with multiple worker processes).
``state()``/``load_state()`` (:mod:`repro.runtime.snapshot`) and
``read_rows()`` (live locked master reads) are the row-state interfaces.

The shard applies incoming update parts to the master block, then
propagates them to every peer process cache, echoes client clock messages
as :class:`ClockMarker` (the delivery frontier the clock bound blocks on),
and tracks acks so the origin worker's unsynchronized accumulator can
shrink only once an update really is visible everywhere — the paper's
definition of a *synchronized* update.

Strong-VAP (paper §2, "half-synchronized" updates): before starting a
delivery the shard consults :func:`controller.strong_delivery_gate`; gated
updates queue FIFO per key and are released as acks free half-sync budget,
mirroring ``server.py`` ``_try_start_delivery`` / ``_on_deliver``.  As in
the simulator, a queued update is *not* counted against the clock frontier
— the marker echo is immediate — so the two bounds compose identically in
both implementations.

Multi-process quiesce: when the runtime runs with a real transport, each
client sends :class:`ProcDoneMsg` after its last clock; once every process
is done and ``pending``/``queued`` have drained, the shard broadcasts
:class:`ShardFinMsg` (FIFO-after everything else it will ever send), which
is the client's signal that its inbound stream is complete.

Serving tier (:mod:`repro.runtime.serving`): the shard additionally keeps
``clock_vc`` — its **applied vector clock** over client processes
(``clock_vc[p]`` = highest period of p whose updates this shard has applied;
exact because ClockMsg is FIFO-after the period's updates on the p->shard
channel) — and publishes to subscribed read replicas: coalesced per-key row
deltas after every apply cycle, followed by a ``ReplicaVcMsg`` stamp, all
FIFO on the per-replica publish channel.  A replica subscribing mid-run is
bootstrapped **in-stream**: the shard answers with its current dense
partition (snapshot payload format) plus vc stamp before any further delta,
so the replica's view is exact from the first frame it applies.
"""
from __future__ import annotations

import queue
import threading
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import controller
from repro.runtime.messages import (SHUTDOWN, AckBatchMsg, AckMsg, Channel,
                                    ClockMarker, ClockMsg, DeliverMsg,
                                    FullyDelivered, ProcDoneMsg, ReplicaDeltaMsg,
                                    ReplicaFinMsg, ReplicaStateMsg, ReplicaVcMsg,
                                    ShardFinMsg, SubscribeMsg, UnsubscribeMsg,
                                    UpdateMsg, group_by_channel, pump_inbox)
from repro.runtime.transport import FifoAssert

_BATCH = 256        # max messages coalesced per apply/dispatch cycle


class ServerShard:
    def __init__(self, rt, sid: int):
        self.rt = rt
        self.sid = sid
        self.inbox: queue.Queue = queue.Queue()
        self.lock = threading.Lock()      # guards .dense for live reads
        # master state: one dense (n_owned_rows, C) block per key; the
        # global row `r` (with r % n_shards == sid) lives at r // n_shards
        self.dense: Dict[str, np.ndarray] = {
            key: x0[rt._shard_rows[key][sid]].copy()
            for key, x0 in rt._x0.items()}
        # strong-VAP: per-key magnitude of half-synchronized updates
        self.halfsync: Dict[str, np.ndarray] = {
            key: np.zeros_like(x0) for key, x0 in rt._x0.items()}
        # uid -> (msg, remaining acks)
        self.pending: Dict[int, Tuple[UpdateMsg, int]] = {}
        # per-key FIFO of updates waiting on the strong delivery gate
        self.queued: Dict[str, deque] = defaultdict(deque)
        self._fifo = FifoAssert()          # per origin process
        self._done_procs: set = set()      # multi-process quiesce, leg 1
        self._fin_sent = False
        self._outbox: List[Tuple[Channel, object]] = []
        # serving tier: applied per-process vector clock (guarded by .lock
        # for consistent reads from the gateway) + replica publish channels
        self.clock_vc = np.full(rt.n_proc, -1, dtype=np.int64)
        self.subscribers: Dict[int, object] = {}   # replica id -> channel
        self._pub: Dict[int, List[object]] = {}    # pending publish per replica
        self._vc_dirty = False
        self.thread = threading.Thread(
            target=self._loop, name=f"ps-shard-{sid}", daemon=True)

    # ------------------------------------------------------------------ loop
    def _loop(self) -> None:
        pump_inbox(self.inbox, self._handle_batch, cap=_BATCH)

    def _handle_batch(self, batch: list) -> bool:
        """Coalesce runs of UpdateMsgs into one vectorized apply, dispatch
        everything else in arrival order, flush sends per channel."""
        rt = self.rt
        shutdown = False
        done = 0
        run: List[UpdateMsg] = []
        for msg in batch:
            if msg is SHUTDOWN:
                shutdown = True
                break
            done += 1
            try:
                if rt.check:
                    sender = getattr(msg, "process", None)
                    if sender is not None:
                        err = self._fifo.check(sender, msg.seq)
                        if err:
                            rt._violation(f"FIFO violation: proc {sender}->"
                                          f"shard {self.sid} {err}")
                if isinstance(msg, UpdateMsg):
                    run.append(msg)
                else:
                    self._flush_updates(run)
                    run = []
                    self._handle(msg)
            except BaseException as e:          # surface into wait()
                rt._record_error(e)
        try:
            self._flush_updates(run)
            if rt._proc_mode and not shutdown:
                self._maybe_fin()
            self._flush_publish()
        except BaseException as e:
            rt._record_error(e)
        self._flush_outbox()
        # in-flight decrements must come *after* the sends this batch caused
        # were enqueued (incrementing the counter), else the quiesce wait can
        # observe a transient 0 and shut down ahead of late deliveries
        for _ in range(done):
            rt._msg_done()
        return shutdown

    # --------------------------------------------------------------- sends
    def _send(self, chan: Channel, msg) -> None:
        self._outbox.append((chan, msg))

    def _flush_outbox(self) -> None:
        """Per-channel batched send (one frame per channel per cycle)."""
        if not self._outbox:
            return
        pairs, self._outbox = self._outbox, []
        for chan, msgs in group_by_channel(pairs):
            self.rt._send_many(chan, msgs)

    # ------------------------------------------------------------- dispatch
    def _handle(self, msg) -> None:
        rt = self.rt
        if isinstance(msg, AckMsg):
            with rt._slock:
                rt.stats.n_ack_msgs += 1
                rt.stats.n_acked_updates += 1
            self._ack_uid(msg.uid)
        elif isinstance(msg, AckBatchMsg):
            with rt._slock:
                rt.stats.n_ack_msgs += 1
                rt.stats.n_acked_updates += len(msg.uids)
            for uid in msg.uids:
                self._ack_uid(int(uid))
        elif isinstance(msg, ClockMsg):
            # applied vector clock: the process's period-<=clock updates are
            # FIFO-before this message, so they are already in .dense
            with self.lock:
                self.clock_vc[msg.process] = max(
                    self.clock_vc[msg.process], msg.clock)
            self._vc_dirty = True
            # echo the period-completed marker to every peer.  All of the
            # process's period-<=clock updates precede this message on the
            # same FIFO channel, so their DeliverMsgs are already enqueued
            # ahead of the markers sent here.
            for q in range(rt.n_proc):
                if q != msg.process:
                    self._send(rt._chan_sp[self.sid][q],
                               ClockMarker(msg.process, self.sid, msg.clock))
        elif isinstance(msg, SubscribeMsg):
            self._on_subscribe(msg)
        elif isinstance(msg, UnsubscribeMsg):
            self._on_unsubscribe(msg)
        elif isinstance(msg, ProcDoneMsg):
            self._done_procs.add(msg.process)
        else:
            raise TypeError(f"shard {self.sid}: unexpected message {msg!r}")

    # --------------------------------------------------------------- updates
    def _flush_updates(self, run: List[UpdateMsg]) -> None:
        """Apply a run of update parts as one vectorized op per key, then
        route each through the (per-message) delivery state machine."""
        if not run:
            return
        rt = self.rt
        by_key: Dict[str, List[UpdateMsg]] = {}
        for msg in run:
            by_key.setdefault(msg.key, []).append(msg)
        with self.lock:
            for key, msgs in by_key.items():
                dense = self.dense[key]
                if len(msgs) == 1:
                    m = msgs[0]
                    # rows are unique within one part: plain fancy-index add
                    dense[m.rows // rt.n_shards] += m.delta
                    rows, delta = m.rows, m.delta
                else:
                    rows = np.concatenate([m.rows for m in msgs])
                    delta = np.concatenate([m.delta for m in msgs])
                    # rows may repeat across parts: np.add.at accumulates
                    np.add.at(dense, rows // rt.n_shards, delta)
                # serving: one coalesced delta per key per cycle per replica
                # (global row ids; the arrays are shared — receivers only read)
                for rid in self.subscribers:
                    self._pub.setdefault(rid, []).append(
                        ReplicaDeltaMsg(self.sid, key, rows, delta))
        for msg in run:
            self._route_delivery(msg)

    def _route_delivery(self, msg: UpdateMsg) -> None:
        rt = self.rt
        if rt.n_proc == 1:
            # no peers to propagate to: the update is synchronized already
            if rt.policy.value_bounded:
                self._send(rt._chan_sp[self.sid][msg.process],
                           FullyDelivered(msg.uid, msg.worker, msg.key,
                                          msg.rows, msg.delta, self.sid))
            return
        if self.queued[msg.key] or not controller.strong_delivery_gate(
                rt.policy, self.halfsync[msg.key][msg.rows], msg.delta):
            self.queued[msg.key].append(msg)
            return
        self._start_delivery(msg)

    def _start_delivery(self, msg: UpdateMsg) -> None:
        rt = self.rt
        track = rt.policy.value_bounded   # ack cycle feeds VAP accounting only
        if track:
            hs = self.halfsync[msg.key]
            hs[msg.rows] += np.abs(msg.delta)
            if rt.check:
                mx = float(np.max(hs[msg.rows])) if msg.rows.size else 0.0
                with rt._slock:
                    rt.stats.max_halfsync_mag = max(
                        rt.stats.max_halfsync_mag, mx)
        n = 0
        for q in range(rt.n_proc):
            if q == msg.process:
                continue
            self._send(rt._chan_sp[self.sid][q],
                       DeliverMsg(msg.uid, msg.worker, msg.process, self.sid,
                                  msg.ts, msg.key, msg.rows, msg.delta))
            n += 1
        with rt._slock:
            rt.stats.n_messages += n
            rt.stats.bytes_sent += msg.nbytes * n
        if track:
            self.pending[msg.uid] = (msg, n)

    def _ack_uid(self, uid: int) -> None:
        rt = self.rt
        msg, remaining = self.pending[uid]
        remaining -= 1
        if remaining > 0:
            self.pending[uid] = (msg, remaining)
            return
        del self.pending[uid]
        hs = self.halfsync[msg.key]
        res = hs[msg.rows] - np.abs(msg.delta)
        hs[msg.rows] = np.where(np.abs(res) < 1e-12, 0.0, res)
        if rt.policy.value_bounded:
            # the synchronized-update echo only feeds the VAP unsynced
            # accounting; for clock-only policies it is pure overhead (and
            # the sole inbound traffic of a single-process run)
            self._send(rt._chan_sp[self.sid][msg.process],
                       FullyDelivered(msg.uid, msg.worker, msg.key, msg.rows,
                                      msg.delta, self.sid))
        # freed half-sync budget: release queued deliveries for this key FIFO
        dq = self.queued.get(msg.key)
        while dq:
            nxt = dq[0]
            if controller.strong_delivery_gate(
                    rt.policy, self.halfsync[nxt.key][nxt.rows], nxt.delta):
                dq.popleft()
                self._start_delivery(nxt)
            else:
                break

    # ------------------------------------------------------- proc quiesce
    def _maybe_fin(self) -> None:
        """Broadcast ShardFin once every process is done and deliveries have
        fully drained — nothing further will ever leave this shard."""
        rt = self.rt
        if (self._fin_sent or len(self._done_procs) < rt.n_proc
                or self.pending or any(self.queued.values())):
            return
        self._fin_sent = True
        for q in range(rt.n_proc):
            self._send(rt._chan_sp[self.sid][q], ShardFinMsg(self.sid))

    # ------------------------------------------------------- serving tier
    def vc_snapshot(self) -> np.ndarray:
        """The applied per-process vector clock (consistent copy)."""
        with self.lock:
            return self.clock_vc.copy()

    def _on_subscribe(self, msg: SubscribeMsg) -> None:
        """Register a replica publish channel; bootstrap in-stream.

        The state payload and the vc stamp are taken in the shard thread, so
        they form an exact cut: every delta published afterwards is FIFO
        behind them on this channel."""
        chan = msg.channel
        if msg.want_state:
            chan.send(ReplicaStateMsg(self.sid, self.state(),
                                      self.vc_snapshot()))
        else:
            chan.send(ReplicaVcMsg(self.sid, self.vc_snapshot()))
        self.subscribers[msg.replica] = chan

    def _on_unsubscribe(self, msg: UnsubscribeMsg) -> None:
        chan = self.subscribers.pop(msg.replica, None)
        if chan is None:
            return
        # flush this replica's pending publishes FIFO-before the fin
        msgs = self._pub.pop(msg.replica, [])
        msgs.append(ReplicaFinMsg(self.sid))
        chan.send_many(msgs)

    def _flush_publish(self) -> None:
        """Publish this cycle's coalesced deltas + (if the applied frontier
        moved) a vector-clock stamp to every subscribed replica.  Publish
        channels are serving-owned: sends bypass the runtime's in-flight
        quiesce accounting on purpose."""
        vc_dirty, self._vc_dirty = self._vc_dirty, False
        if self.subscribers:
            stamp = self.vc_snapshot() if vc_dirty else None
            for rid, chan in self.subscribers.items():
                msgs = self._pub.pop(rid, [])
                if stamp is not None:
                    msgs.append(ReplicaVcMsg(self.sid, stamp))
                if msgs:
                    chan.send_many(msgs)
        elif self._pub:
            self._pub.clear()
        if vc_dirty:
            self.rt._maybe_periodic_snapshot()

    # ------------------------------------------------------------- snapshots
    def read_rows(self, key: str, out: np.ndarray) -> None:
        """Scatter this shard's live rows of `key` into the full (R, C)
        buffer `out` (locked: safe against the apply loop mid-run)."""
        rows = self.rt._shard_rows[key][self.sid]
        with self.lock:
            out[rows] = self.dense[key]

    def state(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Snapshot payload: per key, global row ids + dense values."""
        with self.lock:
            return {key: {"rows": self.rt._shard_rows[key][self.sid].copy(),
                          "values": self.dense[key].copy()}
                    for key in self.dense}

    def load_state(self, state: Dict[str, Dict[str, np.ndarray]]) -> None:
        """Adopt a snapshot taken by :meth:`state` (rejoin after a kill)."""
        with self.lock:
            for key, part in state.items():
                mine = self.rt._shard_rows[key][self.sid]
                if (part["rows"].shape != mine.shape
                        or not np.array_equal(part["rows"], mine)):
                    raise ValueError(
                        f"snapshot rows for {key!r} do not match shard "
                        f"{self.sid}'s partition")
                if part["values"].shape != self.dense[key].shape:
                    raise ValueError(f"snapshot shape mismatch for {key!r}")
                self.dense[key][...] = part["values"]
