"""Server shard of the PS runtime (paper §4.1).

Each shard is one thread owning a partition of every key's rows under the
current membership epoch (row ``r`` of a key lives on
``active[r % len(active)]`` — :class:`repro.runtime.membership.Partition`),
held as one **dense contiguous numpy block per key** so a batch of row
updates applies as a single vectorized ``np.add.at`` over the concatenated
row indices instead of a Python loop of ``Table.inc`` calls (numpy releases
the GIL inside the fancy-index kernels, which is what lets shard threads
keep up with multiple worker processes).  ``state()``/``load_state()``
(:mod:`repro.runtime.snapshot`) and ``read_rows()`` (live locked master
reads) are the row-state interfaces.

The shard applies incoming update parts to the master block, then
propagates them to every peer process cache, echoes client clock messages
as :class:`ClockMarker` (the delivery frontier the clock bound blocks on),
and tracks acks so the origin worker's unsynchronized accumulator can
shrink only once an update really is visible everywhere — the paper's
definition of a *synchronized* update.

Strong-VAP (paper §2, "half-synchronized" updates): before starting a
delivery the shard consults :func:`controller.strong_delivery_gate`; gated
updates queue FIFO per key and are released as acks free half-sync budget,
mirroring ``server.py`` ``_try_start_delivery`` / ``_on_deliver``.  As in
the simulator, a queued update is *not* counted against the clock frontier
— the marker echo is immediate — so the two bounds compose identically in
both implementations.  The half-sync/pending accounting is key-global (not
partition-local), so it survives membership change untouched.

Elastic membership (:mod:`repro.runtime.membership`): the shard is a *slot*
— it may be inactive (owning no rows), active, retired, or re-activated as
epochs change.  Between a pending epoch's announce and its install, any
message stamped with the next epoch is **held** FIFO and replayed through
the normal apply/publish path at install; a shard active in the old epoch
*cuts* once every client process acked (channel FIFO then guarantees no
more old-epoch updates), handing its frozen ``state()`` + applied vector
clock to the manager.  A retiring slot broadcasts ``clock=INF`` markers —
FIFO-behind everything it ever delivered — so it stops constraining the
clock frontier exactly when its stream completes; a (re)activated slot
broadcasts *seeded* markers from its post-replay vector clock so client
frontiers unblock without waiting a period.

Multi-process quiesce: when the runtime runs with a real transport, each
client sends :class:`ProcDoneMsg` after its last clock; once every process
is done and ``pending``/``queued``/held messages have drained, the shard
broadcasts :class:`ShardFinMsg` (FIFO-after everything else it will ever
send), which is the client's signal that its inbound stream is complete.

Serving tier (:mod:`repro.runtime.serving`): the shard additionally keeps
``clock_vc`` — its **applied vector clock** over client processes
(``clock_vc[p]`` = highest period of p whose updates this shard has applied;
exact because ClockMsg is FIFO-after the period's updates on the p->shard
channel) — and publishes to subscribed read replicas: coalesced per-key row
deltas after every apply cycle, followed by a ``ReplicaVcMsg`` stamp, all
FIFO on the per-replica publish channel.  A replica subscribing mid-run is
bootstrapped **in-stream**: the shard answers with its current dense
partition (snapshot payload format) plus vc stamp before any further delta,
so the replica's view is exact from the first frame it applies.

Publish backpressure: replica publish sends are **non-blocking** where the
wire allows (``WireChannel.try_send_many``) — a wedged replica whose ring
filled up is marked *stale* and its frames are dropped instead of stalling
the shard's apply loop; every subsequent publish cycle retries a full
in-stream re-bootstrap (state + vc, the exact same path as a fresh
subscribe) and the replica resumes exact once its ring drains.

Durability tier (:mod:`repro.runtime.wal`): with ``RuntimeConfig(wal_dir=)``
the shard appends every applied update part to a per-slot write-ahead log
— ``WalWriter.log_parts`` at the end of the apply's lock section (so the
log marks stay consistent with the dense state), ``commit`` (group commit
+ vc stamp) from ``_flush_publish`` when the applied vector clock moved,
and ``seal`` at the epoch cut of a retiring slot.

Exactly-once apply: :class:`UidDedup` records every applied part and drops
exact duplicates by uid under the per-process clock frontier instead of
double-applying.  The drop filter is armed from the start on wal runs (log
replay is at-least-once by design) and arms permanently at the first
membership op on wal-off runs — cross-epoch resends around a kill+rejoin
are the only wal-off source of duplicates.

ESSP (eager server push, arXiv:1410.8043): under ``Policy("essp", ...)``
the shard parks each applied part's fan-out :class:`DeliverMsg`\\ s in a
per-destination hold instead of sending immediately, and releases the
whole hold — one coalesced frame per peer channel, the same outbox framing
the serving publish path uses — whenever it processes a client clock
boundary (and before any INF/seeded marker or fin that vouches for the
held periods).  Workers still gate on SSP's clock bound, but every
boundary pushes all applied deltas to all peers, so observed staleness
collapses well below s.
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import controller
from repro.runtime import trace as trace_mod
from repro.runtime.membership import INF_CLOCK
from repro.runtime.messages import (SHUTDOWN, AckBatchMsg, AckMsg, Channel,
                                    ClockMarker, ClockMsg, DeliverMsg,
                                    EpochAckMsg, EpochBeginMsg, FullyDelivered,
                                    InstallMsg, ProcDoneMsg, ReplicaDeltaMsg,
                                    ReplicaFinMsg, ReplicaStateMsg, ReplicaVcMsg,
                                    ShardFinMsg, SubscribeMsg, UnsubscribeMsg,
                                    UpdateMsg, group_by_channel, pump_inbox)
from repro.runtime.transport import FifoAssert, materialize_msg, release_msgs

log = logging.getLogger("repro.runtime.shard")

_BATCH = 256        # max messages coalesced per apply/dispatch cycle


class UidDedup:
    """Cross-epoch uid-level duplicate filter for the shard apply path.

    Exactly-once apply under *at-least-once* delivery: a part is fresh iff
    its clock timestamp is beyond the origin process's acknowledged
    frontier AND its uid has not been seen above that frontier.  The
    frontier is the per-process clock the shard has fully applied
    (advanced by ClockMsg, which is FIFO-behind every part it covers on
    the client->shard channel, so a live first delivery can never be
    mistaken for a duplicate); uids above the frontier are held in a
    per-process table and pruned as the frontier advances, bounding memory
    to the in-flight window.

    WAL recovery (:func:`repro.runtime.snapshot.recover_to_vc`) replays a
    slot's log through one of these — the vc stamps drive ``advance`` —
    which is what makes replay idempotent across overlapping segments and
    the kill epoch: replaying the same record twice applies it once.
    """

    def __init__(self, n_proc: int):
        self.frontier = np.full(n_proc, -1, dtype=np.int64)
        self._seen: List[Dict[int, int]] = [{} for _ in range(n_proc)]
        self.n_dropped = 0

    def fresh(self, uid: int, process: int, ts: int) -> bool:
        """Record-and-test: True exactly once per (uid, process) above the
        frontier; False (a duplicate) otherwise."""
        if ts <= self.frontier[process] or uid in self._seen[process]:
            self.n_dropped += 1
            return False
        self._seen[process][uid] = ts
        return True

    def advance(self, process: int, clock: int) -> None:
        """Raise the process frontier to ``clock`` and prune the uids it
        now covers (their ts-vs-frontier test subsumes the uid test)."""
        if clock > self.frontier[process]:
            self.frontier[process] = clock
            seen = self._seen[process]
            self._seen[process] = {u: t for u, t in seen.items()
                                   if t > clock}


class ServerShard:
    def __init__(self, rt, sid: int):
        self.rt = rt
        self.sid = sid
        self.inbox: queue.Queue = queue.Queue()
        self.lock = threading.Lock()      # guards .dense/.part/.clock_vc
        self.part = rt.partition          # current membership epoch's map
        self.epoch = self.part.epoch
        # master state: one dense (n_owned_rows, C) block per key, in
        # partition order (global row r at local index r // part.A)
        self.dense: Dict[str, np.ndarray] = {
            key: x0[self.part.rows_of(key, sid)].copy()
            for key, x0 in rt._x0.items()}
        # strong-VAP: per-key magnitude of half-synchronized updates
        # (key-global, so it is untouched by re-partitioning)
        self.halfsync: Dict[str, np.ndarray] = {
            key: np.zeros_like(x0) for key, x0 in rt._x0.items()}
        # uid -> (msg, remaining acks)
        self.pending: Dict[int, Tuple[UpdateMsg, int]] = {}
        # per-key FIFO of updates waiting on the strong delivery gate
        self.queued: Dict[str, deque] = defaultdict(deque)
        self._fifo = FifoAssert()          # per origin process
        self._done_procs: set = set()      # multi-process quiesce, leg 1
        self._fin_sent = False
        self._outbox: List[Tuple[Channel, object]] = []
        # elastic membership: pending epoch between Begin and Install
        self._pending_part = None          # next epoch's Partition
        self._pending_acks: set = set()    # procs that crossed the barrier
        self._cut_done = False
        self._held: List[object] = []      # next-epoch msgs, FIFO per proc
        # zero-lost/zero-duplicated audit: update parts applied, per origin
        self.applied_parts = np.zeros(rt.n_proc, dtype=np.int64)
        # durability tier: per-slot write-ahead log (None unless the runtime
        # was built with wal_dir)
        self.wal = rt._make_wal(sid)
        # at-least-once dedup: always constructed so the per-process clock
        # frontier and uid tables are current from the first applied part,
        # but the *drop* filter only arms where duplicates can exist — wal
        # runs (log replay) from the start, wal-off runs permanently from
        # the first membership op (cross-epoch resends around kill+rejoin
        # can redeliver parts for the rest of the run)
        self._dedup = UidDedup(rt.n_proc)
        self._dedup_armed = self.wal is not None
        # ESSP (eager server push): applied deltas held per destination and
        # released one coalesced frame per peer at every clock boundary
        self._essp_hold: Dict[int, List[DeliverMsg]] = {}
        # serving tier: applied per-process vector clock (guarded by .lock
        # for consistent reads from the gateway) + replica publish channels
        self.clock_vc = np.full(rt.n_proc, -1, dtype=np.int64)
        self.subscribers: Dict[int, object] = {}   # replica id -> channel
        self._pub: Dict[int, List[object]] = {}    # pending publish per replica
        # wedged replicas (drop-and-resync).  Treated as immutable: every
        # change REBINDS a fresh set (atomic under the GIL), so cross-thread
        # readers (ReplicaSet.stale_replicas) can iterate a snapshot safely
        self._stale_subs: frozenset = frozenset()
        self.pub_drops = 0                 # publish cycles dropped on a full
        self.pub_resyncs = 0               # sink / successful re-bootstraps
        self._vc_dirty = False
        # load counters (repro.runtime.metrics): single-writer — only this
        # shard's thread bumps them, collectors read racily.  proc_load maps
        # pid -> (clock, counters) from the ClockMsg load piggyback.
        self.m_rows_applied = 0            # row-updates applied
        self.m_bytes_applied = 0           # delta bytes applied
        self.m_lock_wait = 0.0             # cumulative dense-lock wait (s)
        self.m_last_publish = 0.0          # monotonic ts of last publish
        self.proc_load: Dict[int, Tuple[int, np.ndarray]] = {}
        self.thread = threading.Thread(
            target=self._loop, name=f"ps-shard-{sid}", daemon=True)

    # ------------------------------------------------------------------ loop
    def _loop(self) -> None:
        pump_inbox(self.inbox, self._handle_batch, cap=_BATCH)

    def _handle_batch(self, batch: list) -> bool:
        """Coalesce runs of UpdateMsgs into one vectorized apply, dispatch
        everything else in arrival order, flush sends per channel.  Messages
        stamped with a pending (not yet installed) epoch are held FIFO and
        replayed at install."""
        rt = self.rt
        shutdown = False
        done = 0
        held = 0
        t_batch = time.monotonic_ns() if rt.trace_on else 0
        run: List[UpdateMsg] = []
        for msg in batch:
            if msg is SHUTDOWN:
                shutdown = True
                break
            done += 1
            try:
                if rt.check:
                    sender = getattr(msg, "process", None)
                    if sender is not None:
                        err = self._fifo.check(sender, msg.seq)
                        if err:
                            rt._violation(f"FIFO violation: proc {sender}->"
                                          f"shard {self.sid} {err}")
                if self._should_hold(msg):
                    # held past this cycle (replayed at install): copy any
                    # ring-backed arrays out before the frame pin drops
                    self._held.append(materialize_msg(msg))
                    held += 1
                    continue
                if isinstance(msg, UpdateMsg):
                    run.append(msg)
                else:
                    self._flush_updates(run)
                    run = []
                    self._handle(msg)
            except BaseException as e:          # surface into wait()
                rt._record_error(e)
        try:
            self._flush_updates(run)
            if rt._proc_mode and not shutdown:
                self._maybe_fin()
            self._flush_publish()
        except BaseException as e:
            rt._record_error(e)
        if rt.trace_on and done:
            rt._trace.span(trace_mod.EV_SHARD_BATCH, t_batch, self.sid, done)
        # zero-copy discipline: every view consumed by the applies above is
        # done with, and everything retained (held/queued/pending/publish/
        # outbox) was materialized — release the frame pins BEFORE the
        # blocking outbox writes.  Blocking on a full s->c ring while still
        # pinning the c->s ring would let two full rings deadlock each
        # other (the client comm thread observes the mirror-image rule).
        release_msgs(batch)
        self._flush_outbox()
        # in-flight decrements must come *after* the sends this batch caused
        # were enqueued (incrementing the counter), else the quiesce wait can
        # observe a transient 0 and shut down ahead of late deliveries.
        # Held messages stay in flight until their replay.
        for _ in range(done - held):
            rt._msg_done()
        return shutdown

    def _should_hold(self, msg) -> bool:
        """Next-epoch traffic raced ahead of this slot's install: park it.

        Only updates and clocks need the epoch hold (they touch the dense
        layout / the marker echo); ProcDone is epoch-independent — an
        uninvolved slot's epoch never advances, and ``_maybe_fin`` already
        defers the fin past any pending install + replay."""
        return (isinstance(msg, (UpdateMsg, ClockMsg))
                and msg.epoch > self.part.epoch)

    # --------------------------------------------------------------- sends
    def _send(self, chan: Channel, msg) -> None:
        self._outbox.append((chan, msg))

    def _flush_outbox(self) -> None:
        """Per-channel batched send (one frame per channel per cycle)."""
        if not self._outbox:
            return
        pairs, self._outbox = self._outbox, []
        for chan, msgs in group_by_channel(pairs):
            self.rt._send_many(chan, msgs)

    # ------------------------------------------------------------- dispatch
    def _handle(self, msg) -> None:
        rt = self.rt
        if isinstance(msg, AckMsg):
            with rt._slock:
                rt.stats.n_ack_msgs += 1
                rt.stats.n_acked_updates += 1
            self._ack_uid(msg.uid)
        elif isinstance(msg, AckBatchMsg):
            with rt._slock:
                rt.stats.n_ack_msgs += 1
                rt.stats.n_acked_updates += len(msg.uids)
            for uid in msg.uids:
                self._ack_uid(int(uid))
        elif isinstance(msg, ClockMsg):
            # applied vector clock: the process's period-<=clock updates are
            # FIFO-before this message, so they are already in .dense
            with self.lock:
                self.clock_vc[msg.process] = max(
                    self.clock_vc[msg.process], msg.clock)
            # every part of the period is FIFO-before this message:
            # the dedup frontier may advance and prune its uid table
            self._dedup.advance(msg.process, msg.clock)
            self._vc_dirty = True
            if msg.load is not None:
                # metrics piggyback: the process's boundary counter snapshot
                # (monotone per process; keep the newest boundary)
                cur = self.proc_load.get(msg.process)
                if cur is None or msg.clock >= cur[0]:
                    self.proc_load[msg.process] = (msg.clock, msg.load)
            # ESSP: the clock boundary is the server's push point — release
            # every held delivery (all destinations) FIFO-before the markers
            self._flush_essp_hold()
            # echo the period-completed marker to every peer.  All of the
            # process's period-<=clock updates precede this message on the
            # same FIFO channel, so their DeliverMsgs are already enqueued
            # ahead of the markers sent here.
            for q in range(rt.n_proc):
                if q != msg.process:
                    self._send(rt._chan_sp[self.sid][q],
                               ClockMarker(msg.process, self.sid, msg.clock,
                                           self.epoch))
        elif isinstance(msg, EpochBeginMsg):
            self._pending_part = msg.part
            self._pending_acks = set()
            self._cut_done = False
            # a membership op is in flight: cross-epoch at-least-once
            # resends are now possible (and remain so — late retried wires
            # can land after the install), so the duplicate filter arms
            # permanently.  The uid tables have been recording since shard
            # start, so pre-arming parts are covered too.
            self._dedup_armed = True
        elif isinstance(msg, EpochAckMsg):
            self._pending_acks.add(msg.process)
            self._maybe_cut()
        elif isinstance(msg, InstallMsg):
            self._install(msg)
        elif isinstance(msg, SubscribeMsg):
            self._on_subscribe(msg)
        elif isinstance(msg, UnsubscribeMsg):
            self._on_unsubscribe(msg)
        elif isinstance(msg, ProcDoneMsg):
            self._done_procs.add(msg.process)
            # ESSP: no further ClockMsg from this process will trigger a
            # boundary flush — release any backlog so the fin can drain
            self._flush_essp_hold()
        else:
            raise TypeError(f"shard {self.sid}: unexpected message {msg!r}")

    # ------------------------------------------------------ epoch protocol
    def _maybe_cut(self) -> None:
        """All clients crossed the barrier: freeze and hand off (module
        docstring step 3).  Channel FIFO guarantees no further old-epoch
        update can arrive, so the state cut is final for this epoch."""
        rt = self.rt
        if (self._pending_part is None or self._cut_done
                or len(self._pending_acks) < rt.n_proc):
            return
        self._cut_done = True
        if self.part.owns(self.sid):
            # vc-stamped snapshot payload: the migration transfer format
            rt.membership.inbox.put(
                ("handoff", self.sid, (self.state(), self.vc_snapshot())))
        if not self._pending_part.owns(self.sid):
            if self.wal is not None:
                # the cut is final for this slot: no old-epoch update can
                # arrive (channel FIFO behind the acks) and next-epoch
                # updates route elsewhere — seal the segment at the epoch
                # cut; a later re-activation opens the next one
                self.wal.seal(self.vc_snapshot())
            # retiring: everything this slot will ever deliver (bar strong-
            # VAP-queued updates, which are exempt from the clock frontier
            # exactly like in the simulator) is FIFO-before these markers,
            # so clients may treat the slot as infinitely caught up.  ESSP
            # holds count as "ever deliver": release them first.
            self._flush_essp_hold()
            for q in range(rt.n_proc):
                for p in range(rt.n_proc):
                    if p != q:
                        self._send(rt._chan_sp[self.sid][q],
                                   ClockMarker(p, self.sid, INF_CLOCK,
                                               self.epoch))

    def _install(self, msg: InstallMsg) -> None:
        """Adopt the new epoch's partition and dense blocks, replay held
        next-epoch traffic, then broadcast seeded frontier markers."""
        rt = self.rt
        with self.lock:
            self.part = msg.part
            if msg.blocks is None:              # retiring / staying inactive
                self.dense = {key: x0[:0].copy()
                              for key, x0 in rt._x0.items()}
            else:
                self.dense = dict(msg.blocks)
                np.maximum(self.clock_vc, msg.seed_vc, out=self.clock_vc)
        self.epoch = msg.epoch
        self._pending_part = None
        self._pending_acks = set()
        self._cut_done = False
        held, self._held = self._held, []
        run: List[UpdateMsg] = []
        for m in held:
            if isinstance(m, UpdateMsg):
                run.append(m)
            else:
                self._flush_updates(run)
                run = []
                self._handle(m)
        self._flush_updates(run)
        for _ in held:
            rt._msg_done()
        # ESSP: deliveries the replay just parked must be FIFO-before the
        # seeded markers that vouch for them
        self._flush_essp_hold()
        if self.part.owns(self.sid):
            # seeded markers: deliveries for everything clock_vc covers are
            # FIFO-before this on each s->q channel (replayed just above or
            # published by the old owners, whose markers/INF still vouch),
            # and install strictly follows every client's swap+ack, so the
            # marker can never overtake the receiver's router swap
            with self.lock:
                vc = self.clock_vc.copy()
            for q in range(rt.n_proc):
                for p in range(rt.n_proc):
                    if p != q and vc[p] >= 0:
                        self._send(rt._chan_sp[self.sid][q],
                                   ClockMarker(p, self.sid, int(vc[p]),
                                               self.epoch))
        # serving: existing subscribers lack the base values of rows that
        # migrated INTO this slot (they only ever saw this slot's deltas) —
        # push an in-stream re-bootstrap: a post-replay state + vc cut,
        # FIFO-after everything already published, superseding any replay
        # deltas still pending for them
        if self.part.owns(self.sid) and self.subscribers:
            for rid, chan in self.subscribers.items():
                self._pub.pop(rid, None)
                if rid in self._stale_subs:
                    continue               # the resync path re-bootstraps
                if not self._publish_send(chan, [ReplicaStateMsg(
                        self.sid, self.state(), self.vc_snapshot(),
                        epoch=msg.epoch)]):
                    self._stale_subs = self._stale_subs | {rid}
                    self.pub_drops += 1
                    log.warning(
                        "shard %d: replica %d re-bootstrap after epoch %d "
                        "install dropped on a full sink — marked stale for "
                        "resync", self.sid, rid, msg.epoch)
        self._vc_dirty = True
        rt.membership.inbox.put(("installed", self.sid, msg.epoch))

    # --------------------------------------------------------------- updates
    def _flush_updates(self, run: List[UpdateMsg]) -> None:
        """Apply a run of update parts as one vectorized op per key, then
        route each through the (per-message) delivery state machine."""
        if not run:
            return
        rt = self.rt
        # record-and-test every part (keeps the uid tables complete for a
        # later arming); with the filter armed, drop exact duplicates before
        # they touch the dense state, the audit counters, or the WAL
        # (dropped messages' frame pins release with the batch)
        fresh = [self._dedup.fresh(m.uid, m.process, m.ts) for m in run]
        if self._dedup_armed:
            run = [m for m, f in zip(run, fresh) if f]
            if not run:
                return
        trc = rt._trace if rt.trace_on else None
        by_key: Dict[str, List[UpdateMsg]] = {}
        n_rows = n_bytes = 0
        for msg in run:
            by_key.setdefault(msg.key, []).append(msg)
            self.applied_parts[msg.process] += 1
            n_rows += msg.rows.size
            n_bytes += msg.nbytes
            if trc is not None and trc.sampled(msg.uid):
                # lifeline landing: joins the client's send_part on
                # (proc, uid).  Fresh parts only — the dedup filter above
                # already dropped replays, so with sample=1.0 these points
                # reconcile exactly with sum(applied_parts).
                trc.point(trace_mod.EV_APPLY_PART, msg.process, msg.uid,
                          self.sid)
        t_apply = time.monotonic_ns() if trc is not None else 0
        # apply-lock wait: how long the dense blocks were contended (master
        # reads, migration cuts).  One extra monotonic() pair per *batch*,
        # and only with metrics/trace on — the overhead gates cover this.
        t_lock = time.monotonic() if (rt.metrics_on or trc is not None) \
            else 0.0
        with self.lock:
            if t_lock:
                dt_lock = time.monotonic() - t_lock
                if rt.metrics_on:
                    self.m_lock_wait += dt_lock
                if trc is not None and dt_lock > 1e-6:
                    trc.span(trace_mod.EV_LOCK_WAIT, int(t_lock * 1e9),
                             self.sid)
            self.m_rows_applied += n_rows
            self.m_bytes_applied += n_bytes
            A = self.part.A
            use_kernels = getattr(rt, "ps_kernels", False)
            for key, msgs in by_key.items():
                dense = self.dense[key]
                if len(msgs) == 1:
                    m = msgs[0]
                    if self.subscribers:
                        # the publish entry below retains m's arrays past
                        # this cycle: copy them out of the ring first
                        materialize_msg(m)
                    # rows are unique within one part: plain fancy-index add
                    dense[m.rows // A] += m.delta
                    rows, delta = m.rows, m.delta
                else:
                    rows = np.concatenate([m.rows for m in msgs])
                    delta = np.concatenate([m.delta for m in msgs])
                    # rows may repeat across parts: the scatter-add must
                    # accumulate duplicates sequentially (np.add.at order)
                    if use_kernels:
                        from repro.kernels.ps_apply import ops as apply_ops
                        apply_ops.scatter_add_inplace(dense, rows // A, delta)
                    else:
                        np.add.at(dense, rows // A, delta)
                # serving: one coalesced delta per key per cycle per replica
                # (global row ids; the arrays are shared — receivers only read)
                for rid in self.subscribers:
                    if rid not in self._stale_subs:
                        self._pub.setdefault(rid, []).append(
                            ReplicaDeltaMsg(self.sid, key, rows, delta,
                                            epoch=self.part.epoch))
            if self.wal is not None:
                # WAL append FIFO-behind the apply, inside the same lock
                # section so the log marks (parts/applied/max_ts) stay
                # consistent with the dense state a snapshot cuts; frames
                # are encoded to owned bytes here (ring views are only
                # valid while this cycle's pins are held) and written out
                # at the next clock-boundary group commit
                t_wal = time.monotonic_ns() if trc is not None else 0
                self.wal.log_parts(run)
                if trc is not None:
                    trc.span(trace_mod.EV_WAL_APPEND, t_wal, self.sid,
                             len(run))
        if trc is not None:
            trc.span(trace_mod.EV_APPLY, t_apply, self.sid, len(run), n_rows)
        for msg in run:
            self._route_delivery(msg)

    def _route_delivery(self, msg: UpdateMsg) -> None:
        rt = self.rt
        if rt.n_proc == 1:
            # no peers to propagate to: the update is synchronized already
            if rt.policy.tracks_sync:
                # the echo rides the outbox, flushed after the pin release
                materialize_msg(msg)
                self._send(rt._chan_sp[self.sid][msg.process],
                           FullyDelivered(msg.uid, msg.worker, msg.key,
                                          msg.rows, msg.delta, self.sid))
            return
        if self.queued[msg.key] or not controller.strong_delivery_gate(
                rt.policy, self.halfsync[msg.key][msg.rows], msg.delta):
            self.queued[msg.key].append(materialize_msg(msg))
            return
        self._start_delivery(msg)

    def _start_delivery(self, msg: UpdateMsg) -> None:
        rt = self.rt
        # the fan-out DeliverMsgs (and the VAP pending entry) outlive this
        # apply cycle's frame pins — the dense apply already consumed the
        # view in place, so this copy is the delivery path's only one
        materialize_msg(msg)
        # ack cycle feeds the unsynced accounting only (VAP value bound /
        # elastic norm bound)
        track = rt.policy.tracks_sync
        if track:
            hs = self.halfsync[msg.key]
            hs[msg.rows] += np.abs(msg.delta)
            if rt.check:
                mx = float(np.max(hs[msg.rows])) if msg.rows.size else 0.0
                with rt._slock:
                    rt.stats.max_halfsync_mag = max(
                        rt.stats.max_halfsync_mag, mx)
        hold = rt.policy.server_push_on_boundary
        n = 0
        for q in range(rt.n_proc):
            if q == msg.process:
                continue
            d = DeliverMsg(msg.uid, msg.worker, msg.process, self.sid,
                           msg.ts, msg.key, msg.rows, msg.delta)
            if hold:
                # ESSP: park until the next clock boundary, then one
                # coalesced frame per peer (see _flush_essp_hold)
                self._essp_hold.setdefault(q, []).append(d)
            else:
                self._send(rt._chan_sp[self.sid][q], d)
            n += 1
        with rt._slock:
            rt.stats.n_messages += n
            rt.stats.bytes_sent += msg.nbytes * n
        if track:
            self.pending[msg.uid] = (msg, n)

    def _flush_essp_hold(self) -> None:
        """ESSP server push: move every held delivery into the outbox, in
        apply order per destination.  The outbox's per-channel batching
        (the same framing the serving publish path rides) turns each
        destination's backlog into one coalesced wire frame.  Callers must
        flush *before* emitting any marker that vouches for the held
        periods (clock echo, epoch-cut INF, post-install seed)."""
        if not self._essp_hold:
            return
        hold, self._essp_hold = self._essp_hold, {}
        chans = self.rt._chan_sp[self.sid]
        for q, msgs in hold.items():
            for m in msgs:
                self._send(chans[q], m)

    def _ack_uid(self, uid: int) -> None:
        rt = self.rt
        msg, remaining = self.pending[uid]
        remaining -= 1
        if remaining > 0:
            self.pending[uid] = (msg, remaining)
            return
        del self.pending[uid]
        # exact subtraction (see runtime.py FullyDelivered): |delta| was
        # added to halfsync verbatim at _start_delivery, so the inverse is
        # exact; the strong gate's own > 1e-12 dead zone absorbs residue
        # left by other interleavings
        hs = self.halfsync[msg.key]
        hs[msg.rows] -= np.abs(msg.delta)
        if rt.policy.tracks_sync:
            # the synchronized-update echo only feeds the unsynced
            # accounting (VAP / elastic); for clock-only policies it is
            # pure overhead (and the sole inbound traffic of a
            # single-process run)
            self._send(rt._chan_sp[self.sid][msg.process],
                       FullyDelivered(msg.uid, msg.worker, msg.key, msg.rows,
                                      msg.delta, self.sid))
        # freed half-sync budget: release queued deliveries for this key FIFO
        dq = self.queued.get(msg.key)
        while dq:
            nxt = dq[0]
            if controller.strong_delivery_gate(
                    rt.policy, self.halfsync[nxt.key][nxt.rows], nxt.delta):
                dq.popleft()
                self._start_delivery(nxt)
            else:
                break

    # ------------------------------------------------------- proc quiesce
    def _maybe_fin(self) -> None:
        """Broadcast ShardFin once every process is done and deliveries have
        fully drained — nothing further will ever leave this shard.  A
        pending membership install (held messages still to replay) defers
        the fin."""
        rt = self.rt
        if (self._fin_sent or len(self._done_procs) < rt.n_proc
                or self.pending or any(self.queued.values())
                or self._pending_part is not None or self._held
                or self._essp_hold):
            return
        self._fin_sent = True
        for q in range(rt.n_proc):
            self._send(rt._chan_sp[self.sid][q], ShardFinMsg(self.sid))

    # ------------------------------------------------------- serving tier
    def vc_snapshot(self) -> np.ndarray:
        """The applied per-process vector clock (consistent copy)."""
        with self.lock:
            return self.clock_vc.copy()

    def vc_if_active(self) -> Optional[np.ndarray]:
        """The applied vc, or None while this slot owns no rows — the
        membership-aware master frontier the serving SLO measures against
        (ownership and vc are read under one lock, so a mid-migration
        reader always sees at least one shard vouching for every row)."""
        with self.lock:
            if not self.part.owns(self.sid):
                return None
            return self.clock_vc.copy()

    def _on_subscribe(self, msg: SubscribeMsg) -> None:
        """Register a replica publish channel; bootstrap in-stream.

        The state payload and the vc stamp are taken in the shard thread, so
        they form an exact cut: every delta published afterwards is FIFO
        behind them on this channel.  The bootstrap send is non-blocking
        like every publish: a subscriber whose (reused) edge is already
        wedged full starts out *stale* and gets its bootstrap from the
        resync path once the sink drains — the shard never stalls."""
        chan = msg.channel
        boot = (ReplicaStateMsg(self.sid, self.state(), self.vc_snapshot(),
                                epoch=self.part.epoch)
                if msg.want_state
                else ReplicaVcMsg(self.sid, self.vc_snapshot()))
        self.subscribers[msg.replica] = chan
        if self._publish_send(chan, [boot]):
            self._stale_subs = self._stale_subs - {msg.replica}
        else:
            self._stale_subs = self._stale_subs | {msg.replica}
            self.pub_drops += 1
            log.warning(
                "shard %d: replica %d subscribed on a wedged sink — "
                "bootstrap dropped, replica starts stale until the resync "
                "path gets through (epoch %d)", self.sid, msg.replica,
                self.part.epoch)

    def _on_unsubscribe(self, msg: UnsubscribeMsg) -> None:
        chan = self.subscribers.pop(msg.replica, None)
        self._stale_subs = self._stale_subs - {msg.replica}
        if chan is None:
            return
        # flush this replica's pending publishes FIFO-before the fin —
        # non-blocking: a wedged replica simply misses its fin (close()'s
        # fin wait is deadline-bounded) rather than stalling the shard
        msgs = self._pub.pop(msg.replica, [])
        msgs.append(ReplicaFinMsg(self.sid))
        if not self._publish_send(chan, msgs):
            self.pub_drops += 1

    def _publish_send(self, chan, msgs: list) -> bool:
        """Non-blocking publish where the wire supports it (see module
        docstring, "Publish backpressure")."""
        try_send = getattr(chan, "try_send_many", None)
        if try_send is None:
            chan.send_many(msgs)               # in-process queue: unbounded
            return True
        return try_send(msgs)

    def _try_resync(self, rid: int, chan) -> None:
        """Attempt the in-stream re-bootstrap of a stale replica: a fresh
        state + vc cut, exactly the subscribe path.  Skipped cheaply while
        the sink still lacks room for a state-sized frame."""
        if chan.room() < self.rt._state_frame_bytes:
            return
        if self._publish_send(chan, [ReplicaStateMsg(
                self.sid, self.state(), self.vc_snapshot(),
                epoch=self.part.epoch)]):
            self._stale_subs = self._stale_subs - {rid}
            self.pub_resyncs += 1
            log.info(
                "shard %d: replica %d resynced — in-stream re-bootstrap "
                "delivered after its sink drained (epoch %d, resyncs %d)",
                self.sid, rid, self.part.epoch, self.pub_resyncs)

    def _flush_publish(self) -> None:
        """Publish this cycle's coalesced deltas + (if the applied frontier
        moved) a vector-clock stamp to every subscribed replica.  Publish
        channels are serving-owned: sends bypass the runtime's in-flight
        quiesce accounting on purpose, and they never block the shard — a
        full sink marks the replica stale for drop-and-resync."""
        rt = self.rt
        trc = rt._trace if rt.trace_on else None
        vc_dirty, self._vc_dirty = self._vc_dirty, False
        if self.subscribers:
            t_pub = time.monotonic_ns() if trc is not None else 0
            self.m_last_publish = time.monotonic()
            stamp = self.vc_snapshot() if vc_dirty else None
            for rid, chan in self.subscribers.items():
                if rid in self._stale_subs:
                    self._pub.pop(rid, None)
                    self._try_resync(rid, chan)
                    continue
                msgs = self._pub.pop(rid, [])
                if stamp is not None:
                    msgs.append(ReplicaVcMsg(self.sid, stamp))
                if msgs and not self._publish_send(chan, msgs):
                    self._stale_subs = self._stale_subs | {rid}   # wedged:
                    self.pub_drops += 1         # drop now, resync later
                    log.warning(
                        "shard %d: replica %d publish sink full — marking "
                        "stale, dropping this cycle's deltas and retrying a "
                        "full re-bootstrap each cycle (epoch %d, drops so "
                        "far %d)", self.sid, rid, self.part.epoch,
                        self.pub_drops)
                elif trc is not None and msgs:
                    # publish lifeline: seqs were stamped by the send, so
                    # the replica's ingest joins on (shard, replica, seq)
                    for m in msgs:
                        if (type(m) is ReplicaDeltaMsg
                                and trc.sampled(m.seq)):
                            trc.point(trace_mod.EV_PUBLISH_PART, self.sid,
                                      m.seq, rid)
            if trc is not None:
                stamp_min = int(stamp.min()) if stamp is not None else -1
                trc.span(trace_mod.EV_PUBLISH, t_pub, self.sid, stamp_min,
                         len(self.subscribers))
        elif self._pub:
            self._pub.clear()
        if vc_dirty:
            if self.wal is not None and self.part.owns(self.sid):
                # group commit at the clock boundary: pending delta frames
                # + a vc stamp, FIFO on disk exactly like the publish
                # stream (WAL-before-snapshot: the commit precedes any
                # periodic snapshot this boundary triggers)
                vc = self.vc_snapshot()
                t_wal = time.monotonic_ns() if trc is not None else 0
                self.wal.commit(vc)
                if trc is not None:
                    trc.span(trace_mod.EV_WAL_COMMIT, t_wal, self.sid,
                             int(vc.min()))
            self.rt._maybe_periodic_snapshot()

    # ------------------------------------------------------------- snapshots
    def read_rows(self, key: str, out: np.ndarray) -> None:
        """Scatter this shard's live rows of `key` into the full (R, C)
        buffer `out` (locked: safe against the apply loop mid-run)."""
        with self.lock:
            rows = self.part.rows_of(key, self.sid)
            if rows.size:
                out[rows] = self.dense[key]

    def state(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Snapshot payload: per key, global row ids + dense values."""
        with self.lock:
            return {key: {"rows": self.part.rows_of(key, self.sid).copy(),
                          "values": self.dense[key].copy()}
                    for key in self.dense}

    def durability_cut(self):
        """``(state, vc, wal marks)`` under ONE lock acquisition.

        The WAL append in :meth:`_flush_updates` bumps the log marks in
        the same lock section as the dense apply, so a cut taken here is
        an exact per-slot log prefix: every part counted in ``marks`` is
        folded into ``state``, and none beyond.  That is what lets
        :func:`repro.runtime.snapshot.recover_to_vc` skip replay of the
        covered prefix without double-applying or losing a part.
        """
        with self.lock:
            state = {key: {"rows": self.part.rows_of(key, self.sid).copy(),
                           "values": self.dense[key].copy()}
                     for key in self.dense}
            vc = self.clock_vc.copy()
            marks = self.wal.marks() if self.wal is not None else None
        return state, vc, marks

    def load_state(self, state: Dict[str, Dict[str, np.ndarray]]) -> None:
        """Adopt a snapshot taken by :meth:`state` (rejoin after a kill)."""
        with self.lock:
            for key, part in state.items():
                mine = self.part.rows_of(key, self.sid)
                if (part["rows"].shape != mine.shape
                        or not np.array_equal(part["rows"], mine)):
                    raise ValueError(
                        f"snapshot rows for {key!r} do not match shard "
                        f"{self.sid}'s partition")
                if part["values"].shape != self.dense[key].shape:
                    raise ValueError(f"snapshot shape mismatch for {key!r}")
                self.dense[key][...] = part["values"]
