"""Runtime configuration (:class:`RuntimeConfig`).

:class:`~repro.runtime.runtime.PSRuntime` grew one keyword at a time —
transports, snapshots, elastic membership, the zero-copy wire, kernels —
until the constructor carried 15+ kwargs and every call site repeated the
same sprawl.  ``RuntimeConfig`` is now the single construction surface:

    from repro.runtime import PSRuntime, RuntimeConfig

    rt = PSRuntime(RuntimeConfig(4, ssp(3), x0, transport="proc"))

All validation lives in :meth:`RuntimeConfig.__post_init__` (the ValueError
checks moved verbatim from the old ``PSRuntime.__init__``), so a config is
either valid or never constructed — the runtime can trust every field.
``PSRuntime(n_workers=..., ...)`` still works as a thin deprecation shim
that builds the config and warns.

Field order matches the legacy positional signature exactly, so migrating a
call site is mechanical: ``PSRuntime(args...)`` ->
``PSRuntime(RuntimeConfig(args...))``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Optional, Tuple

from repro.core.policies import Policy
from repro.core.server import UpdateMap

TRANSPORTS: Tuple[str, ...] = ("queue", "tcp", "shm", "proc")
WAL_FSYNC: Tuple[str, ...] = ("none", "boundary")


@dataclass
class RuntimeConfig:
    """Everything a :class:`PSRuntime` needs to build itself.

    The first three fields are the required triple every run names
    (worker count, consistency policy, initial table values); the rest
    default to the single-host topology the test-suite uses.
    """

    n_workers: int
    policy: Policy
    init_params: UpdateMap
    n_shards: int = 2
    threads_per_process: int = 1
    seed: int = 0
    prioritize_by_magnitude: bool = True
    check_invariants: bool = True
    barrier_reads: bool = False
    transport: str = "queue"
    restore_from: Optional[dict] = None
    snapshot_every: int = 0
    snapshot_dir: Optional[str] = None
    max_shards: Optional[int] = None
    membership_plan: Optional[object] = None   # membership.MembershipPlan
    zero_copy: Optional[bool] = None
    ps_kernels: bool = False
    # observability (PR 7): keep the per-shard/per-process load counters and
    # the ClockMsg load piggyback on.  The hooks are cheap (<3% upd/s, gated
    # in CI by bench_autoscale's A/B row) but can be switched off for
    # apples-to-apples perf comparisons against older baselines.
    metrics: bool = field(default=True)
    # durability tier (PR 8, repro.runtime.wal): per-shard write-ahead delta
    # log under wal_dir, group-committed at clock boundaries.  wal_fsync is
    # "none" (flush to the OS, no fsync between applies — the default) or
    # "boundary" (fsync per group commit); None means unset and resolves to
    # "none" when wal_dir is given.  Segments rotate past wal_segment_bytes.
    wal_dir: Optional[str] = None
    wal_fsync: Optional[str] = None
    wal_segment_bytes: int = 1 << 22
    # snapshot retention: keep only the newest k periodic snapshots on disk
    # (0 = keep all), pruning WAL segments fully covered by the oldest
    # retained snapshot along with them.
    snapshot_keep_last: int = 0
    # tracing tier (repro.runtime.trace): sampled end-to-end event tracing
    # across every layer into per-thread bounded ring buffers, exported as
    # Chrome trace JSON by rt.dump_trace().  None/False = off (the default,
    # near-zero cost); True = on with defaults; a float in (0, 1] = the
    # update-lifeline sample rate; a {"sample":, "capacity":} dict or a
    # trace.TraceConfig for full control.
    trace: object = None

    def __post_init__(self) -> None:
        if self.n_workers % self.threads_per_process:
            raise ValueError("n_workers must divide into processes evenly")
        if self.n_shards < 1:
            raise ValueError("need at least one server shard")
        if self.max_shards is not None and self.max_shards < self.n_shards:
            raise ValueError("max_shards must be >= n_shards")
        if self.barrier_reads and self.threads_per_process != 1:
            raise ValueError("barrier_reads requires threads_per_process == 1")
        if self.transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {self.transport!r}; "
                             f"choose from {TRANSPORTS}")
        if self.snapshot_every < 0:
            raise ValueError("snapshot_every must be >= 0 (0 disables)")
        if self.snapshot_every and not self.snapshot_dir:
            raise ValueError(
                "snapshot_every without snapshot_dir would drop every "
                "periodic snapshot on the floor at restart; pass "
                "snapshot_dir= (or set snapshot_every=0)")
        if self.wal_fsync is not None and not self.wal_dir:
            raise ValueError("wal_fsync without wal_dir is a silent no-op; "
                             "pass wal_dir= (or drop wal_fsync)")
        if self.wal_fsync is not None and self.wal_fsync not in WAL_FSYNC:
            raise ValueError(f"unknown wal_fsync policy {self.wal_fsync!r}; "
                             f"choose from {WAL_FSYNC}")
        if self.wal_segment_bytes < 1:
            raise ValueError("wal_segment_bytes must be >= 1")
        if self.snapshot_keep_last < 0:
            raise ValueError("snapshot_keep_last must be >= 0 (0 keeps all)")
        if self.snapshot_keep_last and not self.snapshot_dir:
            raise ValueError("snapshot_keep_last prunes on-disk snapshots; "
                             "it requires snapshot_dir")
        # normalize + validate eagerly so a bad trace spec fails at
        # construction, not at the first sampled event
        from repro.runtime.trace import normalize_trace
        normalize_trace(self.trace)


def config_from_legacy(*args, **kwargs) -> RuntimeConfig:
    """Build a :class:`RuntimeConfig` from the legacy ``PSRuntime(...)``
    positional/keyword argument list (the deprecation shim's worker)."""
    names = [f.name for f in fields(RuntimeConfig)]
    if len(args) > len(names):
        raise TypeError(f"PSRuntime() takes at most {len(names)} "
                        f"positional arguments ({len(args)} given)")
    for name, value in zip(names, args):
        if name in kwargs:
            raise TypeError(f"PSRuntime() got multiple values for {name!r}")
        kwargs[name] = value
    unknown = set(kwargs) - set(names)
    if unknown:
        raise TypeError(f"PSRuntime() got unexpected keyword arguments "
                        f"{sorted(unknown)}")
    return RuntimeConfig(**kwargs)
