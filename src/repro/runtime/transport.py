"""Multi-process transport for the PS runtime (ROADMAP "runtime follow-ups").

Three interchangeable wire backends behind the same :class:`Channel`
interface the threaded runtime already uses (``messages.Channel``):

  * ``queue`` — the original in-process ``queue.Queue`` edges (threads only);
  * ``tcp``   — loopback sockets, one connection per client<->shard channel
    pair, length-prefixed pickle-protocol-5 frames with numpy row buffers
    carried out-of-band as contiguous byte ranges;
  * ``shm``   — same frames over single-producer/single-consumer shared-
    memory byte rings (two rings per client<->shard pair, one per
    direction) for same-host deployments.

Framing.  A frame is ``u32 payload_len | payload``.  Two payload formats
share the stream, discriminated by the payload's first u32:

* **pickle-5** (tcp, control messages, serving)::

      u32 n_buffers | u32 head_len | head | (u64 buf_len | buf) * n_buffers

  ``head`` is ``pickle.dumps(msgs, protocol=5, buffer_callback=...)`` of a
  *list* of messages, so senders coalesce many row updates into one frame
  (``Channel.send_many``) and the arrays inside ``UpdateMsg``/``DeliverMsg``
  travel as raw contiguous buffers after the pickle head instead of being
  copied through the pickler.

* **raw row blocks** (shm data plane, :class:`RowCodec`)::

      u32 RAW_MAGIC | u32 n_msgs | n_msgs * (hdr | rows int64 | delta f64)

  ``hdr`` is the fixed 48-byte struct ``_RAW_HDR`` (msg kind, dtype code,
  interned key id, uid/seq, worker/process/ts/shard/epoch, row and column
  counts).  ``RAW_MAGIC`` can never collide with a sane pickle payload's
  ``n_buffers``.  Only ``UpdateMsg``/``DeliverMsg`` are raw-eligible; a
  batch mixing in control messages is split into consecutive raw/pickle
  frames under the channel lock, preserving FIFO.  On the read side,
  :class:`RingViewReader` decodes the arrays as numpy views *into the ring*
  (zero-copy) and defers the ring's head cursor until the consumer releases
  the frame — see its docstring for the pin/release discipline.

``payload_len == EOF_LEN`` is the end-of-stream sentinel.
:class:`FrameDecoder` is incremental: feed it arbitrary byte chunks (short
reads, split frames) and it yields complete messages only.

FIFO.  Channels stamp per-channel sequence numbers under a lock exactly like
the in-process queues; receivers assert contiguity via :class:`FifoAssert`,
so a reordering (or replaying) transport is *detected*, not assumed away.

Portability.  The shm ring's lock-free cursor protocol assumes **total
store ordering** (x86/x86-64): the producer's data memcpy must become
visible to the consumer no later than the cursor store that publishes it,
and vice versa for the consumer's head update.  On weakly-ordered ISAs
(aarch64/arm64) the stores can be reordered by the hardware, the cursors
would need real acquire/release barriers, and pure Python cannot express
them — so :func:`require_tso` *refuses to construct* the shm backend there
at runtime (clear error pointing at ``transport="tcp"``) instead of letting
the FrameDecoder's short-frame errors and the FIFO asserts flag the
corruption after the fact.
"""
from __future__ import annotations

import logging
import os
import pickle
import platform
import queue
import select
import socket
import struct
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from multiprocessing import shared_memory

import numpy as np

from repro.runtime import trace as trace_mod
from repro.runtime.messages import DeliverMsg, UpdateMsg

log = logging.getLogger("repro.runtime.transport")

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
EOF_LEN = 0xFFFFFFFF          # length-prefix value signalling end-of-stream
MAX_FRAME = EOF_LEN - 1

# raw row-block payloads (zero-copy shm data plane) -------------------------
RAW_MAGIC = 0x46574152        # b"RAWF" little-endian; impossible n_buffers
K_UPDATE = 1                  # raw msg kinds
K_DELIVER = 2
DT_F64 = 0                    # delta dtype codes (rows are always int64)
# kind u8 | dtype u8 | key id u16 | uid i64 | seq i64 |
# worker, process, ts, shard, epoch, n_rows, n_cols i32  -> 48 bytes
_RAW_HDR = struct.Struct("<BBHqqiiiiiii")

EOF = object()                # yielded by FrameDecoder when the peer closed

# ISAs whose memory model breaks the shm ring's lock-free cursor protocol
_WEAKLY_ORDERED = ("aarch64", "arm64")


def require_tso(what: str = "the shared-memory ring transport") -> None:
    """Refuse to run the shm rings on a weakly-ordered ISA.

    The SPSC cursor protocol relies on x86 total store ordering (module
    docstring); on aarch64/arm64 the missing barriers corrupt frames
    silently, so fail loudly at construction instead."""
    machine = platform.machine().lower()
    if machine in _WEAKLY_ORDERED:
        raise RuntimeError(
            f"{what} assumes x86 total store ordering, but this host is "
            f"{machine!r} (weakly ordered): the lock-free ring cursors "
            'would need memory barriers Python cannot express. '
            'Use transport="tcp" (loopback sockets) instead.')


def encode_frame(msgs: list) -> bytes:
    """One wire frame holding `msgs` (a list — batching is the unit)."""
    buffers: List[pickle.PickleBuffer] = []
    head = pickle.dumps(msgs, protocol=5, buffer_callback=buffers.append)
    parts = [b"", _U32.pack(len(buffers)), _U32.pack(len(head)), head]
    for b in buffers:
        raw = b.raw()
        parts.append(_U64.pack(raw.nbytes))
        parts.append(raw)          # join() copies once; no tobytes() double
    payload_len = sum(len(p) for p in parts)
    if payload_len > MAX_FRAME:
        raise ValueError(f"frame too large: {payload_len} bytes")
    parts[0] = _U32.pack(payload_len)
    return b"".join(parts)


def eof_frame() -> bytes:
    return _U32.pack(EOF_LEN)


def decode_payload(payload: bytes) -> list:
    """Inverse of the payload part of :func:`encode_frame`."""
    n_buf = _U32.unpack_from(payload, 0)[0]
    head_len = _U32.unpack_from(payload, 4)[0]
    off = 8
    head = payload[off:off + head_len]
    if len(head) != head_len:
        raise ValueError("short frame: truncated pickle head")
    off += head_len
    bufs = []
    for _ in range(n_buf):
        if off + 8 > len(payload):
            raise ValueError("short frame: truncated buffer header")
        n = _U64.unpack_from(payload, off)[0]
        off += 8
        buf = payload[off:off + n]
        if len(buf) != n:
            raise ValueError("short frame: truncated buffer body")
        bufs.append(buf)
        off += n
    if off != len(payload):
        raise ValueError(f"frame overrun: {len(payload) - off} trailing bytes")
    return pickle.loads(head, buffers=bufs)


class FrameDecoder:
    """Incremental frame decoder tolerating arbitrary chunking of the stream.

    ``feed(data)`` returns the list of *messages* (flattened across any
    complete frames in the buffer so far); a trailing partial frame stays
    buffered until its bytes arrive.  After the EOF sentinel, ``closed`` is
    True and further frames are rejected.
    """

    def __init__(self):
        self._buf = bytearray()
        self.closed = False

    def feed(self, data: bytes) -> list:
        if self.closed and data:
            raise ValueError("data after EOF sentinel")
        self._buf += data
        out: list = []
        while True:
            if len(self._buf) < 4:
                break
            plen = _U32.unpack_from(self._buf, 0)[0]
            if plen == EOF_LEN:
                self.closed = True
                if len(self._buf) > 4:
                    raise ValueError("data after EOF sentinel")
                del self._buf[:4]
                break
            if len(self._buf) < 4 + plen:
                break                      # short frame: wait for more bytes
            payload = bytes(self._buf[4:4 + plen])
            del self._buf[:4 + plen]
            out.extend(decode_payload(payload))
        return out

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)


class FifoAssert:
    """Per-sender contiguous-sequence assertion (shared by shard & client).

    ``check(sender, seq)`` returns an error string on a gap/reorder/replay,
    else None.  Mirrors the simulator's ``_last_seq_seen`` checking.
    """

    def __init__(self):
        self._last: Dict[object, int] = {}

    def check(self, sender, seq: int) -> Optional[str]:
        last = self._last.get(sender, -1)
        self._last[sender] = max(seq, last)
        if seq != last + 1:
            return f"seq {seq} after {last}"
        return None


# ---------------------------------------------------------------------------
# raw row-block codec (zero-copy shm data plane)
# ---------------------------------------------------------------------------


class RowCodec:
    """Encode/decode ``UpdateMsg``/``DeliverMsg`` as fixed-header raw frames.

    Key names are interned to u16 ids against a fixed, order-stable key list
    (``list(x0.keys())`` — identical in parent and forked children, so both
    sides agree on the table without a handshake).  Messages that are not
    raw-eligible (control messages, unknown keys, exotic dtypes) fall back
    to pickle-5 frames on the same stream; :meth:`frames` splits a mixed
    batch into consecutive raw/pickle frames so FIFO order is preserved.

    Encoding is zero-copy on the producer side too: :meth:`frames` yields
    *lists of buffers* (length prefix, fixed headers, and the messages' own
    array memoryviews) that :meth:`ShmRing.write_parts` copies straight into
    the ring — no intermediate ``b"".join`` of the row data.
    """

    def __init__(self, keys):
        self._keys = list(keys)
        if len(self._keys) > 0xFFFF:
            raise ValueError("RowCodec supports at most 65535 keys")
        self._key_id = {k: i for i, k in enumerate(self._keys)}

    # ------------------------------------------------------------- encode
    def _raw_ok(self, m) -> bool:
        t = type(m)
        if t is not UpdateMsg and t is not DeliverMsg:
            return False
        return (m.key in self._key_id
                and isinstance(m.rows, np.ndarray)
                and isinstance(m.delta, np.ndarray)
                and m.rows.dtype == np.int64
                and m.delta.dtype == np.float64
                and m.delta.ndim == 2)

    def _pack_raw(self, msgs: list) -> list:
        """One raw frame as a list of buffers (length prefix first)."""
        parts: list = [b"", _U32.pack(RAW_MAGIC), _U32.pack(len(msgs))]
        total = 8
        for m in msgs:
            rows = np.ascontiguousarray(m.rows)
            delta = np.ascontiguousarray(m.delta)
            kind = K_UPDATE if type(m) is UpdateMsg else K_DELIVER
            hdr = _RAW_HDR.pack(
                kind, DT_F64, self._key_id[m.key], m.uid, m.seq,
                m.worker, m.process, m.ts, getattr(m, "shard", 0),
                getattr(m, "epoch", 0), rows.shape[0], delta.shape[1])
            parts.append(hdr)
            parts.append(memoryview(rows).cast("B"))
            parts.append(memoryview(delta).cast("B"))
            total += _RAW_HDR.size + rows.nbytes + delta.nbytes
        if total > MAX_FRAME:
            raise ValueError(f"frame too large: {total} bytes")
        parts[0] = _U32.pack(total)
        return parts

    def raw_size(self, m) -> int:
        return _RAW_HDR.size + m.rows.nbytes + m.delta.nbytes

    def frames(self, msgs: list, max_frame: Optional[int]):
        """Split a batch into wire items, each either a raw frame (list of
        buffers) or a pickle frame (bytes), in batch order."""
        cap = (max_frame if max_frame is not None else MAX_FRAME) - 4
        out: list = []
        i, n = 0, len(msgs)
        while i < n:
            if self._raw_ok(msgs[i]):
                cur, cur_bytes = [], 8
                while i < n and self._raw_ok(msgs[i]):
                    sz = self.raw_size(msgs[i])
                    if cur and cur_bytes + sz > cap:
                        out.append(self._pack_raw(cur))
                        cur, cur_bytes = [], 8
                    cur.append(msgs[i])
                    cur_bytes += sz
                    i += 1
                if cur:
                    out.append(self._pack_raw(cur))
            else:
                j = i
                while j < n and not self._raw_ok(msgs[j]):
                    j += 1
                self._pickle_frames(msgs[i:j], max_frame, out)
                i = j
        return out

    def _pickle_frames(self, msgs: list, max_frame: Optional[int],
                       out: list) -> None:
        frame = encode_frame(msgs)
        if (max_frame is not None and len(frame) > max_frame
                and len(msgs) > 1):
            mid = len(msgs) // 2
            self._pickle_frames(msgs[:mid], max_frame, out)
            self._pickle_frames(msgs[mid:], max_frame, out)
        else:
            out.append(frame)

    # ------------------------------------------------------------- decode
    def decode_raw(self, mv) -> list:
        """Inverse of :meth:`_pack_raw` over a payload memoryview.  The
        returned messages' ``rows``/``delta`` are numpy views *into* ``mv``
        — zero-copy when ``mv`` maps ring memory (the caller then pins the
        frame until every message is released)."""
        n_msgs = _U32.unpack_from(mv, 4)[0]
        off = 8
        msgs = []
        for _ in range(n_msgs):
            (kind, dt, kid, uid, seq, worker, process, ts, shard, epoch,
             n_rows, n_cols) = _RAW_HDR.unpack_from(mv, off)
            off += _RAW_HDR.size
            if dt != DT_F64:
                raise ValueError(f"unknown raw dtype code {dt}")
            rows = np.frombuffer(mv, dtype=np.int64, count=n_rows,
                                 offset=off)
            off += n_rows * 8
            delta = np.frombuffer(mv, dtype=np.float64, count=n_rows * n_cols,
                                  offset=off).reshape(n_rows, n_cols)
            off += n_rows * n_cols * 8
            key = self._keys[kid]
            if kind == K_UPDATE:
                m = UpdateMsg(uid, worker, process, ts, key, rows, delta,
                              epoch, seq)
            elif kind == K_DELIVER:
                m = DeliverMsg(uid, worker, process, shard, ts, key, rows,
                               delta, seq)
            else:
                raise ValueError(f"unknown raw message kind {kind}")
            msgs.append(m)
        if off != mv.nbytes:
            raise ValueError(
                f"raw frame overrun: {mv.nbytes - off} trailing bytes")
        return msgs


class FrameHandle:
    """Pin on one decoded-in-place raw frame: the ring's head cursor may not
    pass this frame until every message decoded from it is released."""

    __slots__ = ("_reader", "start", "end", "_remaining", "released")

    def __init__(self, reader: "RingViewReader", start: int, end: int,
                 count: int):
        self._reader = reader
        self.start = start            # absolute stream offset of the frame
        self.end = end                # absolute offset one past the payload
        self._remaining = count
        self.released = False

    def release_one(self) -> None:
        r = self._reader
        with r._lock:
            self._remaining -= 1
            if self._remaining <= 0 and not self.released:
                self.released = True
                r._advance_locked()


def release_msg(msg) -> None:
    """Drop a message's pin on its source frame (no-op for owned msgs)."""
    h = getattr(msg, "_frame", None)
    if h is not None:
        msg._frame = None
        h.release_one()


def release_msgs(msgs) -> None:
    for m in msgs:
        release_msg(m)


def materialize_msg(msg):
    """Copy a view-backed message's arrays out of the ring and release its
    pin, in place.  Required before *retaining* a message (or its arrays)
    past the apply cycle that received it — once the pin drops and the read
    cursor advances, the producer may overwrite the backing ring bytes."""
    h = getattr(msg, "_frame", None)
    if h is not None:
        msg.rows = np.array(msg.rows)
        msg.delta = np.array(msg.delta)
        msg._frame = None
        h.release_one()
    return msg


class RingViewReader:
    """Zero-copy consumer side of a :class:`ShmRing` carrying RowCodec frames.

    Owns the ring's read side entirely: a *decode* cursor (``_pos``) runs
    ahead of the shared *head* cursor, which only advances past the longest
    prefix of frames whose messages have all been released.  Raw frames
    that lie contiguous in the ring decode as numpy views into ring memory
    (pinned via :class:`FrameHandle`); frames straddling the wrap point —
    and all pickle frames — are copied out and decode as owned messages
    (no pin, head free to advance).

    Discipline for consumers: every decoded message must be either
    *released* (:func:`release_msg`, after its arrays were fully consumed
    this apply cycle) or *materialized* (:func:`materialize_msg`, before
    being retained), and a consumer must never block on a wire write while
    holding unreleased pins — the producer could be waiting on this very
    ring's free space (see ``shard._handle_batch`` ordering).
    """

    def __init__(self, ring: "ShmRing", codec: RowCodec, bell_r: int,
                 stop: threading.Event,
                 trace: Optional["trace_mod.TraceHub"] = None):
        self.ring = ring
        self.codec = codec
        self.bell_r = bell_r
        self.stop = stop
        self.closed = False
        self.trace = trace
        self._warned_stale = False
        self._pos = 0          # absolute decode cursor
        self._released = 0     # absolute head we last published
        self._pending: deque = deque()   # pinned FrameHandles, stream order
        self._lock = threading.Lock()

    # head may only advance to the start of the first still-pinned frame
    # (or all the way to the decode cursor when nothing is pinned)
    def _advance_locked(self) -> None:
        while self._pending and self._pending[0].released:
            self._pending.popleft()
        new_head = self._pending[0].start if self._pending else self._pos
        if new_head > self._released:
            self._released = new_head
            self.ring._set_head(new_head)

    def pinned_frames(self) -> int:
        with self._lock:
            return len(self._pending)

    def _copy_out(self, pos: int, n: int) -> bytes:
        cap = self.ring.capacity
        off = pos % cap
        first = min(n, cap - off)
        base = ShmRing.HDR
        out = bytes(self.ring.buf[base + off:base + off + first])
        if first < n:
            out += bytes(self.ring.buf[base:base + n - first])
        return out

    def _decode_ready(self) -> list:
        out: list = []
        cap = self.ring.capacity
        t0 = time.monotonic_ns() if self.trace is not None else 0
        while not self.closed:
            tail = self.ring._tail()
            # validate the cross-process cursor read exactly like
            # ShmRing.read_available: a stale/torn value must never reach
            # the arithmetic below (it would replay or overrun the stream)
            if tail < self._pos or tail - self._released > cap:
                if not self._warned_stale:
                    self._warned_stale = True
                    log.warning(
                        "shm view reader: stale cross-process tail cursor "
                        "read (tail=%d decode_pos=%d released=%d cap=%d); "
                        "treating as empty and retrying [warned once per "
                        "ring]", tail, self._pos, self._released, cap)
                break
            if tail - self._pos < 4:
                break
            plen = _U32.unpack(self._copy_out(self._pos, 4))[0]
            if plen == EOF_LEN:
                self.closed = True
                with self._lock:
                    self._pos += 4
                    self._advance_locked()
                break
            if tail - self._pos < 4 + plen:
                break               # defensive: frames publish atomically
            start = self._pos
            body = start + 4
            end = body + plen
            off = body % cap
            pinned = off + plen <= cap      # contiguous span in the ring
            if pinned:
                base = ShmRing.HDR
                mv = self.ring.buf[base + off:base + off + plen]
            else:                           # straddles the wrap: copy out
                mv = memoryview(self._copy_out(body, plen))
            if plen >= 8 and _U32.unpack_from(mv, 0)[0] == RAW_MAGIC:
                msgs = self.codec.decode_raw(mv)
                with self._lock:
                    if pinned and msgs:
                        h = FrameHandle(self, start, end, len(msgs))
                        for m in msgs:
                            m._frame = h
                        self._pending.append(h)
                    self._pos = end
                    self._advance_locked()
            else:
                msgs = decode_payload(bytes(mv))    # owned: copy, no pin
                with self._lock:
                    self._pos = end
                    self._advance_locked()
            out.extend(msgs)
        if out and self.trace is not None:
            self.trace.span(trace_mod.EV_WIRE_DECODE, t0, len(out), 0,
                            threading.current_thread().name)
        return out

    def read_msgs(self) -> Optional[list]:
        """Block until at least one message is decodable; None on EOF/stop."""
        while True:
            msgs = self._decode_ready()
            if msgs:
                return msgs
            if self.closed or self.stop.is_set():
                return None
            try:
                os.read(self.bell_r, 1 << 16)   # park until the bell rings
            except OSError:
                return None                     # bell closed: teardown


def view_reader_loop(reader: RingViewReader, inbox: queue.Queue,
                     on_error: Callable[[BaseException], None]) -> None:
    try:
        while True:
            msgs = reader.read_msgs()
            if msgs is None:
                return
            for m in msgs:
                inbox.put(m)
    except BaseException as e:      # surfaced into RunStats by the runtime
        on_error(e)


def start_view_reader(name: str, reader: RingViewReader, inbox: queue.Queue,
                      on_error: Callable[[BaseException], None],
                      ) -> threading.Thread:
    t = threading.Thread(target=view_reader_loop,
                         args=(reader, inbox, on_error),
                         name=name, daemon=True)
    t.start()
    return t


# ---------------------------------------------------------------------------
# wire-backed channels
# ---------------------------------------------------------------------------


class WireChannel:
    """Channel facade over a byte sink: stamps seqs, writes framed batches.

    Same duck type as :class:`repro.runtime.messages.Channel` (``send`` /
    ``send_many``); the seq stamp and the wire write happen under one lock so
    sequence numbers are monotone in *stream order* even with multiple sender
    threads (all workers of a client process share the proc->shard edge).
    """

    def __init__(self, name: str, write: Callable[[bytes], None],
                 max_frame: Optional[int] = None,
                 try_write: Optional[Callable[[bytes], bool]] = None,
                 room: Optional[Callable[[], int]] = None,
                 codec: Optional[RowCodec] = None,
                 on_flush: Optional[Callable[[], None]] = None,
                 trace: Optional["trace_mod.TraceHub"] = None):
        self.name = name
        self._write = write
        self._max_frame = max_frame    # soft cap: split batches above this
        self._try_write = try_write    # non-blocking sink (drop-and-resync)
        self._room = room              # cheap free-space probe, if the sink
        self._seq = 0                  # can tell (shm rings can)
        self._lock = threading.Lock()
        self._codec = codec            # raw row-block encoding (zero-copy)
        self._on_flush = on_flush      # rung once per send_many, not per
                                       # frame (batched doorbell wakes)
        self._trace = trace

    def send(self, msg) -> None:
        self.send_many([msg])

    def send_many(self, msgs: list) -> None:
        if not msgs:
            return
        trc = self._trace
        t0 = time.monotonic_ns() if trc is not None else 0
        with self._lock:
            for m in msgs:
                m.seq = self._seq
                self._seq += 1
            self._write_frames(msgs)
            if self._on_flush is not None:
                self._on_flush()
        if trc is not None:
            trc.span(trace_mod.EV_WIRE_WRITE, t0, len(msgs), 0, self.name)

    # -------------------------------------------------- non-blocking sends
    @property
    def can_try(self) -> bool:
        return self._try_write is not None

    def room(self) -> int:
        """Free sink bytes if the backend can tell, else a large number."""
        return self._room() if self._room is not None else (1 << 62)

    def try_send_many(self, msgs: list) -> bool:
        """Send one frame without blocking; False (and NO seq consumed —
        the stamp rolls back) when the sink has no room right now.  The
        serving publish path uses this so a wedged replica can never stall
        the shard: its frames are dropped and it is re-bootstrapped with a
        fresh in-stream state once the sink drains."""
        if not msgs:
            return True
        if self._try_write is None:
            self.send_many(msgs)
            return True
        with self._lock:
            for m in msgs:
                m.seq = self._seq
                self._seq += 1
            frame = encode_frame(msgs)
            if self._try_write(frame):
                return True
            self._seq -= len(msgs)     # dropped: the stream never saw them
            return False

    def _write_frames(self, msgs: list) -> None:
        """Encode and write, halving batches that exceed the frame cap (a
        bounded wire like a shm ring cannot take arbitrarily large frames;
        a single oversized message still goes out whole — size the ring for
        the largest single row part)."""
        if self._codec is not None:
            first = True
            for item in self._codec.frames(msgs, self._max_frame):
                if not first and self._on_flush is not None:
                    # Earlier frames of this batch are published but not yet
                    # belled; if this next write blocks on ring space, the
                    # parked reader must be woken to drain them or neither
                    # side can ever advance (the wake byte persists in the
                    # pipe, so ringing before the write cannot be lost).
                    # The common single-frame flush keeps exactly one bell:
                    # the send_many/close on_flush after the write.
                    self._on_flush()
                self._write(item)
                first = False
            return
        frame = encode_frame(msgs)
        if (self._max_frame is not None and len(frame) > self._max_frame
                and len(msgs) > 1):
            mid = len(msgs) // 2
            self._write_frames(msgs[:mid])
            self._write_frames(msgs[mid:])
            return
        self._write(frame)

    def close(self) -> None:
        try:
            self._write(eof_frame())
            if self._on_flush is not None:
                self._on_flush()    # wake the reader so it sees the EOF
        except (OSError, ValueError, RuntimeError):
            pass    # peer gone / ring full past deadline; EOF is best-effort


def _reader_loop(read_chunk: Callable[[], Optional[bytes]],
                 inbox: queue.Queue,
                 on_error: Callable[[BaseException], None],
                 trace: Optional["trace_mod.TraceHub"] = None) -> None:
    """Pump a byte source into an inbox until EOF. `read_chunk` returns b''
    to mean try-again (ring empty) and None on hard end-of-stream."""
    dec = FrameDecoder()
    tname = threading.current_thread().name
    try:
        while not dec.closed:
            chunk = read_chunk()
            if chunk is None:
                break
            if not chunk:
                continue
            t0 = time.monotonic_ns() if trace is not None else 0
            msgs = dec.feed(chunk)
            if msgs and trace is not None:
                trace.span(trace_mod.EV_WIRE_DECODE, t0, len(msgs), 0, tname)
            for msg in msgs:
                inbox.put(msg)
    except BaseException as e:      # surfaced into RunStats by the runtime
        on_error(e)


# ---------------------------------------------------------------------------
# TCP backend
# ---------------------------------------------------------------------------


class TcpConn:
    """One accepted/connected socket carrying a duplex client<->shard edge."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # channels idle for long stretches are normal (a client with no
        # inbound deliveries); never let a connect/accept timeout linger
        # and poison recv() mid-run
        sock.settimeout(None)
        # probe the queued-bytes ioctl ONCE at connection setup — room()
        # sits on the per-flush try_write hot path, and re-importing
        # fcntl/termios per call costs more than the probe it guards.
        # SO_SNDBUF is NOT cached: Linux autotunes the send buffer upward
        # when it was never set explicitly, and a stale cached size would
        # under-report room() and refuse sends that fit (a per-call
        # getsockopt is a cheap syscall, nothing like the import machinery).
        try:
            import fcntl
            import termios
            fcntl.ioctl(sock, termios.TIOCOUTQ, b"\0" * 4)
            self._ioctl = fcntl.ioctl
            self._tiocoutq = termios.TIOCOUTQ
        except (OSError, ImportError, AttributeError):
            self._ioctl = None
            self._tiocoutq = 0

    def write(self, data: bytes) -> None:
        self.sock.sendall(data)

    def room(self) -> int:
        """Approximate free kernel send-buffer bytes (Linux SIOCOUTQ:
        SO_SNDBUF minus unsent queued bytes).  Where the ioctl is
        unavailable, falls back to 'unknown' (a large number) and
        :meth:`try_write` degrades to a select()-writability probe."""
        if self._ioctl is None:
            return 1 << 62
        try:
            queued = struct.unpack(
                "i", self._ioctl(self.sock, self._tiocoutq, b"\0" * 4))[0]
            sndbuf = self.sock.getsockopt(socket.SOL_SOCKET,
                                          socket.SO_SNDBUF)
        except OSError:
            return 1 << 62
        return max(0, sndbuf - queued)

    def try_write(self, data: bytes) -> bool:
        """Non-blocking write: refuse unless the whole frame fits in the
        free kernel send buffer right now (so the sendall below cannot
        block on a wedged peer).  On hosts without the queued-bytes ioctl
        this degrades to a select() probe, which only proves *some* room —
        the shm serving transport keeps the hard no-stall guarantee."""
        free = self.room()
        if free < len(data):
            return False
        if free == 1 << 62:                # unknown: fall back to select
            _, writable, _ = select.select([], [self.sock], [], 0)
            if not writable:
                return False
        self.sock.sendall(data)
        return True

    def read_chunk(self) -> Optional[bytes]:
        data = self.sock.recv(1 << 16)
        return data if data else None

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


class TcpTransport:
    """Listener + handshake: one loopback connection per (process, shard).

    Parent: ``listen()`` before forking, then ``accept_all()``.  Child:
    ``connect(pid)`` opens its ``n_shards`` connections, each starting with
    an 8-byte ``(pid, sid)`` handshake so the parent can route it.
    """

    def __init__(self, n_proc: int, n_shards: int):
        self.n_proc = n_proc
        self.n_shards = n_shards
        self._lsock: Optional[socket.socket] = None
        self.port = 0

    def listen(self) -> None:
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind(("127.0.0.1", 0))
        s.listen(self.n_proc * self.n_shards)
        self._lsock = s
        self.port = s.getsockname()[1]

    def accept_all(self, deadline: float) -> Dict[Tuple[int, int], TcpConn]:
        conns: Dict[Tuple[int, int], TcpConn] = {}
        assert self._lsock is not None
        self._lsock.settimeout(1.0)
        want = self.n_proc * self.n_shards
        while len(conns) < want:
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"tcp transport: only {len(conns)}/{want} channels "
                    "connected before deadline")
            try:
                sock, _ = self._lsock.accept()
            except socket.timeout:
                continue
            try:
                # the 8-byte handshake must also respect the deadline — a
                # connector that dies (or a stray local client) must not
                # wedge start() in a blocking recv
                sock.settimeout(5.0)
                hs = _recv_exact(sock, 8)
            except (socket.timeout, ConnectionError, OSError):
                sock.close()
                continue
            pid, sid = _U32.unpack_from(hs, 0)[0], _U32.unpack_from(hs, 4)[0]
            if (pid >= self.n_proc or sid >= self.n_shards
                    or (pid, sid) in conns):   # out-of-range or duplicate:
                sock.close()                   # never split a FIFO channel
                continue                       # across two sockets
            conns[(pid, sid)] = TcpConn(sock)
        self._lsock.close()
        self._lsock = None
        return conns

    def connect(self, pid: int) -> Dict[int, TcpConn]:
        out: Dict[int, TcpConn] = {}
        for sid in range(self.n_shards):
            s = socket.create_connection(("127.0.0.1", self.port), timeout=30)
            s.sendall(_U32.pack(pid) + _U32.pack(sid))
            out[sid] = TcpConn(s)
        return out

    def close_listener(self) -> None:
        if self._lsock is not None:
            self._lsock.close()
            self._lsock = None


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("handshake: peer closed early")
        buf += chunk
    return buf


# ---------------------------------------------------------------------------
# shared-memory ring backend
# ---------------------------------------------------------------------------


class ShmRing:
    """Single-producer single-consumer byte ring in a SharedMemory segment.

    Layout: ``u64 head | u64 tail | data[capacity]``.  ``head`` (read
    cursor) is written only by the consumer, ``tail`` (write cursor) only by
    the producer; both are monotonically increasing byte counts taken modulo
    ``capacity`` on access, so no lock is needed across processes.  The
    counters are updated strictly *after* the corresponding memcpy, which on
    CPython (no store reordering across bytecode, x86 TSO) makes the data
    visible before the cursor that publishes it.

    Both sides additionally *validate* every cross-process cursor read
    (``head <= tail <= head + capacity``): on some virtualized hosts a read
    of the peer's cursor can transiently return a stale value, and acting
    on one would rewind the read cursor (stream replay) or overstate free
    space (overwrite).  A bogus reading is treated as "empty"/"full" and
    retried — monotone cursors guarantee a sane reading follows.
    """

    HDR = 16

    def __init__(self, shm: shared_memory.SharedMemory, capacity: int):
        self.shm = shm
        self.capacity = capacity
        self.buf = shm.buf
        self._warned_stale = False

    @classmethod
    def create(cls, capacity: int) -> "ShmRing":
        shm = shared_memory.SharedMemory(create=True,
                                         size=cls.HDR + capacity)
        shm.buf[:cls.HDR] = b"\0" * cls.HDR
        return cls(shm, capacity)

    # cursor accessors -----------------------------------------------------
    def _head(self) -> int:
        return _U64.unpack_from(self.buf, 0)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self.buf, 8)[0]

    def _set_head(self, v: int) -> None:
        _U64.pack_into(self.buf, 0, v)

    def _set_tail(self, v: int) -> None:
        _U64.pack_into(self.buf, 8, v)

    # producer -------------------------------------------------------------
    def write(self, data: bytes, deadline: float = float("inf"),
              abort: Optional[Callable[[], bool]] = None) -> None:
        """Block (spin + short sleep) until `data` fits, then publish it."""
        spins = 0
        while not self.try_write(data):
            spins += 1
            if spins > 100:
                if time.monotonic() > deadline:
                    raise RuntimeError("shm ring write timed out (peer stuck)")
                if abort is not None and abort():
                    raise RuntimeError("shm ring write aborted")
                time.sleep(2e-4)

    def free_bytes(self) -> int:
        """Bytes writable right now, from the producer's view.

        ``tail`` is the producer's own cursor (trusted); ``head`` crosses a
        process boundary, and on virtualized hosts a read can transiently
        return a stale value — an overstated head would report free space
        that isn't and let the producer overwrite unread bytes, so any
        out-of-range reading clamps to "full" and the caller retries (the
        cursors are monotone: a sane reading always comes around)."""
        used = self._tail() - self._head()
        if used < 0 or used > self.capacity:
            if not self._warned_stale:
                self._warned_stale = True
                log.warning(
                    "shm ring %s: stale cross-process head cursor read "
                    "(used=%d cap=%d); clamping to full and retrying "
                    "[warned once per ring]",
                    self.shm.name, used, self.capacity)
            return 0                    # stale/torn cursor read: treat full
        return self.capacity - used

    def try_write(self, data: bytes) -> bool:
        """Publish `data` iff it fits right now; never blocks or spins."""
        n = len(data)
        if n > self.capacity:
            raise ValueError(
                f"frame of {n} bytes exceeds ring capacity {self.capacity}")
        if self.free_bytes() < n:
            return False
        tail = self._tail()
        pos = tail % self.capacity
        first = min(n, self.capacity - pos)
        off = self.HDR + pos
        self.buf[off:off + first] = data[:first]
        if first < n:                       # wrap around to the start
            self.buf[self.HDR:self.HDR + n - first] = data[first:]
        self._set_tail(tail + n)
        return True

    def try_write_parts(self, parts: list, total: int) -> bool:
        """Publish a multi-part frame iff it fits right now.  Each part is a
        bytes-like buffer (the RowCodec's fixed headers and the messages'
        own array memoryviews); copying them into the ring one by one is
        the producer's single copy — no intermediate join."""
        if total > self.capacity:
            raise ValueError(
                f"frame of {total} bytes exceeds ring capacity "
                f"{self.capacity}")
        if self.free_bytes() < total:
            return False
        tail = self._tail()
        pos = tail % self.capacity
        for part in parts:
            mv = part if isinstance(part, memoryview) else memoryview(part)
            n = mv.nbytes
            first = min(n, self.capacity - pos)
            off = self.HDR + pos
            self.buf[off:off + first] = mv[:first]
            if first < n:                   # wrap around to the start
                self.buf[self.HDR:self.HDR + n - first] = mv[first:]
            pos = (pos + n) % self.capacity
        self._set_tail(tail + total)
        return True

    def write_parts(self, parts: list, deadline: float = float("inf"),
                    abort: Optional[Callable[[], bool]] = None) -> None:
        """Blocking counterpart of :meth:`try_write_parts`."""
        mvs, total = [], 0
        for p in parts:
            mv = p if isinstance(p, memoryview) else memoryview(p)
            mvs.append(mv)
            total += mv.nbytes
        spins = 0
        while not self.try_write_parts(mvs, total):
            spins += 1
            if spins > 100:
                if time.monotonic() > deadline:
                    raise RuntimeError("shm ring write timed out (peer stuck)")
                if abort is not None and abort():
                    raise RuntimeError("shm ring write aborted")
                time.sleep(2e-4)

    # consumer -------------------------------------------------------------
    def read_available(self) -> bytes:
        """Drain and return whatever bytes are currently published.

        ``head`` is the consumer's own cursor (trusted); ``tail`` crosses a
        process boundary and can transiently read stale on virtualized
        hosts.  A bogus reading (behind head, or further ahead than the
        ring could hold) must NOT reach the arithmetic below — a negative
        count would *rewind* head and replay the whole stream — so it is
        treated as empty and retried; the doorbell byte that announced the
        real frame persists in the pipe, so no wakeup is lost."""
        head, tail = self._head(), self._tail()
        n = tail - head
        if n <= 0 or n > self.capacity:
            if (n < 0 or n > self.capacity) and not self._warned_stale:
                self._warned_stale = True
                log.warning(
                    "shm ring %s: stale cross-process tail cursor read "
                    "(n=%d cap=%d); treating as empty and retrying "
                    "[warned once per ring]", self.shm.name, n, self.capacity)
            return b""
        pos = head % self.capacity
        first = min(n, self.capacity - pos)
        off = self.HDR + pos
        out = bytes(self.buf[off:off + first])
        if first < n:
            out += bytes(self.buf[self.HDR:self.HDR + n - first])
        self._set_head(head + n)
        return out

    def close(self) -> None:
        self.buf = None
        try:
            self.shm.close()
        except BufferError:
            # a zero-copy numpy view into the segment is still referenced
            # somewhere (e.g. a message abandoned by an aborted run); the
            # mapping is reclaimed at process exit, and unlink() below
            # works regardless
            pass

    def unlink(self) -> None:
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


class ShmEdge:
    """The two rings of one client<->shard pair (c2s = client writes), each
    with a pipe *doorbell*: the producer writes a wake byte after publishing
    a frame, so the consumer blocks in ``os.read`` (a real kernel sleep that
    releases the GIL) instead of polling.  Sub-ms polling is not an option —
    each poll wakeup forces a GIL handoff, and a few fine-grained pollers
    measurably halve a worker thread's throughput on a small host."""

    def __init__(self, capacity: int):
        self.c2s = ShmRing.create(capacity)
        self.s2c = ShmRing.create(capacity)
        self.c2s_bell = os.pipe()
        self.s2c_bell = os.pipe()
        for _, w in (self.c2s_bell, self.s2c_bell):
            os.set_blocking(w, False)

    @staticmethod
    def ring_bell(bell_w: int) -> None:
        try:
            os.write(bell_w, b"\x01")
        except (BlockingIOError, OSError):
            pass        # pipe full of pending wakeups / peer gone: fine

    def wake_all(self) -> None:
        """Unblock any reader parked on a doorbell (teardown path)."""
        for _, w in (self.c2s_bell, self.s2c_bell):
            self.ring_bell(w)

    def close(self, unlink: bool) -> None:
        for ring in (self.c2s, self.s2c):
            ring.close()
            if unlink:
                ring.unlink()
        for r, w in (self.c2s_bell, self.s2c_bell):
            for fd in (r, w):
                try:
                    os.close(fd)
                except OSError:
                    pass


class ShmTransport:
    """Pre-forked shared-memory edges; children inherit the mappings."""

    def __init__(self, n_proc: int, n_shards: int, capacity: int = 1 << 20):
        require_tso()
        self.edges: Dict[Tuple[int, int], ShmEdge] = {
            (p, s): ShmEdge(capacity)
            for p in range(n_proc) for s in range(n_shards)}

    def close(self, unlink: bool) -> None:
        for e in self.edges.values():
            e.wake_all()               # unpark doorbell readers first
        for e in self.edges.values():
            e.close(unlink)


def ring_writer(ring: ShmRing, bell_w: int,
                deadline: float = float("inf")) -> Callable[[bytes], None]:
    """Byte sink for a :class:`WireChannel`: publish, then ring the bell."""
    def write(data: bytes) -> None:
        ring.write(data, deadline)
        ShmEdge.ring_bell(bell_w)
    return write


def ring_parts_writer(ring: ShmRing, deadline: float = float("inf"),
                      abort: Optional[Callable[[], bool]] = None,
                      ) -> Callable[[object], None]:
    """Byte sink for a zero-copy :class:`WireChannel`: accepts either a
    plain bytes frame (EOF sentinel, pickle fallback) or a RowCodec list of
    buffers, and does NOT ring the doorbell itself — the channel rings via
    ``on_flush``: once after a single-frame send_many (the common case),
    and once per frame when a batch splits, so a producer blocking on ring
    space can never strand published-but-unbelled frames behind a parked
    reader."""
    def write(item) -> None:
        if isinstance(item, (bytes, bytearray, memoryview)):
            ring.write(item, deadline, abort)
        else:
            ring.write_parts(item, deadline, abort)
    return write


def try_ring_writer(ring: ShmRing, bell_w: int) -> Callable[[bytes], bool]:
    """Non-blocking byte sink for ``WireChannel.try_send_many``: publish iff
    the frame fits right now, ringing the bell only on success."""
    def try_write(data: bytes) -> bool:
        if ring.try_write(data):
            ShmEdge.ring_bell(bell_w)
            return True
        return False
    return try_write


def ring_reader(ring: ShmRing, bell_r: int,
                stop: threading.Event) -> Callable[[], Optional[bytes]]:
    """read_chunk adapter for :func:`_reader_loop` over a ShmRing: drain
    whatever is published, else park on the doorbell until the producer
    rings.  A stale wake byte (data already drained) just loops once more;
    a wake byte can never be missed because it persists in the pipe."""
    def read_chunk() -> Optional[bytes]:
        data = ring.read_available()
        if data:
            return data
        if stop.is_set():
            return None
        try:
            os.read(bell_r, 1 << 16)       # kernel sleep until a frame lands
        except OSError:
            return None                    # bell closed: teardown
        return b""
    return read_chunk


def start_reader(name: str, read_chunk: Callable[[], Optional[bytes]],
                 inbox: queue.Queue,
                 on_error: Callable[[BaseException], None],
                 trace: Optional["trace_mod.TraceHub"] = None,
                 ) -> threading.Thread:
    t = threading.Thread(target=_reader_loop,
                         args=(read_chunk, inbox, on_error, trace),
                         name=name, daemon=True)
    t.start()
    return t
