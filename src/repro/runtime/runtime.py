"""Real multi-threaded asynchronous parameter server (DESIGN.md layer 1').

Where :mod:`repro.core.server` *simulates* the paper's bounded-asynchronous
semantics in a deterministic event loop, this module *implements* them with
actual concurrency, in the style of Petuum-PS:

  * N worker threads per client process share a **process cache**
    (read-my-writes: a worker's Incs are visible to its own process
    immediately);
  * **server shards** (one thread each) own hash-partitioned rows of
    :class:`repro.core.tables.Table` — row ``r`` of a key lives on shard
    ``r % n_shards`` — and hold the master copy;
  * all edges are **FIFO per-channel queues** with sequence numbers the
    receivers assert in check mode;
  * the **Consistency Controller** (:mod:`repro.core.controller`, shared with
    the simulator) gates progress: the clock bound blocks a worker whose
    period would outrun the delivery frontier (BSP/SSP/CAP/CVAP), and the
    value bound blocks an Inc that would push the element-wise unsynchronized
    accumulator past ``max(u, v_thr)`` (VAP/CVAP);
  * within a period, updates are applied and sent **largest-magnitude first**
    (paper §4.2); BSP/SSP hold them in a per-worker outbox until Clock().

The simulator stays the executable specification: given the same
``update_fn`` both produce the same set of updates, so the quiesced runtime
state must equal the simulator's final state element-wise (updates are
additive and commutative).  ``tests/test_runtime_conformance.py`` asserts
exactly that, plus the clock/value invariants under free thread
interleavings.

``barrier_reads`` (conformance mode, requires ``threads_per_process == 1``):
peer updates stamped with the reader's current period or later are staged and
applied only at the period boundary, so reads see *exactly* the updates the
consistency model guarantees and nothing fresher.  Under BSP this makes the
runtime bit-deterministic, which is what lets differential tests compare LDA
trajectories against the simulator and the SPMD sync layer.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import controller
from repro.core.policies import Policy
from repro.core.server import RunStats, UpdateMap
from repro.runtime.messages import (SHUTDOWN, AckMsg, Channel, ClockMarker,
                                    ClockMsg, DeliverMsg, FullyDelivered,
                                    UpdateMsg)
from repro.runtime.shard import ServerShard


class ClientProcess:
    """A client process: shared cache + comm thread for its worker threads."""

    def __init__(self, rt: "PSRuntime", pid: int):
        self.rt = rt
        self.pid = pid
        self.cond = threading.Condition()     # guards every field below
        self.cache: Dict[str, np.ndarray] = {k: v.copy()
                                             for k, v in rt._x0.items()}
        self.workers = list(range(pid * rt.tpp, (pid + 1) * rt.tpp))
        # per-worker element-wise unsynchronized accumulators
        self.unsynced: Dict[int, Dict[str, np.ndarray]] = {
            w: {k: np.zeros_like(v) for k, v in rt._x0.items()}
            for w in self.workers}
        self.thread_clock: Dict[int, int] = {w: 0 for w in self.workers}
        self.sent_clock = 0                   # completed periods announced
        # marks[p, s]: highest period of process p fully forwarded by shard s
        self.marks = np.full((rt.n_proc, rt.n_shards), -1, dtype=np.int64)
        self.staged: List[DeliverMsg] = []    # barrier_reads holding pen
        self.inbox: queue.Queue = queue.Queue()
        self._last_seq = defaultdict(lambda: -1)   # per sender shard
        self.thread = threading.Thread(
            target=self._loop, name=f"ps-proc-{pid}", daemon=True)

    # ---------------------------------------------------------------- frontier
    def frontier_min(self) -> int:
        """Lowest period every peer process is known-delivered through."""
        peers = [p for p in range(self.rt.n_proc) if p != self.pid]
        if not peers:
            return 1 << 60
        return int(self.marks[peers, :].min())

    def cur_period(self) -> int:
        return min(self.thread_clock.values())

    # ---------------------------------------------------------------- comm
    def _loop(self) -> None:
        while True:
            msg = self.inbox.get()
            if msg is SHUTDOWN:
                self.inbox.task_done()
                return
            try:
                self._handle(msg)
            except BaseException as e:
                self.rt._record_error(e)
            finally:
                self.inbox.task_done()
                self.rt._msg_done()

    def _handle(self, msg) -> None:
        rt = self.rt
        ack: Optional[Tuple[Channel, AckMsg]] = None
        with self.cond:
            if rt.check:
                last = self._last_seq[msg.shard]
                if msg.seq != last + 1:
                    rt._violation(f"FIFO violation: shard {msg.shard}->proc "
                                  f"{self.pid} seq {msg.seq} after {last}")
                self._last_seq[msg.shard] = msg.seq
            if isinstance(msg, DeliverMsg):
                if rt.barrier_reads and msg.ts >= self.cur_period():
                    self.staged.append(msg)
                else:
                    self._apply_delivery(msg)
                    ack = (rt._chan_ps[self.pid][msg.shard],
                           AckMsg(msg.uid, self.pid))
            elif isinstance(msg, ClockMarker):
                # max(): the frontier may never regress (channel FIFO already
                # orders markers per (proc, shard); this makes it local)
                self.marks[msg.process, msg.shard] = max(
                    self.marks[msg.process, msg.shard], msg.clock)
            elif isinstance(msg, FullyDelivered):
                acc = self.unsynced[msg.worker][msg.key]
                res = acc[msg.rows] - msg.delta
                acc[msg.rows] = np.where(np.abs(res) < 1e-12, 0.0, res)
            else:
                raise TypeError(f"proc {self.pid}: unexpected message {msg!r}")
            self.cond.notify_all()
        if ack is not None:
            rt._send(*ack)

    def _apply_delivery(self, msg: DeliverMsg) -> None:
        self.cache[msg.key][msg.rows] += msg.delta

    def release_staged(self, new_period: int) -> List[Tuple[Channel, AckMsg]]:
        """Apply staged deliveries now inside the staleness window.

        Caller holds ``self.cond`` (the ticking worker, at a period
        boundary).  Returns the acks to send after the lock is dropped.
        """
        acks, keep = [], []
        for msg in self.staged:
            if msg.ts < new_period:
                self._apply_delivery(msg)
                acks.append((self.rt._chan_ps[self.pid][msg.shard],
                             AckMsg(msg.uid, self.pid)))
            else:
                keep.append(msg)
        self.staged = keep
        return acks


class RuntimeViewHandle:
    """Read API handed to update_fn — mirrors the simulator's ViewHandle."""

    def __init__(self, rt: "PSRuntime", proc: ClientProcess, worker: int):
        self._rt = rt
        self._proc = proc
        self.worker = worker
        self.gets = 0

    def get(self, key: str) -> np.ndarray:
        self.gets += 1
        with self._proc.cond:
            flat = self._proc.cache[key].copy()
        return flat.reshape(self._rt._shapes[key])

    def keys(self) -> Sequence[str]:
        return list(self._rt._x0.keys())


class PSRuntime:
    """The threaded asynchronous parameter server.

    Drop-in counterpart of :class:`repro.core.server.AsyncPS` — same
    ``update_fn(worker, clock, view, rng)`` contract, same per-worker rng
    seeding, same :class:`RunStats` — but wall-clock concurrent instead of
    simulated.  ``NetworkModel`` / ``compute_time`` / ``straggler`` have no
    analogue here: latency and skew are real.
    """

    def __init__(self, n_workers: int, policy: Policy,
                 init_params: UpdateMap,
                 n_shards: int = 2,
                 threads_per_process: int = 1,
                 seed: int = 0,
                 prioritize_by_magnitude: bool = True,
                 check_invariants: bool = True,
                 barrier_reads: bool = False):
        if n_workers % threads_per_process:
            raise ValueError("n_workers must divide into processes evenly")
        if n_shards < 1:
            raise ValueError("need at least one server shard")
        if barrier_reads and threads_per_process != 1:
            raise ValueError("barrier_reads requires threads_per_process == 1")
        self.P = n_workers
        self.tpp = threads_per_process
        self.n_proc = n_workers // threads_per_process
        self.n_shards = n_shards
        self.policy = policy
        self.seed = seed
        self.prioritize = prioritize_by_magnitude
        self.check = check_invariants
        self.barrier_reads = barrier_reads

        # canonical (R, C) float64 master shapes; original shapes for reads
        self._shapes: Dict[str, Tuple[int, ...]] = {}
        self._x0: Dict[str, np.ndarray] = {}
        self._shard_rows: Dict[str, List[np.ndarray]] = {}
        for key, v in init_params.items():
            a = np.asarray(v, dtype=np.float64)
            self._shapes[key] = a.shape
            flat = a.reshape(a.shape[0], -1) if a.ndim > 1 else a.reshape(-1, 1)
            self._x0[key] = flat.copy()
            rows = np.arange(flat.shape[0])
            self._shard_rows[key] = [rows[rows % n_shards == s]
                                     for s in range(n_shards)]

        self.stats = RunStats()
        self._slock = threading.Lock()
        self._total = {k: np.zeros_like(v) for k, v in self._x0.items()}
        self._uid = itertools.count()
        self._done_clock = 0
        self._t0 = 0.0
        self._deadline = float("inf")
        self._errors: List[BaseException] = []
        self._qcond = threading.Condition()   # guards _inflight
        self._inflight = 0

        self.shards = [ServerShard(self, s) for s in range(n_shards)]
        self.procs = [ClientProcess(self, p) for p in range(self.n_proc)]
        # FIFO channels: client process -> shard, shard -> client process
        self._chan_ps = [[Channel(f"p{p}->s{s}", self.shards[s].inbox)
                          for s in range(n_shards)] for p in range(self.n_proc)]
        self._chan_sp = [[Channel(f"s{s}->p{p}", self.procs[p].inbox)
                          for p in range(self.n_proc)] for s in range(n_shards)]

        self.update_fn: Optional[Callable] = None
        self.n_clocks = 0
        self._workers: List[threading.Thread] = []
        self._started = False
        self._finished = False

    # ------------------------------------------------------------- plumbing
    def proc_of(self, worker: int) -> int:
        return worker // self.tpp

    def _send(self, chan: Channel, msg) -> None:
        with self._qcond:
            self._inflight += 1
        chan.send(msg)

    def _msg_done(self) -> None:
        with self._qcond:
            self._inflight -= 1
            if self._inflight == 0:
                self._qcond.notify_all()

    def _violation(self, text: str) -> None:
        with self._slock:
            self.stats.violations.append(text)

    def _record_error(self, e: BaseException) -> None:
        with self._slock:
            self._errors.append(e)

    def _check_alive(self) -> None:
        if time.monotonic() > self._deadline:
            raise RuntimeError(
                "runtime deadlock: wall-clock deadline exceeded "
                f"(inflight={self._inflight})")
        if self._errors:
            raise RuntimeError("runtime aborted: peer thread failed")

    # ---------------------------------------------------------------- running
    def start(self, update_fn: Callable, n_clocks: int,
              timeout: float = 120.0) -> None:
        """Launch shard/comm/worker threads; pair with :meth:`wait`."""
        if self._started:
            raise RuntimeError("runtime already started")
        self._started = True
        self.update_fn = update_fn
        self.n_clocks = n_clocks
        self._deadline = time.monotonic() + timeout
        self._t0 = time.monotonic()
        for s in self.shards:
            s.thread.start()
        for p in self.procs:
            p.thread.start()
        self._workers = [threading.Thread(target=self._worker_loop, args=(w,),
                                          name=f"ps-worker-{w}", daemon=True)
                         for w in range(self.P)]
        for t in self._workers:
            t.start()

    def wait(self) -> RunStats:
        """Join workers, quiesce all in-flight messages, run final checks."""
        if not self._started or self._finished:
            raise RuntimeError("runtime not running")
        for t in self._workers:
            while t.is_alive():
                t.join(timeout=0.5)
                if time.monotonic() > self._deadline:
                    self._record_error(RuntimeError(
                        f"worker {t.name} still alive at deadline"))
                    break
        if not self._errors:
            with self._qcond:
                while self._inflight > 0:
                    if time.monotonic() > self._deadline:
                        self._record_error(RuntimeError(
                            f"quiesce timed out ({self._inflight} in flight)"))
                        break
                    self._qcond.wait(0.25)
        self._finished = True
        for p in self.procs:
            p.inbox.put(SHUTDOWN)
        for s in self.shards:
            s.inbox.put(SHUTDOWN)
        for th in [p.thread for p in self.procs] + [s.thread for s in self.shards]:
            th.join(timeout=5.0)
        self.stats.sim_time = time.monotonic() - self._t0
        if self._errors:
            raise RuntimeError(
                f"runtime failed: {self._errors[0]!r}") from self._errors[0]
        if self.check:
            self._final_checks()
        return self.stats

    def run(self, update_fn: Callable, n_clocks: int,
            timeout: float = 120.0) -> RunStats:
        """Run every worker for ``n_clocks`` periods (start + wait)."""
        self.start(update_fn, n_clocks, timeout=timeout)
        return self.wait()

    # ------------------------------------------------------------ worker flow
    def _worker_loop(self, w: int) -> None:
        proc = self.procs[self.proc_of(w)]
        rng = np.random.default_rng(self.seed * 7919 + w)
        try:
            for clock in range(self.n_clocks):
                self._clock_gate(w, clock, proc)
                view = RuntimeViewHandle(self, proc, w)
                upd = self.update_fn(w, clock, view, rng)
                items = [(k, np.asarray(d, dtype=np.float64))
                         for k, d in upd.items()]
                if self.prioritize:
                    items.sort(key=lambda kv: -float(np.max(np.abs(kv[1]))))
                outbox: List[Tuple[Channel, UpdateMsg]] = []
                for key, delta in items:
                    sends = self._apply_update(w, clock, proc, key, delta)
                    if self.policy.push_at_clock_only:
                        outbox.extend(sends)
                    else:
                        for chan, msg in sends:
                            self._send(chan, msg)
                self._on_clock(w, proc, outbox)
        except BaseException as e:
            self._record_error(e)

    def _clock_gate(self, w: int, clock: int, proc: ClientProcess) -> None:
        """Block until the delivery frontier admits this period (clock bound)."""
        if self.n_proc == 1 or not self.policy.clock_bounded:
            return
        need = clock - self.policy.staleness - 1
        if need < 0:
            return
        t0 = time.monotonic()
        blocked = False
        with proc.cond:
            while proc.frontier_min() < need:
                blocked = True
                self._check_alive()
                proc.cond.wait(0.25)
            if self.check:
                st = clock - proc.frontier_min() - 1
                with self._slock:
                    self.stats.max_observed_staleness = max(
                        self.stats.max_observed_staleness, st)
                    if st > self.policy.staleness:
                        self.stats.violations.append(
                            f"staleness violation: worker {w} clock {clock} "
                            f"observed {st}")
        if blocked:
            with self._slock:
                self.stats.block_time_clock += time.monotonic() - t0

    def _apply_update(self, w: int, clock: int, proc: ClientProcess,
                      key: str, delta: np.ndarray
                      ) -> List[Tuple[Channel, UpdateMsg]]:
        """Value-gate, apply to the process cache, split into shard parts."""
        d2 = (delta.reshape(delta.shape[0], -1) if delta.ndim > 1
              else delta.reshape(-1, 1))
        t0 = time.monotonic()
        blocked = False
        with proc.cond:
            while True:
                ok, _ = controller.value_gate(
                    self.policy, proc.unsynced[w][key], d2)
                if ok:
                    break
                blocked = True
                self._check_alive()
                proc.cond.wait(0.25)
            proc.cache[key] += d2                       # read-my-writes
            acc = proc.unsynced[w][key]
            acc += d2
            mag = float(np.max(np.abs(d2))) if d2.size else 0.0
            with self._slock:
                self.stats.n_updates += 1
                self.stats.max_update_mag = max(self.stats.max_update_mag, mag)
                self._total[key] += d2
                if blocked:
                    self.stats.block_time_value += time.monotonic() - t0
                if self.check and self.policy.value_bounded:
                    bound = controller.vap_unsynced_bound(
                        self.policy, self.stats.max_update_mag)
                    mx = float(np.max(np.abs(acc)))
                    self.stats.max_unsynced_mag = max(
                        self.stats.max_unsynced_mag, mx)
                    if mx > bound + 1e-9:
                        self.stats.violations.append(
                            f"VAP violation: worker {w} unsynced {mx} > {bound}")
        sends = []
        for s in range(self.n_shards):
            rows = self._shard_rows[key][s]
            if rows.size == 0:
                continue
            part = d2[rows]
            nz = np.any(part != 0.0, axis=1)
            if not nz.all():                            # elide all-zero rows
                rows, part = rows[nz], part[nz]
                if rows.size == 0:
                    continue
            msg = UpdateMsg(next(self._uid), w, proc.pid, clock, key,
                            rows, part.copy())
            sends.append((self._chan_ps[proc.pid][s], msg))
        return sends

    def _on_clock(self, w: int, proc: ClientProcess,
                  outbox: List[Tuple[Channel, UpdateMsg]]) -> None:
        """Clock(): flush the SSP outbox, tick, maybe advance the process."""
        for chan, msg in outbox:        # before the tick, matching the sim
            self._send(chan, msg)
        advanced: List[int] = []
        staged_acks: List[Tuple[Channel, AckMsg]] = []
        with proc.cond:
            proc.thread_clock[w] += 1
            new_min = proc.cur_period()     # process clock = min of threads
            while proc.sent_clock < new_min:
                advanced.append(proc.sent_clock)
                proc.sent_clock += 1
            if advanced and self.barrier_reads:
                staged_acks = proc.release_staged(new_min)
            proc.cond.notify_all()
        for c in advanced:
            for s in range(self.n_shards):
                self._send(self._chan_ps[proc.pid][s], ClockMsg(proc.pid, c))
        for chan, msg in staged_acks:
            self._send(chan, msg)
        if advanced:
            self._note_global_clock()

    def _note_global_clock(self) -> None:
        done = min(p.sent_clock for p in self.procs)
        with self._slock:
            while self._done_clock < done:
                self._done_clock += 1
                self.stats.clock_times.append(time.monotonic() - self._t0)

    @property
    def running(self) -> bool:
        """True while worker threads are still producing updates."""
        return (self._started and not self._finished
                and any(t.is_alive() for t in self._workers))

    # ------------------------------------------------------------- reads
    def read(self, key: str, process: int = 0) -> np.ndarray:
        """Serving read: a Get() against a live process cache."""
        proc = self.procs[process]
        with proc.cond:
            flat = proc.cache[key].copy()
        return flat.reshape(self._shapes[key])

    def master_value(self, key: str) -> np.ndarray:
        """Assemble the authoritative value from the shard tables.

        Only meaningful once the runtime is quiesced (after :meth:`wait`).
        """
        out = np.zeros_like(self._x0[key])
        for shard in self.shards:
            for rid, row in shard.rows_snapshot(key).items():
                out[rid] = row
        return out.reshape(self._shapes[key])

    def view(self, process: int) -> Dict[str, np.ndarray]:
        """A process cache as {key: array in the original shape}."""
        proc = self.procs[process]
        with proc.cond:
            return {k: v.copy().reshape(self._shapes[k])
                    for k, v in proc.cache.items()}

    # ------------------------------------------------------------- checks
    def _final_checks(self) -> None:
        """Eventual consistency: caches and master equal x0 + sum(updates)."""
        expected = {k: self._x0[k] + self._total[k] for k in self._x0}
        for p in range(self.n_proc):
            cache = self.procs[p].cache
            for k in self._x0:
                if not np.allclose(cache[k], expected[k], atol=1e-6):
                    self._violation(
                        f"eventual-consistency violation on {k} (process {p})")
        for k in self._x0:
            master = self.master_value(k).reshape(self._x0[k].shape)
            if not np.allclose(master, expected[k], atol=1e-6):
                self._violation(
                    f"eventual-consistency violation on {k} (shard tables)")
