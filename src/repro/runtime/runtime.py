"""Real asynchronous parameter server (DESIGN.md layer 1').

Where :mod:`repro.core.server` *simulates* the paper's bounded-asynchronous
semantics in a deterministic event loop, this module *implements* them with
actual concurrency, in the style of Petuum-PS:

  * N worker threads per client process share a **process cache**
    (read-my-writes: a worker's Incs are visible to its own process
    immediately);
  * **server shards** (one thread each) own hash-partitioned rows of the
    master state — row ``r`` of a key lives on shard ``r % n_shards`` — as
    dense numpy blocks applied with vectorized batch adds;
  * all edges are **FIFO per-channel queues** with sequence numbers the
    receivers assert in check mode;
  * the **Consistency Controller** (:mod:`repro.core.controller`, shared with
    the simulator) gates progress: the clock bound blocks a worker whose
    period would outrun the delivery frontier (BSP/SSP/CAP/ESSP/CVAP), the
    value bound blocks an Inc that would push the element-wise unsynchronized
    accumulator past ``max(u, v_thr)`` (VAP/CVAP), and the elastic bound
    blocks an Inc that would push the L2 norm of the worker's *whole*
    unsynchronized sum past ``max(‖u‖₂, B)`` (elastic, arXiv:2001.05918) —
    elastic accounting rides the same unsynced accumulators and
    FullyDelivered ack cycle as VAP;
  * within a period, updates are applied and sent **largest-magnitude first**
    (paper §4.2); BSP/SSP hold them in a per-worker outbox until Clock().

Transports (``transport=``):

  * ``"queue"`` (default) — every client process is a *thread group* inside
    this Python process and channels are in-process FIFO queues;
  * ``"tcp"`` / ``"shm"`` — every client process is a **forked OS process**
    and channels run over the real wire backends of
    :mod:`repro.runtime.transport` (loopback sockets / shared-memory rings),
    with per-row updates coalesced into multi-row frames.  Server shards
    live in the parent; workers escape the GIL entirely.  ``"proc"`` is an
    alias for the default multi-process backend (``shm``).

Multi-process quiesce replaces the in-flight counter: clients send
``ProcDone`` after their last clock, shards answer ``ShardFin`` once their
delivery state has drained, and each child then ships its final cache,
stats, and update totals to the parent over a pipe, where they are merged
and checked exactly like the threaded run.

**Elastic shard membership** (:mod:`repro.runtime.membership`): ``n_slots``
shard slots are provisioned up front (threads + channels under every
transport) with ``n_shards`` active in epoch 0; ``add_shard()`` /
``remove_shard()`` (or a scriptable ``MembershipPlan``) re-partition
**live** — an epoch barrier rides the existing FIFO channels, rows migrate
parent-side through the vc-stamped snapshot re-partition path, and the
clock/value bounds hold for accesses issued *during* the migration
(``tests/test_membership.py`` + the ``tests/chaos.py`` fault-injection
harness assert exactly that, plus a per-process zero-lost/zero-duplicated
update counter audit).

The simulator stays the executable specification: given the same
``update_fn`` both produce the same set of updates, so the quiesced runtime
state must equal the simulator's final state element-wise (updates are
additive and commutative).  ``tests/test_runtime_conformance.py`` asserts
exactly that — for the threaded *and* the multi-process runtime — plus the
clock/value invariants under free interleavings.

``barrier_reads`` (conformance mode, requires ``threads_per_process == 1``):
peer updates stamped with the reader's current period or later are staged and
applied only at the period boundary, so reads see *exactly* the updates the
consistency model guarantees and nothing fresher.  Under BSP this makes the
runtime bit-deterministic, which is what lets differential tests compare LDA
trajectories against the simulator and the SPMD sync layer.
"""
from __future__ import annotations

import itertools
import math
import multiprocessing
import os
import queue
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import controller
from repro.core.server import RunStats
from repro.runtime import trace as trace_mod
from repro.runtime import transport as T
from repro.runtime.config import (TRANSPORTS, RuntimeConfig,
                                  config_from_legacy)
from repro.runtime.membership import (INF_CLOCK, MembershipManager,
                                      Partition)
from repro.runtime.messages import (SHUTDOWN, AckBatchMsg, Channel,
                                    ClockMarker, ClockMsg, DeliverMsg,
                                    EpochAckMsg, EpochMsg, FullyDelivered,
                                    ProcDoneMsg, ShardFinMsg, UpdateMsg,
                                    group_by_channel, pump_inbox)
from repro.runtime.metrics import (LOAD_BLOCK_CLOCK, LOAD_BLOCK_VALUE,
                                   LOAD_LEN, LOAD_UPDATES, MetricsHub,
                                   RuntimeMetrics)
from repro.runtime.shard import ServerShard

_PROC_ALIAS = "shm"          # what transport="proc" resolves to


def _ack_batches(pairs: List[Tuple[Channel, int]], pid: int
                 ) -> List[Tuple[Channel, AckBatchMsg]]:
    """[(shard chan, uid), ...] -> one coalesced :class:`AckBatchMsg` per
    channel (VAP ack batching: a flush's acks share a single frame)."""
    return [(chan, AckBatchMsg(np.asarray(uids, dtype=np.int64), pid))
            for chan, uids in group_by_channel(pairs)]


def _unsynced_norm(unsynced: Dict[str, np.ndarray]) -> float:
    """L2 norm of one worker's whole unsynchronized accumulator set."""
    sq = sum(float(np.sum(v * v)) for v in unsynced.values())
    return math.sqrt(max(sq, 0.0))


def _elastic_norms(unsynced: Dict[str, np.ndarray], key: str,
                   d2: np.ndarray) -> Tuple[float, float]:
    """(‖unsynced‖₂ before, ‖unsynced‖₂ after applying d2 to key)."""
    sq = sum(float(np.sum(v * v)) for v in unsynced.values())
    cur = unsynced[key]
    new = cur + d2
    new_sq = sq - float(np.sum(cur * cur)) + float(np.sum(new * new))
    return math.sqrt(max(sq, 0.0)), math.sqrt(max(new_sq, 0.0))


class ClientProcess:
    """A client process: shared cache + comm thread for its worker threads.

    Identical in both regimes — under ``transport="queue"`` it lives in the
    main interpreter; under a wire transport it lives in a forked child and
    ``rt`` is the child's :class:`_ClientHost`.
    """

    def __init__(self, rt, pid: int):
        self.rt = rt
        self.pid = pid
        self.cond = threading.Condition()     # guards every field below
        self.cache: Dict[str, np.ndarray] = {k: v.copy()
                                             for k, v in rt._x0.items()}
        self.workers = list(range(pid * rt.tpp, (pid + 1) * rt.tpp))
        # per-worker element-wise unsynchronized accumulators
        self.unsynced: Dict[int, Dict[str, np.ndarray]] = {
            w: {k: np.zeros_like(v) for k, v in rt._x0.items()}
            for w in self.workers}
        self.thread_clock: Dict[int, int] = {w: 0 for w in self.workers}
        self.sent_clock = 0                   # completed periods announced
        # elastic membership: this process's routing epoch.  route_lock
        # excludes worker flushes during the barrier swap, making the
        # EpochAck that follows FIFO-after every old-epoch frame.
        self.part: Partition = rt.partition
        self.route_lock = threading.Lock()
        self._pending_epoch: Optional[EpochMsg] = None
        # marks[p, s]: highest period of process p fully forwarded by shard
        # slot s.  Inactive slots sit at INF (they constrain nothing); a
        # slot (re)activated at epoch e resets to -1 until its seeded
        # markers land; a retiring slot is lifted to INF by the marker it
        # sends FIFO-behind its last delivery.
        self.marks = np.full((rt.n_proc, rt.n_slots), INF_CLOCK,
                             dtype=np.int64)
        self.marks[:, list(self.part.active)] = -1
        # epoch at which each slot was last activated: stale markers from a
        # slot's previous activation are filtered by this
        self.act_epoch = np.zeros(rt.n_slots, dtype=np.int64)
        self.staged: List[DeliverMsg] = []    # barrier_reads holding pen
        # load counters (repro.runtime.metrics): bumped under locks the hot
        # paths already hold (no new synchronization), snapshotted at clock
        # boundaries and piggybacked on the outgoing ClockMsg
        self.m_updates = 0
        self.m_block_clock = 0.0
        self.m_block_value = 0.0
        self.inbox: queue.Queue = queue.Queue()
        self._fifo = T.FifoAssert()           # per sender shard
        self._acks: List[Tuple[Channel, int]] = []      # (shard chan, uid)
        self.thread = threading.Thread(
            target=self._loop, name=f"ps-proc-{pid}", daemon=True)

    # ---------------------------------------------------------------- frontier
    def frontier_min(self) -> int:
        """Lowest period every peer process is known-delivered through."""
        peers = [p for p in range(self.rt.n_proc) if p != self.pid]
        if not peers:
            return 1 << 60
        return int(self.marks[peers, :].min())

    def cur_period(self) -> int:
        return min(self.thread_clock.values())

    # ---------------------------------------------------------------- comm
    def _loop(self) -> None:
        pump_inbox(self.inbox, self._handle_batch)

    def _handle_batch(self, batch: list) -> bool:
        rt = self.rt
        shutdown = False
        done = 0
        with self.cond:
            for msg in batch:
                if msg is SHUTDOWN:
                    shutdown = True
                    break
                done += 1
                try:
                    self._handle(msg)
                except BaseException as e:
                    rt._record_error(e)
            self.cond.notify_all()
        # zero-copy discipline: every view-backed delivery was either
        # applied above or materialized into `staged`, so drop the frame
        # pins NOW — before the blocking ack sends below.  Holding pins
        # across a wire write could deadlock two full rings against each
        # other (the shard may be blocked writing into our ring, waiting
        # for exactly this release to free its own inbound ring).
        T.release_msgs(batch)
        # the epoch swap runs outside self.cond (it takes route_lock, and
        # cond must never be held while waiting on it) but still on the
        # comm thread, so it can never deadlock against a gated worker
        pend, self._pending_epoch = self._pending_epoch, None
        if pend is not None:
            self._adopt_epoch(pend)
        # acks leave after the lock is dropped, coalesced into ONE AckBatch
        # message per (client, shard, flush) — the uids travel as a single
        # int64 buffer instead of one AckMsg per delivered part
        acks, self._acks = self._acks, []
        for chan, batch in _ack_batches(acks, self.pid):
            rt._send(chan, batch)
        # in-flight decrements strictly after the acks were enqueued, so the
        # quiesce wait never observes a transient 0 mid-conversation
        for _ in range(done):
            rt._msg_done()
        return shutdown

    def _handle(self, msg) -> None:
        """Process one message.  Caller holds ``self.cond``."""
        rt = self.rt
        if rt.check:
            err = self._fifo.check(msg.shard, msg.seq)
            if err:
                rt._violation(f"FIFO violation: shard {msg.shard}->proc "
                              f"{self.pid} {err}")
        if isinstance(msg, DeliverMsg):
            if rt.barrier_reads and msg.ts >= self.cur_period():
                # retained past this apply cycle: copy out of the ring
                self.staged.append(T.materialize_msg(msg))
            else:
                self._apply_delivery(msg)
                # acks only feed the unsynced accounting (VAP value bound /
                # elastic norm bound); clock-only policies skip the cycle
                if rt.policy.tracks_sync:
                    self._acks.append(
                        (rt._chan_ps[self.pid][msg.shard], msg.uid))
        elif isinstance(msg, ClockMarker):
            # max(): the frontier may never regress (channel FIFO already
            # orders markers per (proc, shard); this makes it local).  A
            # marker stamped before the slot's latest activation is stale —
            # it predates the re-partition and must not lift the reset mark.
            if msg.epoch >= self.act_epoch[msg.shard]:
                self.marks[msg.process, msg.shard] = max(
                    self.marks[msg.process, msg.shard], msg.clock)
        elif isinstance(msg, EpochMsg):
            self._pending_epoch = msg         # adopted after this batch
        elif isinstance(msg, FullyDelivered):
            # exact subtraction, mirroring the simulator's VAP accounting
            # (core/server.py _on_deliver): the accumulator received exactly
            # msg.delta when the update applied, so subtracting it back is
            # exact — the old sub-1e-12 snap discarded legitimately in-flight
            # tiny deltas (see test_runtime_conformance sub-epsilon test).
            # The value/strong gates carry their own > 1e-12 dead zone, so
            # float residue from *other* orderings never wedges a worker.
            acc = self.unsynced[msg.worker][msg.key]
            acc[msg.rows] -= msg.delta
        elif isinstance(msg, ShardFinMsg):
            rt._on_shard_fin(msg)
        else:
            raise TypeError(f"proc {self.pid}: unexpected message {msg!r}")

    def _apply_delivery(self, msg: DeliverMsg) -> None:
        self.cache[msg.key][msg.rows] += msg.delta

    def release_staged(self, new_period: int
                       ) -> List[Tuple[Channel, AckBatchMsg]]:
        """Apply staged deliveries now inside the staleness window.

        Caller holds ``self.cond`` (the ticking worker, at a period
        boundary).  Returns coalesced ack batches (one per shard channel)
        to send after the lock is dropped.
        """
        acks, keep = [], []
        for msg in self.staged:
            if msg.ts < new_period:
                self._apply_delivery(msg)
                if self.rt.policy.tracks_sync:
                    acks.append((self.rt._chan_ps[self.pid][msg.shard],
                                 msg.uid))
            else:
                keep.append(msg)
        self.staged = keep
        return _ack_batches(acks, self.pid)

    # ------------------------------------------------------------ membership
    def _adopt_epoch(self, msg: EpochMsg) -> None:
        """Swap the key->shard router at the epoch barrier.

        Runs on the comm thread, outside ``self.cond``.  ``route_lock``
        excludes in-flight worker flushes, so after the swap no old-epoch
        frame can be emitted — which makes the EpochAckMsg sent below a
        true barrier on every channel (FIFO-after the last old-epoch
        Update/Clock).  New-epoch frames may precede the ack; receivers
        hold them by their epoch stamp, not by ack order.
        """
        rt = self.rt
        with self.route_lock:
            old = self.part
            if msg.epoch <= old.epoch:
                return                        # duplicate announce
            new_part = Partition(msg.epoch, msg.active, rt._row_counts)
            with self.cond:
                for sid in new_part.active:
                    if not old.owns(sid):     # (re)activated slot: it now
                        self.marks[:, sid] = -1   # constrains the frontier
                        self.act_epoch[sid] = msg.epoch
            self.part = new_part
        for sid in sorted(set(old.active) | set(new_part.active)):
            rt._send(rt._chan_ps[self.pid][sid],
                     EpochAckMsg(self.pid, msg.epoch))


class RuntimeViewHandle:
    """Read API handed to update_fn — mirrors the simulator's ViewHandle."""

    def __init__(self, rt, proc: ClientProcess, worker: int):
        self._rt = rt
        self._proc = proc
        self.worker = worker
        self.gets = 0

    def get(self, key: str) -> np.ndarray:
        self.gets += 1
        with self._proc.cond:
            flat = self._proc.cache[key].copy()
        return flat.reshape(self._rt._shapes[key])

    def keys(self) -> Sequence[str]:
        return list(self._rt._x0.keys())


class _WorkerFlowMixin:
    """The client-side worker flow, shared by the in-process runtime
    (:class:`PSRuntime`, transport="queue") and the forked per-process host
    (:class:`_ClientHost`, wire transports).  Subclasses provide the state
    surface: ``procs``, ``policy``, ``stats``, ``_slock``, ``_total``,
    ``_chan_ps``, ``_send``/``_send_many``/``_msg_done``, ``_next_uid``,
    ``_check_alive``, ``_violation``, ``_record_error``,
    ``_note_global_clock`` and the sizing/config attributes.
    """

    # ------------------------------------------------------------ worker flow
    def _worker_loop(self, w: int) -> None:
        proc = self.procs[self.proc_of(w)]
        rng = np.random.default_rng(self.seed * 7919 + w)
        try:
            for clock in range(self.n_clocks):
                self._clock_gate(w, clock, proc)
                view = RuntimeViewHandle(self, proc, w)
                upd = self.update_fn(w, clock, view, rng)
                items = [(k, np.asarray(d, dtype=np.float64))
                         for k, d in upd.items()]
                if self.prioritize and len(items) > 1:
                    # one magnitude pass per flush, then a stable descending
                    # argsort (identical order to the former per-item
                    # Python sort key, including ties) — this numpy path is
                    # also the reference for kernels/topk_mag
                    mags = np.fromiter(
                        (np.abs(d).max() if d.size else 0.0
                         for _, d in items),
                        dtype=np.float64, count=len(items))
                    items = [items[int(i)]
                             for i in self._magnitude_order(mags)]
                outbox: List[Tuple[str, np.ndarray]] = []
                for key, delta in items:
                    d2 = self._apply_update(w, clock, proc, key, delta)
                    if self.policy.norm_bounded:
                        # elastic gates on the WHOLE accumulator: a delta
                        # parked in a per-period outbox could never be
                        # acknowledged and would wedge the gate on the next
                        # key.  Send per Inc, like the simulator does.
                        self._flush_outbox(w, clock, proc, [(key, d2)])
                    else:
                        outbox.append((key, d2))
                if not self.policy.push_at_clock_only:
                    # async policies push without waiting for Clock(): one
                    # coalesced multi-row frame per shard channel per period
                    # (PR 1 pushed per Inc; the update *set* and all bounds
                    # are unchanged, only send timing within a period)
                    self._flush_outbox(w, clock, proc, outbox)
                    outbox = []
                self._on_clock(w, clock, proc, outbox)
        except BaseException as e:
            self._record_error(e)

    def _magnitude_order(self, mags: np.ndarray) -> np.ndarray:
        """Largest-|Δ|-first send order (paper §4.2).  Stable on ties, so
        the kernel and numpy paths agree with the former Python sort."""
        if getattr(self, "ps_kernels", False):
            from repro.kernels.topk_mag import ops as topk_ops
            return topk_ops.magnitude_order(mags)
        return np.argsort(-mags, kind="stable")

    def _flush_outbox(self, w: int, clock: int, proc: ClientProcess,
                      outbox: List[Tuple[str, np.ndarray]]) -> None:
        """Split each update by the process's *current* partition and send,
        one frame per shard channel, FIFO preserved.

        Routing is deferred from Inc time to flush time on purpose: an SSP
        outbox filled under epoch e but flushed after the comm thread's
        barrier swap must route by e+1, or the old owner would receive an
        update after its EpochAck cut and lose it in the handoff.  The
        route_lock critical section is pure split+enqueue — it never waits
        on a consistency gate, so the swap can always get in promptly.
        """
        if not outbox:
            return
        trc = self._trace if self.trace_on else None
        t0_ns = time.monotonic_ns() if trc is not None else 0
        n_parts = 0
        with proc.route_lock:
            part = proc.part
            pairs: List[Tuple[Channel, UpdateMsg]] = []
            for key, d2 in outbox:
                for sid in part.active:
                    rows = part.rows_of(key, sid)
                    if rows.size == 0:
                        continue
                    sub = d2[rows]
                    nz = np.any(sub != 0.0, axis=1)
                    if not nz.all():                 # elide all-zero rows
                        rows, sub = rows[nz], sub[nz]
                        if rows.size == 0:
                            continue
                    msg = UpdateMsg(self._next_uid(), w, proc.pid, clock,
                                    key, rows, sub, part.epoch)
                    pairs.append((self._chan_ps[proc.pid][sid], msg))
                    n_parts += 1
                    if trc is not None and trc.sampled(msg.uid):
                        # lifeline start: joined to the shard's apply_part
                        # on (proc, uid), which the wire already carries
                        trc.point(trace_mod.EV_SEND, proc.pid, msg.uid, key)
            for chan, msgs in group_by_channel(pairs):
                self._send_many(chan, msgs)
        if n_parts:
            with self._slock:
                self._parts_sent[proc.pid] += n_parts
            if trc is not None:
                trc.span(trace_mod.EV_FLUSH, t0_ns, proc.pid, clock, n_parts)

    def _clock_gate(self, w: int, clock: int, proc: ClientProcess) -> None:
        """Block until the delivery frontier admits this period (clock bound)."""
        if self.n_proc == 1 or not self.policy.clock_bounded:
            return
        need = clock - self.policy.staleness - 1
        if need < 0:
            return
        t0 = time.monotonic()
        blocked = False
        strag = -1
        with proc.cond:
            while proc.frontier_min() < need:
                blocked = True
                if self.trace_on:
                    # who is holding the frontier right now?  the peer whose
                    # slowest slot mark is lowest (recomputed each wait lap,
                    # so the span blames the last straggler observed)
                    peers = [p for p in range(self.n_proc) if p != proc.pid]
                    strag = peers[int(proc.marks[peers, :]
                                      .min(axis=1).argmin())]
                self._check_alive()
                proc.cond.wait(0.25)
            if self.check:
                st = clock - proc.frontier_min() - 1
                with self._slock:
                    self.stats.max_observed_staleness = max(
                        self.stats.max_observed_staleness, st)
                    if st > self.policy.staleness:
                        self.stats.violations.append(
                            f"staleness violation: worker {w} clock {clock} "
                            f"observed {st}")
        if blocked:
            dt = time.monotonic() - t0
            with self._slock:
                self.stats.block_time_clock += dt
                proc.m_block_clock += dt
            if self.trace_on:
                self._trace.span(trace_mod.EV_BLOCK_CLOCK, int(t0 * 1e9),
                                 proc.pid, w, strag)

    def _apply_update(self, w: int, clock: int, proc: ClientProcess,
                      key: str, delta: np.ndarray) -> np.ndarray:
        """Value-gate and apply to the process cache; returns the canonical
        (R, C) delta for the flush-time shard split."""
        d2 = (delta.reshape(delta.shape[0], -1) if delta.ndim > 1
              else delta.reshape(-1, 1))
        t0 = time.monotonic()
        blocked = False
        with proc.cond:
            while True:
                ok, _ = controller.value_gate(
                    self.policy, proc.unsynced[w][key], d2)
                if ok and self.policy.norm_bounded:
                    # elastic: one bound on the whole accumulator's L2 norm,
                    # re-evaluated as FullyDelivered echoes shrink it
                    acc_n, new_n = _elastic_norms(proc.unsynced[w], key, d2)
                    ok = controller.elastic_gate(self.policy, acc_n, new_n)
                if ok:
                    break
                blocked = True
                self._check_alive()
                proc.cond.wait(0.25)
            proc.cache[key] += d2                       # read-my-writes
            acc = proc.unsynced[w][key]
            acc += d2
            mag = float(np.max(np.abs(d2))) if d2.size else 0.0
            proc.m_updates += 1                         # (under proc.cond)
            with self._slock:
                self.stats.n_updates += 1
                self.stats.max_update_mag = max(self.stats.max_update_mag, mag)
                self._total[key] += d2
                if blocked:
                    dt = time.monotonic() - t0
                    self.stats.block_time_value += dt
                    proc.m_block_value += dt
                if self.check and self.policy.value_bounded:
                    bound = controller.vap_unsynced_bound(
                        self.policy, self.stats.max_update_mag)
                    mx = float(np.max(np.abs(acc)))
                    self.stats.max_unsynced_mag = max(
                        self.stats.max_unsynced_mag, mx)
                    if mx > bound + 1e-9:
                        self.stats.violations.append(
                            f"VAP violation: worker {w} unsynced {mx} > {bound}")
                if self.policy.norm_bounded:
                    dn = float(np.linalg.norm(d2)) if d2.size else 0.0
                    self.stats.max_update_norm = max(
                        self.stats.max_update_norm, dn)
                    if self.check:
                        un = _unsynced_norm(proc.unsynced[w])
                        self.stats.max_unsynced_norm = max(
                            self.stats.max_unsynced_norm, un)
                        nb = controller.elastic_unsynced_bound(
                            self.policy, self.stats.max_update_norm)
                        if un > nb + 1e-9:
                            self.stats.violations.append(
                                f"elastic violation: worker {w} unsynced "
                                f"norm {un} > {nb}")
        if blocked and self.trace_on:
            self._trace.span(trace_mod.EV_BLOCK_VALUE, int(t0 * 1e9),
                             proc.pid, w, clock)
        return d2

    def _on_clock(self, w: int, clock: int, proc: ClientProcess,
                  outbox: List[Tuple[str, np.ndarray]]) -> None:
        """Clock(): flush the SSP outbox, tick, maybe advance the process."""
        # held updates must hit the channels *before* the tick (matching the
        # sim): a sibling worker's tick may advance the process clock, and
        # its ClockMsg for this period must be FIFO-after these updates —
        # the shard's marker echo relies on exactly that channel order
        self._flush_outbox(w, clock, proc, outbox)
        advanced: List[int] = []
        staged_acks: List[Tuple[Channel, AckBatchMsg]] = []
        with proc.cond:
            proc.thread_clock[w] += 1
            new_min = proc.cur_period()     # process clock = min of threads
            while proc.sent_clock < new_min:
                advanced.append(proc.sent_clock)
                proc.sent_clock += 1
            if advanced and self.barrier_reads:
                staged_acks = proc.release_staged(new_min)
            proc.cond.notify_all()
        if advanced:
            # metrics piggyback: snapshot this process's load counters at
            # the boundary and ride them on the ClockMsg it already sends
            # (one tiny float64 array; control frames are pickled on every
            # wire).  Racy counter reads only wobble a rate estimate.
            load = None
            if self.metrics_on:
                load = np.zeros(LOAD_LEN, dtype=np.float64)
                load[LOAD_UPDATES] = proc.m_updates
                load[LOAD_BLOCK_CLOCK] = proc.m_block_clock
                load[LOAD_BLOCK_VALUE] = proc.m_block_value
            if self.trace_on:
                for c in advanced:
                    self._trace.point(trace_mod.EV_CLOCK, proc.pid, c)
            # ClockMsg routes by the current partition too; if the epoch
            # swapped between the update flush above and here, the old
            # owner's missing clock only *under*-states its applied vc
            # (conservative), and the new owner holds the early clock by
            # its epoch stamp until install
            with proc.route_lock:
                part = proc.part
                pairs = [(self._chan_ps[proc.pid][sid],
                          ClockMsg(proc.pid, c, part.epoch, load))
                         for c in advanced for sid in part.active]
                for chan, msgs in group_by_channel(pairs):
                    self._send_many(chan, msgs)
        for chan, msg in staged_acks:
            self._send(chan, msg)
        if advanced:
            self._note_global_clock()


class PSRuntime(_WorkerFlowMixin):
    """The concurrent asynchronous parameter server.

    Drop-in counterpart of :class:`repro.core.server.AsyncPS` — same
    ``update_fn(worker, clock, view, rng)`` contract, same per-worker rng
    seeding, same :class:`RunStats` — but wall-clock concurrent instead of
    simulated.  ``NetworkModel`` / ``compute_time`` / ``straggler`` have no
    analogue here: latency and skew are real.

    ``transport="queue"`` runs worker *threads* in this process;
    ``"tcp"``/``"shm"``/``"proc"`` fork one OS process per client process
    and carry the same message protocol over the wire (see module docstring).

    Construction goes through :class:`repro.runtime.config.RuntimeConfig`
    (``PSRuntime(config)``); the legacy kwarg surface
    (``PSRuntime(n_workers, policy, x0, ...)``) is a deprecation shim that
    builds the config for you and warns.
    """

    def __init__(self, config: Optional[RuntimeConfig] = None,
                 *args, **kwargs):
        if isinstance(config, RuntimeConfig):
            if args or kwargs:
                raise TypeError("PSRuntime(config) takes no further "
                                "arguments — put them on the RuntimeConfig")
            cfg = config
        else:
            warnings.warn(
                "PSRuntime(n_workers, policy, ...) is deprecated; build a "
                "repro.runtime.RuntimeConfig and pass PSRuntime(config)",
                DeprecationWarning, stacklevel=2)
            legacy = () if config is None else (config,)
            cfg = config_from_legacy(*legacy, *args, **kwargs)
        self.config = cfg
        # validation already ran in RuntimeConfig.__post_init__
        self.transport_kind = (_PROC_ALIAS if cfg.transport == "proc"
                               else cfg.transport)
        self._proc_mode = self.transport_kind != "queue"
        self.P = cfg.n_workers
        self.tpp = cfg.threads_per_process
        self.n_proc = cfg.n_workers // cfg.threads_per_process
        self.n_shards = cfg.n_shards          # initial active count
        # elastic membership: n_slots shard slots are provisioned (threads +
        # channels for every transport, so forked clients inherit the wires)
        # but only n_shards are active in epoch 0; add_shard()/remove_shard()
        # re-partition live (repro.runtime.membership)
        self.n_slots = (cfg.n_shards if cfg.max_shards is None
                        else int(cfg.max_shards))
        self.policy = cfg.policy
        self.seed = cfg.seed
        self.prioritize = cfg.prioritize_by_magnitude
        self.check = cfg.check_invariants
        self.barrier_reads = cfg.barrier_reads
        # zero_copy: raw RowCodec frames + in-ring view decode on the shm
        # transport (None -> on; other transports ignore it).  ps_kernels:
        # route the dense-block apply and the magnitude ordering through
        # repro.kernels.{ps_apply,topk_mag} (numpy dispatch when Pallas is
        # off, so flipping the flag on a CPU host changes nothing bitwise).
        self.zero_copy = True if cfg.zero_copy is None else bool(cfg.zero_copy)
        self.ps_kernels = bool(cfg.ps_kernels)
        self.metrics_on = bool(cfg.metrics)
        # tracing tier (repro.runtime.trace): one hub for the parent (server
        # shards + queue-mode workers); forked clients build their own hub
        # post-fork and ship their rings back in the quiesce payload
        self._trace_cfg = trace_mod.normalize_trace(cfg.trace)
        self.trace_on = self._trace_cfg is not None
        self._trace = (trace_mod.TraceHub(self._trace_cfg, "server")
                       if self.trace_on else None)

        # canonical (R, C) float64 master shapes; original shapes for reads
        self._shapes: Dict[str, Tuple[int, ...]] = {}
        self._x0: Dict[str, np.ndarray] = {}
        self._row_counts: Dict[str, int] = {}
        for key, v in cfg.init_params.items():
            a = np.asarray(v, dtype=np.float64)
            self._shapes[key] = a.shape
            flat = a.reshape(a.shape[0], -1) if a.ndim > 1 else a.reshape(-1, 1)
            self._x0[key] = flat.copy()
            self._row_counts[key] = flat.shape[0]
        self.partition = Partition(0, tuple(range(cfg.n_shards)),
                                   self._row_counts)
        # upper bound on one shard's in-stream bootstrap frame (publish
        # backpressure: gate resync attempts on sink room)
        self._state_frame_bytes = sum(
            v.nbytes + 8 * v.shape[0] for v in self._x0.values()) + 4096

        self.stats = RunStats()
        self._slock = threading.Lock()
        self._total = {k: np.zeros_like(v) for k, v in self._x0.items()}
        # zero-lost/zero-duplicated audit: update parts sent, per process
        # (matched against the shards' applied_parts at the final checks)
        self._parts_sent = np.zeros(self.n_proc, dtype=np.int64)
        self._uid = itertools.count()
        self._done_clock = 0
        self._t0 = 0.0
        self._deadline = float("inf")
        self._errors: List[BaseException] = []
        self._qcond = threading.Condition()   # guards _inflight (queue mode)
        self._inflight = 0

        # mid-run periodic snapshots: taken by the shard thread that moves
        # the applied frontier across a multiple of `snapshot_every` clocks
        self.snapshot_every = cfg.snapshot_every
        self.snapshot_dir = cfg.snapshot_dir
        self.snapshots: List[Tuple[int, dict]] = []
        self._snap_lock = threading.Lock()
        self._next_snap_clock = (cfg.snapshot_every if cfg.snapshot_every
                                 else (1 << 62))
        self.snapshot_keep_last = cfg.snapshot_keep_last

        # durability tier (repro.runtime.wal): per-shard write-ahead delta
        # log, group-committed at clock boundaries by the shard threads.
        # The codec is the PR-6 raw wire codec over the same key order the
        # shm transport uses, so one format serves publish, migration, and
        # disk; recovery rebuilds it from init_params the same way.
        self.wal_dir = cfg.wal_dir
        self.wal_fsync = cfg.wal_fsync or "none"
        self.wal_segment_bytes = cfg.wal_segment_bytes
        self._wal_epoch_marks: List[Tuple[int, dict]] = []
        if cfg.wal_dir:
            from repro.runtime.wal import WalWriter  # noqa: F401 (import check)
            os.makedirs(cfg.wal_dir, exist_ok=True)
            self._wal_codec = T.RowCodec(list(self._x0.keys()))
        else:
            self._wal_codec = None

        self.shards = [ServerShard(self, s) for s in range(self.n_slots)]
        self.membership = MembershipManager(self)
        self._membership_plan = cfg.membership_plan
        # unified metrics (repro.runtime.metrics): serving-tier objects
        # register here so rt.metrics() can fold them in
        self._metrics_hub = MetricsHub(self)
        self._gateways: List[object] = []
        self._replica_sets: List[object] = []
        if cfg.restore_from is not None:
            from repro.runtime.snapshot import restore_into
            restore_into(self, cfg.restore_from)
        if self._proc_mode:
            self.procs: List[ClientProcess] = []
            self._chan_ps = None              # lives in the children
            self._chan_sp: List[List] = []    # wire channels, built in start()
            self._children: List[multiprocessing.Process] = []
            self._pipes: List = []
            self._readers: List[threading.Thread] = []
            self._transport = None
            self._final_caches: Dict[int, Dict[str, np.ndarray]] = {}
        else:
            self.procs = [ClientProcess(self, p) for p in range(self.n_proc)]
            # FIFO channels: client process -> shard slot, and back
            self._chan_ps = [[Channel(f"p{p}->s{s}", self.shards[s].inbox)
                              for s in range(self.n_slots)]
                             for p in range(self.n_proc)]
            self._chan_sp = [[Channel(f"s{s}->p{p}", self.procs[p].inbox)
                              for p in range(self.n_proc)]
                             for s in range(self.n_slots)]

        self.update_fn: Optional[Callable] = None
        self.n_clocks = 0
        self._workers: List[threading.Thread] = []
        self._started = False
        self._finished = False

    # ------------------------------------------------------------- plumbing
    def proc_of(self, worker: int) -> int:
        return worker // self.tpp

    def _make_wal(self, sid: int):
        """Per-shard :class:`~repro.runtime.wal.WalWriter`, or None when the
        durability tier is off (called once per slot by ServerShard)."""
        if not self.wal_dir:
            return None
        from repro.runtime.wal import WalWriter
        return WalWriter(self.wal_dir, sid, self._wal_codec, self.n_proc,
                         fsync=self.wal_fsync,
                         segment_bytes=self.wal_segment_bytes)

    def _close_wals(self) -> None:
        """Seal every shard's WAL at clean teardown (shard threads are
        joined, so the final vc/state are quiescent).  A crash path never
        gets here by design: it leaves an unsealed/torn tail, which
        :func:`repro.runtime.wal.read_segment` recovers to the last
        complete record."""
        for s in self.shards:
            if s.wal is not None:
                s.wal.seal(s.clock_vc)

    def _wal_on_epoch(self, epoch: int, added, removed) -> None:
        """Membership hook: record each epoch cut's per-slot log positions
        (the kill-epoch bookmark point-in-time tooling starts from).  The
        sealing itself happens shard-side in ``_maybe_cut`` — a retiring
        slot seals its segment at the cut, stamped with its final vc."""
        if not self.wal_dir:
            return
        marks = {s.sid: s.wal.marks() for s in self.shards
                 if s.wal is not None}
        self._wal_epoch_marks.append((epoch, marks))

    def _next_uid(self) -> int:
        return next(self._uid)

    def _send(self, chan, msg) -> None:
        if not self._proc_mode:
            with self._qcond:
                self._inflight += 1
        chan.send(msg)

    def _send_many(self, chan, msgs: list) -> None:
        if not msgs:
            return
        if not self._proc_mode:
            with self._qcond:
                self._inflight += len(msgs)
        chan.send_many(msgs)

    def _msg_done(self) -> None:
        if self._proc_mode:
            return
        with self._qcond:
            self._inflight -= 1
            if self._inflight == 0:
                self._qcond.notify_all()

    def _violation(self, text: str) -> None:
        with self._slock:
            self.stats.violations.append(text)

    def _record_error(self, e: BaseException) -> None:
        with self._slock:
            self._errors.append(e)

    def _check_alive(self) -> None:
        if time.monotonic() > self._deadline:
            raise RuntimeError(
                "runtime deadlock: wall-clock deadline exceeded "
                f"(inflight={self._inflight})")
        if self._errors:
            raise RuntimeError("runtime aborted: peer thread failed")

    # ---------------------------------------------------------------- running
    def start(self, update_fn: Callable, n_clocks: int,
              timeout: float = 120.0) -> None:
        """Launch shard/comm/worker threads (and, under a wire transport,
        the client OS processes); pair with :meth:`wait`."""
        if self._started:
            raise RuntimeError("runtime already started")
        self._started = True
        self.update_fn = update_fn
        self.n_clocks = n_clocks
        self._deadline = time.monotonic() + timeout
        self._t0 = time.monotonic()
        if self._proc_mode:
            self._start_proc()
            if self._membership_plan is not None:
                self.membership.start_plan(self._membership_plan)
            return
        for s in self.shards:
            s.thread.start()
        for p in self.procs:
            p.thread.start()
        self._workers = [threading.Thread(target=self._worker_loop, args=(w,),
                                          name=f"ps-worker-{w}", daemon=True)
                         for w in range(self.P)]
        for t in self._workers:
            t.start()
        if self._membership_plan is not None:
            self.membership.start_plan(self._membership_plan)

    # ------------------------------------------------------- proc-mode start
    def _start_proc(self) -> None:
        ctx = multiprocessing.get_context("fork")
        if self.transport_kind == "tcp":
            self._transport = T.TcpTransport(self.n_proc, self.n_slots)
            self._transport.listen()
        else:
            # ring must hold the largest possible single row part (a whole
            # key) with frame overhead; batches above half the ring split
            # into multiple frames (WireChannel max_frame)
            max_part = max(v.nbytes + 8 * v.shape[0] + 4096
                           for v in self._x0.values())
            cap = max(1 << 20, 8 * max_part)
            self._shm_max_frame = cap // 2
            self._transport = T.ShmTransport(self.n_proc, self.n_slots,
                                             capacity=cap)
        for pid in range(self.n_proc):
            recv, send = ctx.Pipe(duplex=False)
            with warnings.catch_warnings():
                # jax registers an at-fork warning about its worker threads;
                # the children never touch jax (numpy-only worker flow)
                warnings.simplefilter("ignore", RuntimeWarning)
                child = ctx.Process(target=_client_child_main,
                                    args=(self, pid, send),
                                    name=f"ps-client-{pid}", daemon=True)
                child.start()
            send.close()                       # parent keeps the read end
            self._children.append(child)
            self._pipes.append(recv)

        def on_reader_error(e: BaseException) -> None:
            self._record_error(e)

        # parent side: route each client->shard stream into the shard inbox,
        # hand each shard slot a write channel back to every client
        self._chan_sp = [[None] * self.n_proc for _ in range(self.n_slots)]
        if self.transport_kind == "tcp":
            conns = self._transport.accept_all(self._deadline)
            self._conns = conns
            for (p, s), conn in conns.items():
                self._chan_sp[s][p] = T.WireChannel(f"s{s}->p{p}", conn.write,
                                                    trace=self._trace)
                self._readers.append(T.start_reader(
                    f"rx-p{p}s{s}", conn.read_chunk, self.shards[s].inbox,
                    on_reader_error, trace=self._trace))
        else:
            self._reader_stop = threading.Event()
            codec = T.RowCodec(list(self._x0.keys())) if self.zero_copy \
                else None
            for (p, s), edge in self._transport.edges.items():
                if codec is not None:
                    # zero-copy wire: raw row-block frames, doorbell batched
                    # to one wake per flush (per frame only when a batch
                    # splits), and an in-ring view reader on the receive side
                    bell_w = edge.s2c_bell[1]
                    self._chan_sp[s][p] = T.WireChannel(
                        f"s{s}->p{p}",
                        T.ring_parts_writer(edge.s2c, self._deadline),
                        max_frame=self._shm_max_frame, codec=codec,
                        on_flush=lambda w=bell_w: T.ShmEdge.ring_bell(w),
                        trace=self._trace)
                    self._readers.append(T.start_view_reader(
                        f"rx-p{p}s{s}",
                        T.RingViewReader(edge.c2s, codec, edge.c2s_bell[0],
                                         self._reader_stop,
                                         trace=self._trace),
                        self.shards[s].inbox, on_reader_error))
                else:
                    self._chan_sp[s][p] = T.WireChannel(
                        f"s{s}->p{p}",
                        T.ring_writer(edge.s2c, edge.s2c_bell[1],
                                      self._deadline),
                        max_frame=self._shm_max_frame, trace=self._trace)
                    self._readers.append(T.start_reader(
                        f"rx-p{p}s{s}",
                        T.ring_reader(edge.c2s, edge.c2s_bell[0],
                                      self._reader_stop),
                        self.shards[s].inbox, on_reader_error,
                        trace=self._trace))
        for s in self.shards:
            s.thread.start()

    def wait(self) -> RunStats:
        """Join workers, quiesce all in-flight messages, run final checks."""
        if not self._started or self._finished:
            raise RuntimeError("runtime not running")
        if self._proc_mode:
            return self._wait_proc()
        for t in self._workers:
            while t.is_alive():
                t.join(timeout=0.5)
                if time.monotonic() > self._deadline:
                    self._record_error(RuntimeError(
                        f"worker {t.name} still alive at deadline"))
                    break
        # a scripted membership op may still be installing: let it finish
        # before draining (its messages are in-flight-counted like any other)
        self.membership.finish_plan(self._deadline - time.monotonic())
        if not self._errors:
            with self._qcond:
                while self._inflight > 0:
                    if time.monotonic() > self._deadline:
                        self._record_error(RuntimeError(
                            f"quiesce timed out ({self._inflight} in flight)"))
                        break
                    self._qcond.wait(0.25)
        self._finished = True
        for p in self.procs:
            p.inbox.put(SHUTDOWN)
        for s in self.shards:
            s.inbox.put(SHUTDOWN)
        for th in [p.thread for p in self.procs] + [s.thread for s in self.shards]:
            th.join(timeout=5.0)
        self._close_wals()
        self.stats.sim_time = time.monotonic() - self._t0
        if self._errors:
            raise RuntimeError(
                f"runtime failed: {self._errors[0]!r}") from self._errors[0]
        if self.check:
            self._final_checks()
        return self.stats

    # -------------------------------------------------------- proc-mode wait
    def _wait_proc(self) -> RunStats:
        finals: Dict[int, dict] = {}
        try:
            for pid, pipe in enumerate(self._pipes):
                budget = max(0.1, self._deadline - time.monotonic())
                if pipe.poll(budget):
                    try:
                        finals[pid] = pipe.recv()
                    except EOFError:
                        pass
            for child in self._children:
                child.join(timeout=max(0.1, self._deadline - time.monotonic()))
                if child.is_alive():
                    child.terminate()
                    child.join(timeout=5.0)
                    self._record_error(RuntimeError(
                        f"client process {child.name} killed at deadline"))
            self.membership.finish_plan(self._deadline - time.monotonic())
            for pid, child in enumerate(self._children):
                if pid not in finals:
                    # exitcode read after the join above, so the diagnostic
                    # reflects how the child actually ended
                    self._record_error(RuntimeError(
                        f"client process {pid} sent no final state "
                        f"(exitcode={child.exitcode})"))
            # children exited => their EOF frames are on the wire; readers
            # drain them into the shard inboxes and stop
            for r in self._readers:
                r.join(timeout=max(0.1, self._deadline - time.monotonic()) + 5)
            for s in self.shards:
                s.inbox.put(SHUTDOWN)
            for s in self.shards:
                s.thread.join(timeout=5.0)
            self._close_wals()
        finally:
            self._finished = True
            self._cleanup_transport()
        self._merge_finals(finals)
        self.stats.sim_time = time.monotonic() - self._t0
        if self._errors:
            raise RuntimeError(
                f"runtime failed: {self._errors[0]!r}") from self._errors[0]
        if self.check:
            self._final_checks()
        return self.stats

    def _cleanup_transport(self) -> None:
        if self.transport_kind == "tcp":
            self._transport.close_listener()
            for conn in getattr(self, "_conns", {}).values():
                conn.close()
        elif self._transport is not None:
            if hasattr(self, "_reader_stop"):
                self._reader_stop.set()
            self._transport.close(unlink=True)
        self._transport = None

    def _merge_finals(self, finals: Dict[int, dict]) -> None:
        clock_times: List[List[float]] = []
        for pid, fin in sorted(finals.items()):
            st: RunStats = fin["stats"]
            for err in fin["errors"]:
                self._errors.append(RuntimeError(f"client {pid}: {err}"))
            self.stats.n_updates += st.n_updates
            self.stats.block_time_clock += st.block_time_clock
            self.stats.block_time_value += st.block_time_value
            self.stats.max_observed_staleness = max(
                self.stats.max_observed_staleness, st.max_observed_staleness)
            self.stats.max_unsynced_mag = max(
                self.stats.max_unsynced_mag, st.max_unsynced_mag)
            self.stats.max_update_mag = max(
                self.stats.max_update_mag, st.max_update_mag)
            self.stats.max_unsynced_norm = max(
                self.stats.max_unsynced_norm, st.max_unsynced_norm)
            self.stats.max_update_norm = max(
                self.stats.max_update_norm, st.max_update_norm)
            self.stats.violations.extend(st.violations)
            for k, v in fin["total"].items():
                self._total[k] += v
            self._parts_sent[pid] = fin.get("parts_sent", 0)
            self._final_caches[pid] = fin["cache"]
            tr = fin.get("trace")
            if tr and self._trace is not None:
                self._trace.adopt(tr)
            clock_times.append(st.clock_times)
        if clock_times and all(clock_times):
            n = min(len(c) for c in clock_times)
            self.stats.clock_times = [
                max(c[i] for c in clock_times) for i in range(n)]

    def _on_shard_fin(self, msg: ShardFinMsg) -> None:
        raise TypeError("ShardFin must not reach the in-process runtime")

    def run(self, update_fn: Callable, n_clocks: int,
            timeout: float = 120.0) -> RunStats:
        """Run every worker for ``n_clocks`` periods (start + wait)."""
        self.start(update_fn, n_clocks, timeout=timeout)
        return self.wait()

    def _note_global_clock(self) -> None:
        done = min(p.sent_clock for p in self.procs)
        with self._slock:
            while self._done_clock < done:
                self._done_clock += 1
                self.stats.clock_times.append(time.monotonic() - self._t0)

    @property
    def running(self) -> bool:
        """True while workers are still producing updates."""
        if self._finished or not self._started:
            return False
        if self._proc_mode:
            return any(c.is_alive() for c in self._children)
        return any(t.is_alive() for t in self._workers)

    # ------------------------------------------------------------ membership
    @property
    def n_active_shards(self) -> int:
        """Shards active in the current membership epoch (``n_shards`` is
        the epoch-0 count; slots are ``n_slots``)."""
        return self.partition.A

    @property
    def _shard_rows(self) -> Dict[str, List[np.ndarray]]:
        """Per-slot row ownership under the *current* partition (back-compat
        view of the pre-elastic static attribute)."""
        return {key: [self.partition.rows_of(key, s)
                      for s in range(self.n_slots)]
                for key in self._x0}

    def add_shard(self, sid: Optional[int] = None,
                  timeout: float = 30.0) -> int:
        """Activate a dormant shard slot mid-run (live re-partition; see
        :mod:`repro.runtime.membership`).  Returns the activated sid."""
        return self.membership.add_shard(sid, timeout=timeout)

    def remove_shard(self, sid: int, timeout: float = 30.0) -> None:
        """Retire an active shard slot mid-run; its rows migrate to the
        survivors through the vc-stamped snapshot re-partition path."""
        self.membership.remove_shard(sid, timeout=timeout)

    def completed_clock(self) -> int:
        """Global applied-clock frontier: periods completed by every process
        and applied by every active shard (cheap racy read, monotone — the
        membership-plan driver polls this for its clock-boundary triggers)."""
        done = None
        for s in self.shards:
            vc = s.vc_if_active()
            if vc is not None:
                lo = int(vc.min()) + 1
                done = lo if done is None else min(done, lo)
        return done or 0

    # ------------------------------------------------------------- metrics
    def metrics(self) -> RuntimeMetrics:
        """One typed snapshot of every runtime/serving stats surface —
        the unified read API (:mod:`repro.runtime.metrics`).  Windowed
        rates are measured against the previous call.  The scattered
        legacy surfaces (``rt.stats``, ``gateway.stats``,
        ``rset.pub_drops``...) keep working but are deprecated as read
        APIs; new consumers (autoscaler, benches, demos) use this."""
        return self._metrics_hub.collect()

    # ------------------------------------------------------------- tracing
    def _require_trace(self) -> "trace_mod.TraceHub":
        if self._trace is None:
            raise RuntimeError(
                "tracing is off; construct the runtime with "
                "RuntimeConfig(trace=True) (or a sample rate / TraceConfig) "
                "to record events")
        return self._trace

    def dump_trace(self, path: str) -> dict:
        """Export the recorded event log as Chrome trace-event JSON —
        load it at https://ui.perfetto.dev.  One track per thread per
        process; update lifelines ride flow events (client send -> shard
        apply -> replica ingest).  Proc-mode client rings only ship at
        quiesce, so call after :meth:`wait` to see the client side.
        Returns ``{"events":, "dropped":, "path":}``."""
        return trace_mod.dump_chrome_trace(self._require_trace(), path)

    def explain_read(self, result) -> dict:
        """Consistency audit: why did this
        :class:`~repro.runtime.serving.gateway.ReadResult` land where it
        did — names the lagging ``(shard, proc)`` pair and the vc gap that
        forced an escalation.  Pure function of the result's audit stamps
        (works with tracing off)."""
        return trace_mod.explain_read(result)

    def explain_block(self, process: Optional[int] = None,
                      worker: Optional[int] = None) -> dict:
        """Attribute recorded clock/value stalls to the straggler process
        the workers waited on (requires tracing)."""
        return trace_mod.explain_block(self._require_trace(),
                                       process=process, worker=worker)

    def staleness_timeline(self, shard: int) -> dict:
        """Measured master−replica staleness over time for one shard,
        against the policy's clock bound (requires tracing + serving)."""
        bound = (self.policy.staleness if self.policy.clock_bounded
                 else None)
        return trace_mod.staleness_timeline(self._require_trace(), shard,
                                            bound=bound)

    # ------------------------------------------------------------- reads
    def read(self, key: str, process: int = 0) -> np.ndarray:
        """Serving read: a Get() against a live process cache (threaded
        mode), or against the live master shards / the final shipped cache
        (multi-process mode, where peer caches live in other processes)."""
        if self._proc_mode:
            if self._finished and self._final_caches:
                return self._final_caches[process][key].copy().reshape(
                    self._shapes[key])
            return self.master_value(key)
        proc = self.procs[process]
        with proc.cond:
            flat = proc.cache[key].copy()
        return flat.reshape(self._shapes[key])

    def master_value(self, key: str) -> np.ndarray:
        """Assemble the authoritative value from the shard tables.

        Exact once the runtime is quiesced (after :meth:`wait`); mid-run it
        is a live, per-shard-locked read of the master blocks.  Holds the
        membership op lock so it never observes a half-installed partition
        (a read racing a live re-partition waits out the short freeze).
        """
        out = np.zeros_like(self._x0[key])
        with self.membership.op_lock:
            for shard in self.shards:
                shard.read_rows(key, out)
        return out.reshape(self._shapes[key])

    def view(self, process: int) -> Dict[str, np.ndarray]:
        """A process cache as {key: array in the original shape}."""
        if self._proc_mode:
            if not self._finished:
                raise RuntimeError("multi-process caches are only shipped "
                                   "back at wait(); use read() mid-run")
            cache = self._final_caches[process]
            return {k: v.copy().reshape(self._shapes[k])
                    for k, v in cache.items()}
        proc = self.procs[process]
        with proc.cond:
            return {k: v.copy().reshape(self._shapes[k])
                    for k, v in proc.cache.items()}

    # ------------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """Master shard state as a restorable snapshot (see
        :mod:`repro.runtime.snapshot`)."""
        from repro.runtime.snapshot import take_snapshot
        return take_snapshot(self)

    def _maybe_periodic_snapshot(self) -> None:
        """Called by a shard thread after its applied vector clock moved:
        take one snapshot each time the global applied frontier — completed
        clocks fully applied on every shard by every process — crosses a
        multiple of ``snapshot_every``.  Boundary-*triggered*, not
        barrier-exact: updates of later periods already in flight may be
        included, exactly like a parameter server checkpointing without a
        barrier (snapshot.py module doc)."""
        if not self.snapshot_every or self._finished:
            return
        # never block a shard thread against an in-flight membership install
        # (the manager waits for shard-side install confirms while holding
        # op_lock): skip the boundary and let the next ClockMsg re-trigger
        if not self.membership.op_lock.acquire(blocking=False):
            return
        try:
            # racy per-entry reads are fine: the frontier is monotone, so a
            # stale read only delays the trigger to the next ClockMsg
            done = self.completed_clock()
            if done < self._next_snap_clock:
                return
            with self._snap_lock:
                if done < self._next_snap_clock:   # another shard was first
                    return
                while self._next_snap_clock <= done:
                    self._next_snap_clock += self.snapshot_every
                snap = self.snapshot()
                self.snapshots.append((done, snap))
                if self.snapshot_dir:
                    from repro.runtime.snapshot import save_snapshot
                    os.makedirs(self.snapshot_dir, exist_ok=True)
                    save_snapshot(os.path.join(self.snapshot_dir,
                                               f"snap_c{done:06d}.npz"), snap)
                if self.snapshot_keep_last:
                    self._prune_retained()
        finally:
            self.membership.op_lock.release()

    def _prune_retained(self) -> None:
        """Retention (``snapshot_keep_last=k``): drop periodic snapshots
        beyond the newest k — in memory and on disk — then drop WAL
        segments fully covered by the *oldest retained* snapshot, so
        every retained snapshot still recovers exactly (genesis replay
        deliberately stops working past the horizon: retention trades
        point-in-time depth for disk).  Caller holds ``_snap_lock``."""
        k = self.snapshot_keep_last
        if len(self.snapshots) > k:
            del self.snapshots[:len(self.snapshots) - k]
        if self.snapshot_dir:
            import re
            pat = re.compile(r"^snap_c(\d+)\.npz$")
            on_disk = sorted((int(m.group(1)), f)
                             for f in os.listdir(self.snapshot_dir)
                             if (m := pat.match(f)))
            for _, f in on_disk[:-k] if len(on_disk) > k else []:
                try:
                    os.remove(os.path.join(self.snapshot_dir, f))
                except OSError:
                    pass
        if self.wal_dir and self.snapshots:
            oldest = self.snapshots[0][1]
            wal = oldest.get("wal")
            if wal is not None:
                from repro.runtime.wal import prune_segments
                covered = {sid: int(p)
                           for sid, p in enumerate(wal["parts"])}
                prune_segments(self.wal_dir, covered)

    def latest_snapshot(self) -> Optional[dict]:
        """The most recent periodic snapshot, or None (serving-tier replica
        bootstrap seeds from this before subscribing)."""
        with self._snap_lock:
            return self.snapshots[-1][1] if self.snapshots else None

    # ------------------------------------------------------------- checks
    def _final_checks(self) -> None:
        """Eventual consistency: caches and master equal x0 + sum(updates)."""
        expected = {k: self._x0[k] + self._total[k] for k in self._x0}
        caches = (self._final_caches.items() if self._proc_mode
                  else ((p, self.procs[p].cache) for p in range(self.n_proc)))
        for p, cache in caches:
            for k in self._x0:
                if not np.allclose(cache[k], expected[k], atol=1e-6):
                    self._violation(
                        f"eventual-consistency violation on {k} (process {p})")
        for k in self._x0:
            master = self.master_value(k).reshape(self._x0[k].shape)
            if not np.allclose(master, expected[k], atol=1e-6):
                self._violation(
                    f"eventual-consistency violation on {k} (shard tables)")
        # zero-lost/zero-duplicated audit across membership changes: every
        # update part a client sent was applied by exactly one shard slot
        applied = np.zeros(self.n_proc, dtype=np.int64)
        for s in self.shards:
            applied += s.applied_parts
        if not np.array_equal(applied, self._parts_sent):
            self._violation(
                f"update audit: parts sent {self._parts_sent.tolist()} != "
                f"applied {applied.tolist()} (lost or duplicated updates)")


# ---------------------------------------------------------------------------
# forked client process (wire transports)
# ---------------------------------------------------------------------------


class _ClientHost(_WorkerFlowMixin):
    """Child-side runtime facade: owns one :class:`ClientProcess`, its
    worker threads, and the wire channels to every shard.  Mirrors the
    attribute surface :class:`_WorkerFlowMixin` and :class:`ClientProcess`
    expect from ``rt``."""

    def __init__(self, rt: PSRuntime, pid: int):
        self.pid = pid
        self.policy = rt.policy
        self.seed = rt.seed
        self.check = rt.check
        self.barrier_reads = rt.barrier_reads
        self.prioritize = rt.prioritize
        # forked children stay numpy-only (importing jax after fork is not
        # fork-safe); the kernel paths run in the parent and in queue mode
        self.ps_kernels = False
        self.metrics_on = rt.metrics_on
        # fresh hub post-fork: the fork-copied parent hub (and its rings)
        # belongs to the parent timeline; this process records into its own
        # and ships the rings back in the quiesce payload
        self._trace_cfg = rt._trace_cfg
        self.trace_on = rt.trace_on
        self._trace = (trace_mod.TraceHub(self._trace_cfg, f"client-p{pid}")
                       if self.trace_on else None)
        self.n_shards = rt.n_shards
        self.n_slots = rt.n_slots
        self.n_proc = rt.n_proc
        self.tpp = rt.tpp
        self.update_fn = rt.update_fn
        self.n_clocks = rt.n_clocks
        self._deadline = rt._deadline
        self._x0 = rt._x0
        self._shapes = rt._shapes
        self._row_counts = rt._row_counts
        self.partition = rt.partition         # epoch at fork time (0)
        self._t0 = time.monotonic()

        self.stats = RunStats()
        self._slock = threading.Lock()
        self._total = {k: np.zeros_like(v) for k, v in self._x0.items()}
        self._parts_sent = np.zeros(rt.n_proc, dtype=np.int64)
        # globally unique uids without cross-process coordination
        self._uid = itertools.count(pid, rt.n_proc)
        self._errors: List[BaseException] = []
        self._fins: set = set()
        self._all_fins = threading.Event()

        self.proc = ClientProcess(self, pid)
        self.procs = {pid: self.proc}
        self._readers: List[threading.Thread] = []
        self._channels: List[T.WireChannel] = []
        if rt.transport_kind == "tcp":
            self._conns = rt._transport.connect(pid)
            chans = []
            for s in range(rt.n_slots):
                conn = self._conns[s]
                chans.append(T.WireChannel(f"p{pid}->s{s}", conn.write,
                                           trace=self._trace))
                self._readers.append(T.start_reader(
                    f"rx-s{s}", conn.read_chunk, self.proc.inbox,
                    self._record_error, trace=self._trace))
        else:
            self._stop = threading.Event()
            codec = T.RowCodec(list(self._x0.keys())) if rt.zero_copy \
                else None
            chans = []
            for s in range(rt.n_slots):
                edge = rt._transport.edges[(pid, s)]
                if codec is not None:
                    bell_w = edge.c2s_bell[1]
                    chans.append(T.WireChannel(
                        f"p{pid}->s{s}",
                        T.ring_parts_writer(edge.c2s, self._deadline),
                        max_frame=rt._shm_max_frame, codec=codec,
                        on_flush=lambda w=bell_w: T.ShmEdge.ring_bell(w),
                        trace=self._trace))
                    self._readers.append(T.start_view_reader(
                        f"rx-s{s}",
                        T.RingViewReader(edge.s2c, codec, edge.s2c_bell[0],
                                         self._stop, trace=self._trace),
                        self.proc.inbox, self._record_error))
                else:
                    chans.append(T.WireChannel(
                        f"p{pid}->s{s}",
                        T.ring_writer(edge.c2s, edge.c2s_bell[1],
                                      self._deadline),
                        max_frame=rt._shm_max_frame, trace=self._trace))
                    self._readers.append(T.start_reader(
                        f"rx-s{s}", T.ring_reader(edge.s2c, edge.s2c_bell[0],
                                                  self._stop),
                        self.proc.inbox, self._record_error,
                        trace=self._trace))
        self._channels = chans
        self._chan_ps = {pid: chans}

    # ---------------------------------------------------------- rt interface
    def proc_of(self, worker: int) -> int:
        return self.pid

    def _next_uid(self) -> int:
        return next(self._uid)

    def _send(self, chan, msg) -> None:
        chan.send(msg)

    def _send_many(self, chan, msgs: list) -> None:
        if msgs:
            chan.send_many(msgs)

    def _msg_done(self) -> None:
        pass

    def _violation(self, text: str) -> None:
        with self._slock:
            self.stats.violations.append(text)

    def _record_error(self, e: BaseException) -> None:
        with self._slock:
            self._errors.append(e)

    def _check_alive(self) -> None:
        if time.monotonic() > self._deadline:
            raise RuntimeError("client deadline exceeded (gate stuck)")
        if self._errors:
            raise RuntimeError("client aborted: peer thread failed")

    def _note_global_clock(self) -> None:
        # local completion times; the parent merges max() across processes
        now = time.monotonic() - self._t0
        with self._slock:
            while len(self.stats.clock_times) < self.proc.sent_clock:
                self.stats.clock_times.append(now)

    def _on_shard_fin(self, msg: ShardFinMsg) -> None:
        self._fins.add(msg.shard)
        if len(self._fins) == self.n_slots:
            self._all_fins.set()

    # ---------------------------------------------------------------- run
    def run(self) -> dict:
        self.proc.thread.start()
        workers = [threading.Thread(target=self._worker_loop, args=(w,),
                                    name=f"ps-worker-{w}", daemon=True)
                   for w in self.proc.workers]
        for t in workers:
            t.start()
        timed_out = False
        for t in workers:
            while t.is_alive():
                t.join(timeout=0.5)
                if time.monotonic() > self._deadline:
                    timed_out = True
                    self._record_error(RuntimeError(
                        f"worker {t.name} still alive at deadline"))
                    break
        if not timed_out:
            # quiesce leg 1: no more updates/clocks from this process (acks
            # for still-inbound deliveries continue from the comm thread).
            # A still-running (timed-out) worker forbids this promise — the
            # run is failing anyway; ship the error without the handshake.
            with self.proc.route_lock:
                ep = self.proc.part.epoch
            for chan in self._channels:
                self._send(chan, ProcDoneMsg(self.pid, ep))
            # quiesce leg 2: every shard's fin = our inbound stream is done
            if not self._all_fins.wait(
                    timeout=max(0.1, self._deadline - time.monotonic())):
                self._record_error(RuntimeError(
                    f"client {self.pid}: shard fins missing "
                    f"(have {sorted(self._fins)})"))
        self.proc.inbox.put(SHUTDOWN)
        self.proc.thread.join(timeout=5.0)
        for chan in self._channels:
            chan.close()                       # EOF frame ends parent readers
        return {
            "pid": self.pid,
            "stats": self.stats,
            "total": self._total,
            "cache": self.proc.cache,
            "parts_sent": int(self._parts_sent[self.pid]),
            "trace": (self._trace.export() if self._trace is not None
                      else None),
            "errors": [repr(e) for e in self._errors],
        }


def _client_child_main(rt: PSRuntime, pid: int, pipe) -> None:
    """Entry point of a forked client process."""
    try:
        import sys
        # comm/reader threads must grab the GIL promptly from the
        # compute-bound worker: the default 5 ms switch interval adds a
        # multi-ms stall to every inbound frontier/delivery hop
        sys.setswitchinterval(1e-3)
        host = _ClientHost(rt, pid)
        payload = host.run()
    except BaseException as e:
        payload = {"pid": pid, "stats": RunStats(), "total": {},
                   "cache": {}, "errors": [repr(e)]}
    try:
        pipe.send(payload)
        pipe.close()
    finally:
        # skip atexit/teardown inherited from the parent (jax worker-thread
        # joins would hang in a forked child)
        os._exit(0)
