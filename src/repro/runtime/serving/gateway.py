"""The read gateway: per-read staleness SLOs measured against the vector
clock.

Every read declares an SLO:

  * ``slo=k`` (int >= 0) — the returned value may trail the master's
    applied frontier by at most ``k`` clocks;
  * ``slo="fresh"`` (:data:`FRESH`) — the read goes to the master shards
    (per-shard-locked assembly of the authoritative blocks);
  * ``slo=None`` — any replica qualifies; the response is still stamped.

Routing: among the replicas whose vector clock satisfies the bound, the
gateway picks the least-loaded (fewest served reads) and copies the value
out under the replica lock.  It then **re-measures** against the live
master vector clock sampled *after* the copy — an upper bound on the true
staleness at serve time, since the master frontier only advances — and only
returns if the conservative measure still meets the SLO; otherwise it tries
again.  When no replica qualifies it parks on the replica set's doorbell
(a condition the ingest threads ring whenever a vector clock advances — a
real kernel sleep, not sub-ms polling) and, at the deadline, **escalates to
the master**, so the SLO is met by construction and the stamp on every
:class:`ReadResult` lets tests assert it was *honored*, not just requested.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Union

import numpy as np

from repro.runtime import trace as trace_mod
from repro.runtime.metrics import slo_key
from repro.runtime.serving.replica import ReplicaSet

FRESH = "fresh"                  # sentinel SLO: serve the master state
Slo = Union[int, str, None]

log = logging.getLogger("repro.runtime.serving.gateway")


def _slo_code(slo: Slo) -> int:
    """The integer trace encoding of an SLO (trace.SLO_ANY / SLO_FRESH)."""
    if slo is None:
        return trace_mod.SLO_ANY
    if slo == FRESH:
        return trace_mod.SLO_FRESH
    return int(slo)


class ReadShedError(RuntimeError):
    """A ``fresh`` read refused by SLO-aware admission control: the master
    is hot and the gateway is shedding master-path reads (the autoscaler's
    :meth:`ReadGateway.set_shed_fresh`).  Clients retry, degrade to a
    bounded SLO, or surface the overload."""

    def __init__(self, key: str):
        super().__init__(f"fresh read of {key!r} shed: master overloaded")
        self.key = key


@dataclass
class ReadResult:
    """One served read, stamped with how stale it actually was."""
    value: np.ndarray            # in the key's original shape
    key: str
    source: str                  # "replica:<rid>", "cache" or "master"
    staleness: int               # measured clocks behind the master vc
    slo: Slo                     # what the client asked for
    escalated: bool              # no replica qualified before the deadline
    waited_s: float              # wall time from request to response
    # consistency audit stamps (rt.explain_read): on an escalated read, the
    # (slot, process) cell of the best candidate replica's vector clock that
    # trailed the master frontier furthest at escalation time, and by how
    # many clocks.  -1/-1/0 when the read never escalated.
    lag_shard: int = -1
    lag_proc: int = -1
    vc_gap: int = 0


@dataclass
class GatewayStats:
    """Deprecated as a read surface: consume ``rt.metrics().gateways``
    (:mod:`repro.runtime.metrics`) instead; the fields stay for
    back-compat and as the hub's raw source."""
    n_reads: int = 0
    n_replica_reads: int = 0
    n_master_reads: int = 0      # fresh SLO + escalations
    n_escalations: int = 0
    n_shed: int = 0              # fresh reads refused by admission control
    n_cache_hits: int = 0        # reads served from the gateway cache
    max_served_staleness: int = 0
    block_time: float = 0.0      # time actually parked on the doorbell only
    reads_per_replica: Dict[int, int] = field(default_factory=dict)
    reads_by_slo: Dict[str, int] = field(default_factory=dict)


class ReadGateway:
    """SLO-routed serving reads over a :class:`ReplicaSet`.

    Thread-safe: any number of client threads may call :meth:`read`
    concurrently (the serving copy happens under the chosen replica's lock,
    stats under the gateway's own).
    """

    def __init__(self, rt, n_replicas: int = 2, transport: str = "queue",
                 check: bool = True, bootstrap_from_snapshot: bool = False,
                 replica_set: Optional[ReplicaSet] = None,
                 read_cache: bool = False):
        self.rt = rt
        self.replicas = replica_set if replica_set is not None else ReplicaSet(
            rt, n_replicas, transport=transport, check=check,
            bootstrap_from_snapshot=bootstrap_from_snapshot)
        self.stats = GatewayStats()
        self._slock = threading.Lock()
        # SLO-aware admission: while engaged (autoscaler's master-hot
        # signal), fresh reads are refused with ReadShedError instead of
        # adding master-shard lock traffic
        self.shed_fresh = False
        # gateway read cache (within a vc stamp): serve repeated hot-key
        # reads without touching a replica while the cached stamp still
        # meets the request's SLO.  {key: (flat value copy, vc at copy)} —
        # staleness is re-measured against the LIVE master vc on every hit,
        # so an advanced master frontier invalidates naturally and a cached
        # read can never stamp staler than requested.
        self.read_cache = read_cache
        self._cache: Dict[str, tuple] = {}
        reg = getattr(rt, "_gateways", None)
        self._gw_id = len(reg) if reg is not None else 0
        if reg is not None:                  # unified metrics registry
            reg.append(self)

    # ------------------------------------------------------------ admission
    def set_shed_fresh(self, shed: bool) -> None:
        """Engage/release fresh-read shedding (SLO-aware admission)."""
        shed = bool(shed)
        if shed != self.shed_fresh:
            if shed:
                log.warning("gateway %d: fresh-read shedding ENGAGED — "
                            "master hot, fresh reads now refused with "
                            "ReadShedError", self._gw_id)
            else:
                log.info("gateway %d: fresh-read shedding released",
                         self._gw_id)
        self.shed_fresh = shed

    # ---------------------------------------------------------------- reads
    def read(self, key: str, slo: Slo = None,
             timeout: float = 30.0) -> ReadResult:
        """Serve one read under the declared staleness SLO (module doc)."""
        t0 = time.monotonic()
        rt = self.rt
        trc = rt._trace if rt.trace_on else None
        with self._slock:
            k = slo_key(slo)
            self.stats.reads_by_slo[k] = self.stats.reads_by_slo.get(k, 0) + 1
        if slo == FRESH:
            if self.shed_fresh:
                with self._slock:
                    self.stats.n_shed += 1
                raise ReadShedError(key)
            res = self._serve_master(key, slo, t0, escalated=False)
            if trc is not None:
                trc.point(trace_mod.EV_READ, _slo_code(slo), res.staleness,
                          res.source)
            return res
        bound = float("inf") if slo is None else int(slo)
        if bound < 0:
            raise ValueError(f"slo must be >= 0 or {FRESH!r}, got {slo!r}")
        deadline = t0 + timeout
        rset = self.replicas
        fails = 0
        blocked = 0.0
        while True:
            res = self._try_cache(key, bound, slo, t0)
            if res is not None:
                break
            with rset.cond:
                v0 = rset.version
            res = self._try_replicas(key, bound, slo, t0)
            if res is not None:
                break
            fails += 1
            now = time.monotonic()
            if now >= deadline:
                # audit stamp BEFORE the master copy: the lagging cell is
                # measured at the moment escalation was decided
                lag = self._lag_info()
                if trc is not None:
                    trc.point(trace_mod.EV_ESCALATE, self._gw_id, 0, key)
                res = self._serve_master(key, slo, t0, escalated=True)
                res.lag_shard, res.lag_proc, res.vc_gap = lag
                break
            with rset.cond:
                # version guard: a doorbell rung during the FIRST failed
                # attempt retries immediately instead of sleeping through
                # it; after that, retries are paced by the doorbell itself
                # (one per notify), else a hot vc under heavy write traffic
                # turns waiting readers into a GIL-burning retry storm that
                # starves the very ingest threads they are waiting on
                if rset.version == v0 or fails >= 2:
                    t_w = time.monotonic()
                    rset.cond.wait(min(0.25, deadline - now))
                    blocked += time.monotonic() - t_w
                    if trc is not None:
                        trc.span(trace_mod.EV_PARK, int(t_w * 1e9),
                                 self._gw_id, 0, key)
        if blocked:
            with self._slock:
                self.stats.block_time += blocked
        if trc is not None:
            trc.point(trace_mod.EV_READ, _slo_code(slo), res.staleness,
                      res.source)
        return res

    def _lag_info(self) -> tuple:
        """The (slot, process, gap) cell that forced this escalation: over
        the live replicas, take the BEST candidate (smallest worst-case vc
        gap vs the master frontier) and name the cell where even it trailed
        furthest.  (-1, -1, 0) when no live replica exists at all."""
        rset = self.replicas
        mvc = rset.master_vc()
        best = None
        for rep in rset.replicas:
            if rep.poisoned or rep.retired:
                continue
            gap = mvc - rep.vc
            worst = int(gap.max())
            if best is None or worst < best[0]:
                s, p = np.unravel_index(int(gap.argmax()), gap.shape)
                best = (worst, int(s), int(p))
        if best is None:
            return (-1, -1, 0)
        worst, s, p = best
        return (s, p, max(worst, 0))

    def _try_cache(self, key: str, bound: float, slo: Slo,
                   t0: float) -> Optional[ReadResult]:
        """Serve from the gateway cache if its stamp still meets the SLO.

        The cached entry's vc was sampled at (or conservatively before) the
        moment its value was copied; measuring it against the *live* master
        vc can only overstate the true staleness (the frontier is
        monotone), so a hit never stamps staler than it really is — and an
        entry whose measured lag exceeds the bound simply misses (the vc
        advance invalidated it)."""
        if not self.read_cache:
            return None
        with self._slock:
            ent = self._cache.get(key)
        if ent is None:
            return None
        flat, cvc = ent
        lag = self.replicas.staleness(cvc, self.replicas.master_vc())
        if lag > bound:
            return None
        with self._slock:
            self.stats.n_reads += 1
            self.stats.n_cache_hits += 1
            self.stats.max_served_staleness = max(
                self.stats.max_served_staleness, lag)
        return ReadResult(flat.copy().reshape(self.rt._shapes[key]), key,
                          "cache", lag, slo, False, time.monotonic() - t0)

    def _cache_put(self, key: str, flat: np.ndarray, vc) -> None:
        with self._slock:
            self._cache[key] = (flat, vc)

    def _try_replicas(self, key: str, bound: float, slo: Slo,
                      t0: float) -> Optional[ReadResult]:
        rset = self.replicas
        mvc = rset.master_vc()
        # least-loaded first; the racy .reads peek only orders candidates
        for rep in sorted(rset.replicas, key=lambda r: r.reads):
            if rep.poisoned or rep.retired:
                continue                       # ingest failed / drained out
            if rset.staleness(rep.vc, mvc) > bound:
                continue                       # cheap unlocked pre-filter
            value, rvc = rep.serve(key)
            # conservative stamp: master vc sampled AFTER the copy can only
            # be ahead of the frontier at copy time, so measured >= true
            lag = rset.staleness(rvc, rset.master_vc())
            if lag > bound:                    # master advanced mid-copy
                continue
            with self._slock:
                self.stats.n_reads += 1
                self.stats.n_replica_reads += 1
                self.stats.max_served_staleness = max(
                    self.stats.max_served_staleness, lag)
                self.stats.reads_per_replica[rep.rid] = (
                    self.stats.reads_per_replica.get(rep.rid, 0) + 1)
            if self.read_cache:
                # rep.serve already copied out of the replica buffers; the
                # reshape below shares that copy with the caller, so the
                # cache keeps its own
                self._cache_put(key, value.copy(), rvc)
            return ReadResult(value.reshape(self.rt._shapes[key]), key,
                              f"replica:{rep.rid}", lag, slo, False,
                              time.monotonic() - t0)
        return None

    def _serve_master(self, key: str, slo: Slo, t0: float,
                      escalated: bool) -> ReadResult:
        # cache stamp sampled BEFORE the copy: everything the stamp claims
        # is certainly in the copied value (the frontier is monotone), so
        # hits measured against it stay conservative
        mvc = self.replicas.master_vc() if self.read_cache else None
        value = self.rt.master_value(key)      # per-shard-locked assembly
        with self._slock:
            self.stats.n_reads += 1
            self.stats.n_master_reads += 1
            if escalated:
                self.stats.n_escalations += 1
        if self.read_cache:
            flat = np.ascontiguousarray(value).reshape(
                self.rt._x0[key].shape).copy()
            self._cache_put(key, flat, mvc)
        return ReadResult(value, key, "master", 0, slo, escalated,
                          time.monotonic() - t0)

    # ------------------------------------------------------------- lifecycle
    def add_replica(self, bootstrap_from_snapshot: bool = False):
        return self.replicas.add_replica(
            bootstrap_from_snapshot=bootstrap_from_snapshot)

    def remove_replica(self, rid=None):
        return self.replicas.remove_replica(rid)

    def close(self, timeout: float = 10.0) -> None:
        self.replicas.close(timeout=timeout)

    def __enter__(self) -> "ReadGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
