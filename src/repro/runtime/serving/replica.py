"""Read replicas of the PS serving tier.

A :class:`ReplicaSet` holds N :class:`Replica` objects, each subscribed to
every master shard's publish stream over one of the serving transports:

  * ``queue`` — in-process FIFO :class:`~repro.runtime.messages.Channel`
    edges (shard thread -> replica inbox);
  * ``shm``   — the shard writes framed batches into a single-producer
    shared-memory ring with a pipe doorbell, a reader thread drains it into
    the replica inbox (same :class:`~repro.runtime.transport.ShmRing` /
    :class:`~repro.runtime.transport.WireChannel` machinery as the
    multi-process runtime transport; refuses weakly-ordered ISAs via
    :func:`~repro.runtime.transport.require_tso`);
  * ``tcp``   — the same frames over a loopback socket per (shard, replica).

Consistency accounting.  Each replica keeps a **per-shard vector clock**
``vc[s, p]`` — the highest period of client process ``p`` whose updates it
has applied for shard ``s``'s rows, adopted from the ``ReplicaVcMsg``
stamps the shard publishes FIFO-behind the deltas they cover.  The master's
authoritative frontier is the live per-shard applied vector clock
(:meth:`ServerShard.vc_snapshot`), so a read's **measured staleness** is

    max over shards s, processes p of (master_vc[s, p] - replica_vc[s, p])

in clock units — 0 means the replica has applied everything the master
shards have.  Extra freshness (deltas of periods past the vc) is allowed,
exactly like every bounded-staleness read in the paper; missing covered
updates are impossible because the stamp is FIFO-behind them.

Bootstrap.  A replica joining mid-run is seeded **in-stream**: the shard
answers its Subscribe with the current dense partition in the snapshot
payload format (:class:`ReplicaStateMsg` — the same per-shard dict
:meth:`ServerShard.state` / :mod:`repro.runtime.snapshot` use), stamped with
the shard's vc, before any further delta on that channel, so the replica's
view of that partition is exact from the first frame.  Optionally the
replica warm-starts from the runtime's latest **periodic snapshot**
(``PSRuntime(snapshot_every=k)``), assembled through the snapshot module's
re-partition path, so it can serve honestly-stamped stale reads before the
(larger) in-stream states arrive.
"""
from __future__ import annotations

import logging
import os
import queue
import socket
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import snapshot as SNAP
from repro.runtime import trace as trace_mod
from repro.runtime import transport as T
from repro.runtime.messages import (SHUTDOWN, Channel, ReplicaDeltaMsg,
                                    ReplicaFinMsg, ReplicaStateMsg,
                                    ReplicaVcMsg, SubscribeMsg,
                                    UnsubscribeMsg, pump_inbox)

SERVING_TRANSPORTS = ("queue", "shm", "tcp")

log = logging.getLogger("repro.runtime.serving.replica")


class Replica:
    """One read replica: full-key value buffers + per-shard vector clock,
    fed by a comm thread draining the shard publish streams."""

    def __init__(self, rset: "ReplicaSet", rid: int,
                 seed_snapshot: Optional[dict] = None):
        rt = rset.rt
        self.rset = rset
        self.rid = rid
        self.lock = threading.Lock()        # guards values / vc / counters
        if seed_snapshot is not None:
            # warm start from a periodic snapshot: full values through the
            # snapshot module's re-partition path + a conservative vc seed
            master = SNAP.assemble_master(seed_snapshot)
            if set(master) != set(rt._x0):
                raise ValueError("bootstrap snapshot keys do not match "
                                 "the runtime's")
            self.values: Dict[str, np.ndarray] = {
                k: master[k].astype(np.float64, copy=True) for k in rt._x0}
            self.vc = SNAP.conservative_vc(seed_snapshot, rt.n_slots,
                                           rt.n_proc)
        else:
            self.values = {k: v.copy() for k, v in rt._x0.items()}
            # per *slot* vector clock: inactive slots sit at -1 and the
            # master frontier never claims them, so they drop out of the
            # staleness max; a newly activated slot's master row appears at
            # install and keeps reads conservative until the in-stream
            # re-bootstrap lands here
            self.vc = np.full((rt.n_slots, rt.n_proc), -1, dtype=np.int64)
        # per-row membership epoch of the last state cut that covered the
        # row (-1 = genesis).  A publish cut at epoch e folds in ALL rows
        # a prior owner applied before handing them off (the epoch barrier
        # guarantees it), so an older-epoch delta arriving for a cut row is
        # a late frame from the retiring slot's channel racing the new
        # owner's bootstrap: applying it would double-count.
        self.row_epoch: Dict[str, np.ndarray] = {
            k: np.full(v.shape[0], -1, dtype=np.int64)
            for k, v in self.values.items()}
        self.inbox: queue.Queue = queue.Queue()
        self.fins: set = set()              # shards that acked unsubscribe
        self.poisoned = False               # ingest failed: out of rotation
        self.retired = False                # drained by remove_replica()
        self.reads = 0                      # served reads (routing cost)
        self.deltas_applied = 0
        self.bytes_ingested = 0
        self.stale_row_drops = 0            # old-epoch delta rows filtered
        self._fifo = T.FifoAssert()         # per publishing shard
        self.thread = threading.Thread(target=self._loop,
                                       name=f"ps-replica-{rid}", daemon=True)

    # ------------------------------------------------------------ ingest
    def _loop(self) -> None:
        pump_inbox(self.inbox, self._handle_batch)

    def _handle_batch(self, batch: list) -> bool:
        rt = self.rset.rt
        trc = rt._trace if rt.trace_on else None
        t0 = time.monotonic_ns() if trc is not None else 0
        vc_moved = False
        shutdown = False
        n_handled = 0
        with self.lock:
            for msg in batch:
                if msg is SHUTDOWN:
                    shutdown = True
                    break
                try:
                    vc_moved |= self._handle(msg, trc)
                    n_handled += 1
                except BaseException as e:
                    # a partially applied message breaks the vc invariant
                    # ("vc[p]=c => every update <= c applied"): take this
                    # replica out of the serving rotation for good rather
                    # than stamping corrupt values as fresh
                    self.poisoned = True
                    log.warning(
                        "replica %d poisoned by ingest failure (%s: %s) — "
                        "out of the serving rotation for good",
                        self.rid, type(e).__name__, e)
                    self.rset._record_error(e)
        if trc is not None and n_handled:
            trc.span(trace_mod.EV_INGEST, t0, self.rid, n_handled)
        if vc_moved:
            self.rset._notify()             # gateway doorbell
        return shutdown

    def _handle(self, msg, trc=None) -> bool:
        """Apply one publish message; returns True if the vc moved.
        Caller holds ``self.lock``."""
        if self.rset.check:
            err = self._fifo.check(msg.shard, msg.seq)
            if err:
                self.rset._violation(
                    f"FIFO violation: shard {msg.shard}->replica "
                    f"{self.rid} {err}")
        if isinstance(msg, ReplicaDeltaMsg):
            if trc is not None and trc.sampled(msg.seq):
                # flow end of the publish lifeline started at the shard's
                # EV_PUBLISH_PART; sampled on seq so both ends agree
                trc.point(trace_mod.EV_INGEST_PART, msg.shard, msg.seq,
                          self.rid)
            # rows may repeat across coalesced source parts: accumulate.
            # Rows whose last cut epoch is newer than the delta's epoch
            # already contain it (see row_epoch above): drop them.
            ok = self.row_epoch[msg.key][msg.rows] <= msg.epoch
            if ok.all():
                np.add.at(self.values[msg.key], msg.rows, msg.delta)
            else:
                self.stale_row_drops += int(np.count_nonzero(~ok))
                if ok.any():
                    np.add.at(self.values[msg.key], msg.rows[ok],
                              msg.delta[ok])
            self.deltas_applied += 1
            self.bytes_ingested += msg.nbytes
            return False
        if isinstance(msg, ReplicaVcMsg):
            np.maximum(self.vc[msg.shard], msg.clock_vc,
                       out=self.vc[msg.shard])
            if trc is not None:
                self._trace_vc(trc, msg.shard)
            return True
        if isinstance(msg, ReplicaStateMsg):
            # in-stream bootstrap: overwrite this shard's partition rows
            # wholesale (exact cut), adopt the stamped vc
            for key, part in msg.state.items():
                self.values[key][part["rows"]] = part["values"]
                if msg.epoch >= 0:
                    self.row_epoch[key][part["rows"]] = msg.epoch
            np.maximum(self.vc[msg.shard], msg.clock_vc,
                       out=self.vc[msg.shard])
            if trc is not None:
                self._trace_vc(trc, msg.shard)
            return True
        if isinstance(msg, ReplicaFinMsg):
            self.fins.add(msg.shard)
            return True                     # wakes close()'s fin wait
        raise TypeError(f"replica {self.rid}: unexpected message {msg!r}")

    def _trace_vc(self, trc, shard: int) -> None:
        """Record the measured master-replica staleness for one shard after
        a vc adoption (trace-gated; feeds ``staleness_timeline``).  Safe
        under ``self.lock``: ``master_vc`` takes shard locks and shard
        threads never take a replica's."""
        mvc = self.rset.master_vc()[shard]
        lag = max(int((mvc - self.vc[shard]).max()), 0)
        trc.point(trace_mod.EV_REPLICA_VC, self.rid, shard, lag)

    # ------------------------------------------------------------ serving
    def serve(self, key: str) -> Tuple[np.ndarray, np.ndarray]:
        """Copy-out read: (flat value, vc at the moment of the copy)."""
        with self.lock:
            self.reads += 1
            return self.values[key].copy(), self.vc.copy()


class ReplicaSet:
    """N read replicas subscribed to a :class:`PSRuntime`'s master shards.

    Lives in the runtime's parent process under every runtime transport
    (the shards always do too); the *serving* transport only picks the wire
    the publish stream rides on.  ``close()`` unsubscribes (the shard
    answers with a FIFO-last ``ReplicaFinMsg``), then tears the channels
    down — safe mid-run or after the runtime quiesced.
    """

    def __init__(self, rt, n_replicas: int = 2, transport: str = "queue",
                 check: bool = True, bootstrap_from_snapshot: bool = False,
                 ring_capacity: Optional[int] = None):
        if transport not in SERVING_TRANSPORTS:
            raise ValueError(f"unknown serving transport {transport!r}; "
                             f"choose from {SERVING_TRANSPORTS}")
        if transport == "shm":
            T.require_tso("the shm serving transport")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.rt = rt
        self.transport = transport
        self.check = check
        self.cond = threading.Condition()   # doorbell: rings on vc advance
        self.version = 0                    # bumps with every ring (guards
        self.replicas: List[Replica] = []   # against missed wakeups)
        self.violations: List[str] = []
        self.errors: List[BaseException] = []
        self._vlock = threading.Lock()
        self._closing = False
        self._closed = False
        self._next_rid = 0
        # control edges into the shard slot inboxes (in-process by
        # construction; inactive slots just never publish)
        self._ctrl = [Channel(f"serve->s{s.sid}", s.inbox) for s in rt.shards]
        self._edges: Dict[Tuple[int, int], dict] = {}
        self._subscribed: Dict[int, set] = {}    # rid -> sids subscribed
        # ring sized so a whole in-stream bootstrap state frame fits; an
        # explicit (small) capacity lets tests exercise the drop-and-resync
        # backpressure path deterministically
        state_bytes = sum(v.nbytes + 8 * v.shape[0] + 4096
                          for v in rt._x0.values())
        self._cap = (max(2 * state_bytes, int(ring_capacity))
                     if ring_capacity else max(1 << 20, 4 * state_bytes))
        for _ in range(n_replicas):
            self.add_replica(bootstrap_from_snapshot=bootstrap_from_snapshot)
        # elastic membership: after each completed epoch, subscribe every
        # replica to newly activated slots (their in-stream bootstrap makes
        # the migrated rows exact) and unsubscribe from retired ones
        rt.membership.add_listener(self._on_epoch)
        reg = getattr(rt, "_replica_sets", None)
        if reg is not None:                  # unified metrics registry
            reg.append(self)

    # -------------------------------------------------------------- plumbing
    def _notify(self) -> None:
        with self.cond:
            self.version += 1
            self.cond.notify_all()

    def _violation(self, text: str) -> None:
        with self._vlock:
            self.violations.append(text)

    def _record_error(self, e: BaseException) -> None:
        if self._closing:
            return                          # teardown races are expected
        with self._vlock:
            self.errors.append(e)

    # ------------------------------------------------------------- topology
    def add_replica(self, bootstrap_from_snapshot: bool = False) -> Replica:
        """Create a replica and subscribe it to every shard (mid-run safe).

        With ``bootstrap_from_snapshot`` the replica warm-starts from the
        runtime's latest periodic snapshot (``snapshot_every``) when one
        exists; the in-stream per-shard state it receives on subscribe then
        supersedes the snapshot partition-by-partition, so the final view
        is exact either way.
        """
        if self._closed:
            raise RuntimeError("replica set is closed")
        snap = self.rt.latest_snapshot() if bootstrap_from_snapshot else None
        rid = self._next_rid
        self._next_rid += 1
        rep = Replica(self, rid, seed_snapshot=snap)
        rep.thread.start()
        self._subscribed[rid] = set()
        # subscribe to the *active* slots of the current epoch; membership
        # changes later adjust via the _on_epoch listener
        with self.rt.membership.op_lock:
            active = self.rt.partition.active
        for sid in active:
            self._subscribe(rep, sid)
        self.replicas.append(rep)
        return rep

    def _subscribe(self, rep: Replica, sid: int) -> None:
        edge = self._edges.get((rep.rid, sid))
        chan = edge["chan"] if edge else self._make_channel(rep, sid)
        rep.fins.discard(sid)               # a re-activated slot's old fin
        self._subscribed[rep.rid].add(sid)  # must not satisfy close() early
        self.rt._send(self._ctrl[sid], SubscribeMsg(rep.rid, chan,
                                                    want_state=True))

    def remove_replica(self, rid: Optional[int] = None) -> Optional[Replica]:
        """Drain a replica out of the serving rotation (autoscaler
        scale-down).  The replica is marked ``retired`` — the gateway stops
        routing to it immediately — and unsubscribed from every shard; its
        ingest thread keeps draining in-flight publishes until ``close()``
        tears the edges down, so the shard side never blocks on it.  Picks
        the least-loaded live replica when ``rid`` is None; refuses to
        retire the last live one.  Returns the retired replica or None."""
        if self._closed:
            raise RuntimeError("replica set is closed")
        live = [r for r in self.replicas if not (r.retired or r.poisoned)]
        if len(live) <= 1:
            return None                     # never drain the whole tier
        if rid is None:
            rep = min(live, key=lambda r: r.reads)
        else:
            rep = next((r for r in live if r.rid == rid), None)
            if rep is None:
                return None
        rep.retired = True
        for sid in sorted(self._subscribed.get(rep.rid, set())):
            self._subscribed[rep.rid].discard(sid)
            self.rt._send(self._ctrl[sid], UnsubscribeMsg(rep.rid))
        self._notify()                      # wake parked readers to re-pick
        return rep

    @property
    def n_live(self) -> int:
        """Replicas currently in the serving rotation."""
        return sum(1 for r in self.replicas
                   if not (r.retired or r.poisoned))

    def _on_epoch(self, epoch: int, part, added: List[int],
                  removed: List[int]) -> None:
        """Membership listener: re-wire every replica's subscriptions.

        Newly activated slots bootstrap the replica in-stream (state + vc,
        FIFO-before any delta); continuing slots already pushed their own
        re-bootstrap at install, so only the added/removed edges change
        here.  Channels are kept across retire/re-activate cycles so the
        per-channel FIFO sequence stays continuous."""
        if self._closed:
            return
        for rep in self.replicas:
            if rep.retired:
                continue
            for sid in added:
                self._subscribe(rep, sid)
            for sid in removed:
                if sid in self._subscribed.get(rep.rid, ()):
                    self._subscribed[rep.rid].discard(sid)
                    self.rt._send(self._ctrl[sid], UnsubscribeMsg(rep.rid))

    def _make_channel(self, rep: Replica, sid: int):
        """The shard->replica publish edge for the chosen transport.

        Wire-backed edges (shm/tcp) are built with a non-blocking
        ``try_write`` sink so the shard's publish path can drop-and-resync
        instead of stalling on a wedged replica, and with a ``pause`` event
        the fault-injection harness uses to wedge the replica's reader
        deliberately."""
        name = f"s{sid}->r{rep.rid}"
        if self.transport == "queue":
            chan = Channel(name, rep.inbox)
            self._edges[(rep.rid, sid)] = {"kind": "queue", "chan": chan}
            return chan
        pause = threading.Event()
        if self.transport == "shm":
            ring = T.ShmRing.create(self._cap)
            bell_r, bell_w = os.pipe()
            os.set_blocking(bell_w, False)
            stop = threading.Event()
            inner = T.ring_reader(ring, bell_r, stop)

            def read_chunk(inner=inner, pause=pause, stop=stop):
                while pause.is_set() and not stop.is_set():
                    time.sleep(0.005)          # wedged: stop draining
                return inner()

            reader = T.start_reader(f"rx-{name}", read_chunk,
                                    rep.inbox, self._record_error)
            chan = T.WireChannel(name, T.ring_writer(ring, bell_w),
                                 max_frame=self._cap // 2,
                                 try_write=T.try_ring_writer(ring, bell_w),
                                 room=ring.free_bytes)
            self._edges[(rep.rid, sid)] = {
                "kind": "shm", "ring": ring, "bell": (bell_r, bell_w),
                "stop": stop, "reader": reader, "chan": chan, "pause": pause}
            return chan
        # tcp: a real loopback socket per (shard, replica)
        lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lsock.bind(("127.0.0.1", 0))
        lsock.listen(1)
        w_sock = socket.create_connection(lsock.getsockname(), timeout=30)
        r_sock, _ = lsock.accept()
        lsock.close()
        w_conn, r_conn = T.TcpConn(w_sock), T.TcpConn(r_sock)
        inner_tcp = r_conn.read_chunk

        def read_chunk_tcp(inner=inner_tcp, pause=pause):
            while pause.is_set():
                time.sleep(0.005)
            return inner()

        reader = T.start_reader(f"rx-{name}", read_chunk_tcp,
                                rep.inbox, self._record_error)
        chan = T.WireChannel(name, w_conn.write, try_write=w_conn.try_write,
                             room=w_conn.room)
        self._edges[(rep.rid, sid)] = {
            "kind": "tcp", "w": w_conn, "r": r_conn, "reader": reader,
            "chan": chan, "pause": pause}
        return chan

    # -------------------------------------------------------- fault injection
    def wedge(self, rid: int, wedged: bool = True) -> None:
        """Deliberately stop (or resume) draining a replica's publish edges
        — the chaos harness's wedged-replica fault.  Only meaningful on the
        wire transports (an in-process queue edge is unbounded and cannot
        exert backpressure)."""
        for (r, _sid), edge in self._edges.items():
            if r == rid and "pause" in edge:
                if wedged:
                    edge["pause"].set()
                else:
                    edge["pause"].clear()

    @property
    def stale_replicas(self) -> set:
        """Replica ids currently marked stale by at least one shard (their
        next successful publish cycle re-bootstraps them in-stream)."""
        out = set()
        for s in self.rt.shards:
            out |= s._stale_subs
        return out

    @property
    def pub_drops(self) -> int:
        """Publish cycles dropped on a full sink (wedged replicas)."""
        return sum(s.pub_drops for s in self.rt.shards)

    @property
    def pub_resyncs(self) -> int:
        """Successful in-stream re-bootstraps of recovered replicas."""
        return sum(s.pub_resyncs for s in self.rt.shards)

    # ---------------------------------------------------------- vc plumbing
    def master_vc(self) -> np.ndarray:
        """The live per-slot applied vector clocks, stacked (n_slots, P).

        Each shard claims its row only while it owns rows (ownership and vc
        read under one lock): a retired slot drops out at -1, and mid-
        migration both the retiring and the new owner may claim — the max
        in :meth:`staleness` makes that over-requirement, never under."""
        out = np.full((self.rt.n_slots, self.rt.n_proc), -1, dtype=np.int64)
        for s in self.rt.shards:
            vc = s.vc_if_active()
            if vc is not None:
                out[s.sid] = vc
        return out

    @staticmethod
    def staleness(replica_vc: np.ndarray, master_vc: np.ndarray) -> int:
        """Clocks the replica trails the master frontier (0 = caught up)."""
        return max(int((master_vc - replica_vc).max()), 0)

    # ------------------------------------------------------------- teardown
    def close(self, timeout: float = 10.0) -> None:
        """Unsubscribe every replica, wait for the shard fins, tear down."""
        if self._closed:
            return
        self._closed = True
        alive = {s.sid for s in self.rt.shards if s.thread.is_alive()}
        needs = {rep.rid: self._subscribed.get(rep.rid, set()) & alive
                 for rep in self.replicas}
        for rep in self.replicas:
            for sid in sorted(needs[rep.rid]):
                self.rt._send(self._ctrl[sid], UnsubscribeMsg(rep.rid))
        # fins are published FIFO-last: once they land, nothing further
        # will ever be written on the publish channels
        deadline = time.monotonic() + timeout
        with self.cond:
            while (any(not needs[rep.rid] <= rep.fins
                       for rep in self.replicas)
                   and time.monotonic() < deadline):
                self.cond.wait(0.25)
        self._closing = True
        for rep in self.replicas:
            rep.inbox.put(SHUTDOWN)
        for rep in self.replicas:
            rep.thread.join(timeout=5.0)
        for (rid, sid), edge in self._edges.items():
            if "pause" in edge:
                edge["pause"].clear()       # unwedge so readers can exit
            if edge["kind"] == "shm":
                edge["stop"].set()
                T.ShmEdge.ring_bell(edge["bell"][1])
                edge["reader"].join(timeout=5.0)
                edge["ring"].close()
                edge["ring"].unlink()
                for fd in edge["bell"]:
                    try:
                        os.close(fd)
                    except OSError:
                        pass
            elif edge["kind"] == "tcp":
                edge["w"].close()           # FIN ends the reader loop
                edge["reader"].join(timeout=5.0)
                edge["r"].close()
