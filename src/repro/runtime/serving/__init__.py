"""Read-replica serving tier for the PS runtime.

The paper's bound, enforced on the *read* path: a :class:`ReplicaSet` of
read replicas subscribes to the master shards' publish streams over the
existing channel/transport layer (``queue`` | ``shm`` | ``tcp``), each
replica tracking a per-shard vector clock of applied updates, and a
:class:`ReadGateway` routes every read — under a client-declared SLO of
``staleness <= k`` clocks or :data:`FRESH` — to the cheapest replica whose
vector clock satisfies it, parking on a doorbell or escalating to the
master when none does.  Every response is stamped with the staleness
actually measured against the master's applied vector clock, so
``tests/test_serving.py`` asserts the SLO was *honored* for SSP/VAP/CVAP
under free interleavings, making the conformance story three-sided:
simulator spec, write runtime, serving tier.
"""
from repro.runtime.serving.gateway import (FRESH, GatewayStats, ReadGateway,
                                           ReadResult, ReadShedError)
from repro.runtime.serving.replica import (SERVING_TRANSPORTS, Replica,
                                           ReplicaSet)

__all__ = [
    "FRESH", "GatewayStats", "ReadGateway", "ReadResult", "ReadShedError",
    "Replica", "ReplicaSet", "SERVING_TRANSPORTS",
]
