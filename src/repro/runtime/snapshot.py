"""Snapshot/restore of the PS runtime's shard tables (failover, ROADMAP
"runtime follow-ups").

A snapshot captures the **master state** — every shard's dense row blocks,
with the global row ids they map to — at a quiesced point (after
``wait()``, or any moment under the per-shard locks; mid-run snapshots are
consistent per shard but may interleave with in-flight deliveries, exactly
like a parameter server checkpointing without a barrier).

Restore paths:

  * ``ServerShard.load_state(snap["shards"][sid])`` — a killed server shard
    rejoins with its partition intact (same ``n_shards``);
  * ``PSRuntime(..., restore_from=snap)`` — a fresh runtime resumes from
    the snapshot's master values (any ``n_shards``: the master is
    reassembled and re-partitioned), so a restarted server continues where
    the killed one stopped.  Because updates are additive, running clocks
    ``[0, a)`` then resuming for ``[a, b)`` lands on exactly the state of an
    uninterrupted ``[0, b)`` run — asserted in ``tests/test_snapshot.py``.

On-disk format: ``np.savez`` with a JSON header (version, n_shards, key
order, original shapes) plus one ``rows``/``values`` array pair per
(shard, key) — no pickled objects, so snapshots are portable across
refactors of the message/runtime classes.
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict, List, Optional

import numpy as np

SNAPSHOT_VERSION = 1


def take_snapshot(rt) -> dict:
    """Capture master shard state of a :class:`PSRuntime` (see module doc).

    Besides the dense row blocks, the snapshot is stamped with each shard's
    applied per-process vector clock (``clock_vcs``) and the completed-clock
    frontier those vcs imply (``clock``) — what lets a serving-tier replica
    seeded from a snapshot report an honest staleness before its in-stream
    bootstrap arrives.

    Elastic membership: only the *active* shards of the current epoch are
    captured (their row sets cover the master exactly), under the
    membership op lock so a snapshot can never interleave with a live
    re-partition's install window.

    Durability tier: when shards carry a WAL, each active shard's state,
    vc, and log marks are cut under ONE lock acquisition
    (``ServerShard.durability_cut``), and the snapshot gains a ``"wal"``
    entry with per-*slot* logged-part positions (``parts``), per-origin
    applied counts, and max update timestamps — the exact per-slot log
    prefix this snapshot covers, which :func:`recover_to_vc` skips on
    replay and :func:`repro.runtime.wal.prune_segments` truncates by."""
    with rt.membership.op_lock:
        acts = [s for s in rt.shards if rt.partition.owns(s.sid)]
        states, vcs, cut_marks = [], [], {}
        for s in acts:
            st, vc, mk = s.durability_cut()
            states.append(st)
            vcs.append(vc)
            cut_marks[s.sid] = mk
        snap = {
            "version": SNAPSHOT_VERSION,
            "n_shards": len(acts),
            "n_proc": rt.n_proc,
            "clock": min(int(vc.min()) for vc in vcs) + 1,
            "shapes": {k: tuple(v) for k, v in rt._shapes.items()},
            "shards": states,
            "clock_vcs": vcs,
        }
        if any(s.wal is not None for s in rt.shards):
            n_slots = len(rt.shards)
            parts = np.zeros(n_slots, dtype=np.int64)
            applied = np.zeros((n_slots, rt.n_proc), dtype=np.int64)
            max_ts = np.full((n_slots, rt.n_proc), -1, dtype=np.int64)
            for s in rt.shards:
                if s.wal is None:
                    continue
                mk = cut_marks.get(s.sid)
                if mk is None:
                    # inactive slot: its log is sealed/quiescent this
                    # epoch, but read the marks under its lock anyway
                    with s.lock:
                        mk = s.wal.marks()
                parts[s.sid] = mk["parts"]
                applied[s.sid] = mk["applied"]
                max_ts[s.sid] = mk["max_ts"]
            snap["wal"] = {"slots": n_slots, "parts": parts,
                           "applied": applied, "max_ts": max_ts}
        return snap


def assemble_master(snap: dict) -> Dict[str, np.ndarray]:
    """Reassemble the full flat (R, C) master value per key."""
    shapes = snap["shapes"]
    out: Dict[str, np.ndarray] = {}
    for key, shape in shapes.items():
        r = shape[0] if len(shape) else 1
        c = int(np.prod(shape[1:])) if len(shape) > 1 else 1
        full = np.zeros((r, c) if len(shape) else (1, 1), dtype=np.float64)
        seen = 0
        for part in snap["shards"]:
            piece = part[key]
            full[piece["rows"]] = piece["values"]
            seen += len(piece["rows"])
        if seen != full.shape[0]:
            raise ValueError(f"snapshot incomplete for {key!r}: "
                             f"{seen}/{full.shape[0]} rows")
        out[key] = full
    return out


def validate_vcs(snap: dict) -> None:
    """Refuse a snapshot whose vector-clock stamps are malformed or
    internally inconsistent (tampering, truncation, bit rot): every vc must
    be a 1-D integer array of ``n_proc`` entries, each in ``[-1, 2^48)``,
    and the stamped completed-clock frontier must equal the frontier the
    vcs imply.  A corrupted vc would let a serving replica stamp stale
    values as fresh, so a bad snapshot is rejected loudly instead."""
    vcs = snap.get("clock_vcs")
    if not vcs:
        return
    n_proc = snap.get("n_proc")
    for sid, vc in enumerate(vcs):
        a = np.asarray(vc)
        if (a.ndim != 1 or not np.issubdtype(a.dtype, np.integer)
                or (n_proc is not None and a.shape[0] != n_proc)):
            raise ValueError(
                f"snapshot vector clock for shard {sid} is malformed "
                f"(shape {a.shape}, dtype {a.dtype}); refusing to restore")
        if a.size and (int(a.min()) < -1 or int(a.max()) >= 1 << 48):
            raise ValueError(
                f"snapshot vector clock for shard {sid} has out-of-range "
                f"entries ({a.tolist()}); refusing a tampered snapshot")
    clock = snap.get("clock")
    if clock is not None:
        implied = min(int(np.asarray(vc).min()) for vc in vcs) + 1
        if clock != implied:
            raise ValueError(
                f"snapshot clock stamp {clock} contradicts its vector "
                f"clocks (implied {implied}); refusing a tampered snapshot")


def conservative_vc(snap: dict, n_shards: int, n_proc: int) -> np.ndarray:
    """Per-(shard, process) vector-clock seed for a serving-tier replica
    bootstrapping from this snapshot: the per-process minimum across the
    snapshot's shards, broadcast to ``n_shards``.  A valid lower bound for
    every current shard even when the shard count changed since the snapshot
    (the same re-partition-safety argument as :func:`assemble_master`);
    falls back to the all ``-1`` vc when the snapshot predates vc stamping
    or the process count differs."""
    validate_vcs(snap)
    vcs = snap.get("clock_vcs")
    if not vcs or snap.get("n_proc") != n_proc:
        return np.full((n_shards, n_proc), -1, dtype=np.int64)
    lo = np.min(np.stack(vcs), axis=0)
    return np.tile(lo, (n_shards, 1)).astype(np.int64)


def snapshot_params(snap: dict) -> Dict[str, np.ndarray]:
    """Snapshot master values in their original shapes — ready to pass as
    ``init_params`` of a resuming runtime (equivalent to ``restore_from``)."""
    master = assemble_master(snap)
    return {k: master[k].reshape(snap["shapes"][k]) for k in master}


def restore_into(rt, snap: dict) -> None:
    """Adopt snapshot master values into a freshly constructed runtime.

    Called from ``PSRuntime.__init__(restore_from=...)`` after the shards
    are built and before any client state exists: both the shard blocks and
    the runtime's x0 (which seeds every process cache) take the snapshot
    values, so eventual-consistency checks remain exact.
    """
    if snap.get("version") != SNAPSHOT_VERSION:
        raise ValueError(f"unsupported snapshot version {snap.get('version')}")
    validate_vcs(snap)
    master = assemble_master(snap)
    if set(master) != set(rt._x0):
        raise ValueError(f"snapshot keys {sorted(master)} != runtime keys "
                         f"{sorted(rt._x0)}")
    for key, full in master.items():
        if tuple(snap["shapes"][key]) != tuple(rt._shapes[key]):
            raise ValueError(f"snapshot shape mismatch for {key!r}: "
                             f"{snap['shapes'][key]} != {rt._shapes[key]}")
        rt._x0[key][...] = full
        for shard in rt.shards:
            rows = rt.partition.rows_of(key, shard.sid)
            shard.dense[key][...] = full[rows]


def save_snapshot(path, snap: dict) -> None:
    """Write a snapshot to ``path`` (``.npz``), no pickled objects."""
    keys: List[str] = sorted(snap["shapes"])
    header = {
        "version": snap["version"],
        "n_shards": snap["n_shards"],
        "n_proc": snap.get("n_proc"),
        "clock": snap.get("clock"),
        "keys": keys,
        "shapes": {k: list(snap["shapes"][k]) for k in keys},
    }
    wal = snap.get("wal")
    if wal is not None:
        header["wal_slots"] = int(wal["slots"])
    arrays = {"header": np.frombuffer(
        json.dumps(header).encode(), dtype=np.uint8)}
    for sid, part in enumerate(snap["shards"]):
        for ki, key in enumerate(keys):
            arrays[f"s{sid}_k{ki}_rows"] = part[key]["rows"]
            arrays[f"s{sid}_k{ki}_values"] = part[key]["values"]
    for sid, vc in enumerate(snap.get("clock_vcs") or []):
        arrays[f"s{sid}_vc"] = vc
    if wal is not None:
        arrays["wal_parts"] = np.asarray(wal["parts"], dtype=np.int64)
        arrays["wal_applied"] = np.asarray(wal["applied"], dtype=np.int64)
        arrays["wal_max_ts"] = np.asarray(wal["max_ts"], dtype=np.int64)
    np.savez(path, **arrays)


def load_snapshot(path) -> dict:
    """Inverse of :func:`save_snapshot`."""
    with np.load(path) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode())
        keys = header["keys"]
        shards = []
        vcs = []
        for sid in range(header["n_shards"]):
            part = {}
            for ki, key in enumerate(keys):
                part[key] = {"rows": z[f"s{sid}_k{ki}_rows"],
                             "values": z[f"s{sid}_k{ki}_values"]}
            shards.append(part)
            if f"s{sid}_vc" in z:
                vcs.append(z[f"s{sid}_vc"])
        wal = None
        if header.get("wal_slots") is not None:
            wal = {"slots": header["wal_slots"],
                   "parts": z["wal_parts"],
                   "applied": z["wal_applied"],
                   "max_ts": z["wal_max_ts"]}
    out = {
        "version": header["version"],
        "n_shards": header["n_shards"],
        "shapes": {k: tuple(s) for k, s in header["shapes"].items()},
        "shards": shards,
    }
    if header.get("n_proc") is not None:
        out["n_proc"] = header["n_proc"]
    if header.get("clock") is not None:
        out["clock"] = header["clock"]
    if vcs:
        out["clock_vcs"] = vcs
    if wal is not None:
        out["wal"] = wal
    return out


# ---------------------------------------------------------------------------
# exact-clock recovery: snapshot + replay(log, upto_vc)  (durability tier)
# ---------------------------------------------------------------------------

_SNAP_RE = re.compile(r"^snap_c(\d+)\.npz$")


def _snapshot_files(snapshot_dir: str) -> List[tuple]:
    """``[(clock, path), ...]`` newest first."""
    if not snapshot_dir or not os.path.isdir(snapshot_dir):
        return []
    out = []
    for f in os.listdir(snapshot_dir):
        m = _SNAP_RE.match(f)
        if m:
            out.append((int(m.group(1)), os.path.join(snapshot_dir, f)))
    out.sort(reverse=True)
    return out


def _pick_covered_snapshot(snapshot_dir: str,
                           upto: Optional[np.ndarray]) -> Optional[dict]:
    """Newest on-disk periodic snapshot usable as a replay base: it must
    carry WAL positional marks (``"wal"``), and — for a point-in-time
    target — must not already fold in any update past ``upto`` (a snapshot
    cannot be un-applied).  Snapshots failing coverage fall through to
    older ones (then genesis); a *corrupt* snapshot raises instead of
    being silently skipped."""
    for _, path in _snapshot_files(snapshot_dir):
        snap = load_snapshot(path)
        validate_vcs(snap)
        wal = snap.get("wal")
        if wal is None:
            continue           # no positional marks: prefix unknown
        if upto is not None and (
                np.asarray(wal["max_ts"]).max(axis=0) > upto).any():
            continue           # contains updates past the target point
        return snap
    return None


def _infer_n_proc(logs: dict) -> int:
    n = 0
    for recs in logs.values():
        for _, records, _ in recs:
            for kind, val in records:
                if kind == "vc":
                    n = max(n, int(np.asarray(val.clock_vc).shape[0]))
                else:
                    for m in val:
                        n = max(n, m.process + 1)
    if n == 0:
        raise ValueError(
            "cannot infer n_proc from an empty wal; pass n_proc=")
    return n


def recover_to_vc(init_params, wal_dir: str, *,
                  snapshot_dir: Optional[str] = None,
                  snapshot: Optional[dict] = None,
                  upto_vc=None, n_proc: Optional[int] = None) -> dict:
    """Rebuild exact master state from ``snapshot + replay(log, upto_vc)``.

    ``init_params`` is the same initial table dict the runtime was
    constructed with (it fixes key order — and therefore the wire codec —
    plus shapes and the additive baseline).  The newest usable periodic
    snapshot under ``snapshot_dir`` (or the explicit ``snapshot``) seeds
    the state and positions replay at the per-slot logged-part prefix it
    covers (``snap["wal"]["parts"]``); every later part in the per-shard
    logs under ``wal_dir`` is re-applied with ``np.add.at`` onto the
    full-key buffers.  With no usable snapshot, recovery replays the full
    log from genesis.

    ``upto_vc`` (point-in-time restore): per-origin-process clock vector;
    parts timestamped past their origin's entry are excluded, yielding the
    exact state at that vector-clock cut — updates are additive and
    commutative, so the cut equals what a run stopped at that frontier
    would hold.

    Replay is **idempotent**: a per-slot :class:`~repro.runtime.shard.
    UidDedup` drops uid-level duplicates across the kill epoch, with its
    frontier advanced by the vc stamps in the log (each stamp is validated
    via :func:`validate_vcs` — a tampered/out-of-range stamp is refused
    loudly).  Torn segment tails (kill mid-write) are dropped by
    :func:`repro.runtime.wal.read_segment`.

    Returns ``{"params", "applied_parts", "clock_vc", "clock",
    "n_replayed", "n_deduped", "from_snapshot"}`` where ``applied_parts``
    is the per-origin-process count of parts folded into ``params``
    (snapshot-covered + replayed) — the number the runtime's
    zero-lost/zero-duplicated counter audit compares against.
    """
    from repro.runtime.shard import UidDedup
    from repro.runtime.transport import RowCodec
    from repro.runtime.wal import read_segment, wal_segments

    # canonical flat (R, C) float64 buffers, exactly like PSRuntime.__init__
    shapes: Dict[str, tuple] = {}
    flat: Dict[str, np.ndarray] = {}
    for key, v in init_params.items():
        a = np.asarray(v, dtype=np.float64)
        shapes[key] = a.shape
        f = a.reshape(a.shape[0], -1) if a.ndim > 1 else a.reshape(-1, 1)
        flat[key] = f.copy()
    codec = RowCodec(list(init_params.keys()))

    upto = None
    if upto_vc is not None:
        upto = np.asarray(upto_vc, dtype=np.int64).reshape(-1)
        if n_proc is None:
            n_proc = int(upto.shape[0])

    # decode every slot's log up front (cold path; segments are bounded by
    # rotation + retention) — genesis recovery infers n_proc from it
    logs = {sid: [(start, *read_segment(path, codec))
                  for start, path in seg_list]
            for sid, seg_list in wal_segments(wal_dir).items()}

    snap = snapshot
    if snap is None and snapshot_dir is not None:
        snap = _pick_covered_snapshot(snapshot_dir, upto)
    if snap is not None:
        if snap.get("version") != SNAPSHOT_VERSION:
            raise ValueError(
                f"unsupported snapshot version {snap.get('version')}")
        validate_vcs(snap)
        if snap.get("wal") is None:
            raise ValueError("snapshot carries no wal marks; cannot "
                             "position replay (take it with wal_dir set)")
        if n_proc is None:
            n_proc = snap.get("n_proc")
    if n_proc is None:
        n_proc = _infer_n_proc(logs)

    skip_parts: Dict[int, int] = {}
    applied = np.zeros(n_proc, dtype=np.int64)
    frontier = np.full(n_proc, -1, dtype=np.int64)
    if snap is not None:
        wal = snap["wal"]
        for sid, p in enumerate(np.asarray(wal["parts"])):
            skip_parts[sid] = int(p)
        applied += np.asarray(wal["applied"], dtype=np.int64).sum(axis=0)
        np.maximum(frontier,
                   np.asarray(wal["max_ts"], dtype=np.int64).max(axis=0),
                   out=frontier)
        master = assemble_master(snap)
        if set(master) != set(flat):
            raise ValueError(f"snapshot keys {sorted(master)} != "
                             f"init_params keys {sorted(flat)}")
        for key, full in master.items():
            if full.shape != flat[key].shape:
                raise ValueError(f"snapshot shape mismatch for {key!r}: "
                                 f"{full.shape} != {flat[key].shape}")
            flat[key][...] = full

    n_replayed = 0
    n_deduped = 0
    for sid in sorted(logs):
        # per-SLOT dedup: stamps only order a single slot's log, and uids
        # are only unique per (process, slot-log) — a shared frontier
        # advanced by one slot's stamps would false-drop another's parts
        dedup = UidDedup(n_proc)
        cover = skip_parts.get(sid, 0)
        for start, records, _sealed in logs[sid]:
            pos = start
            for kind, val in records:
                if kind == "vc":
                    stamp = np.asarray(val.clock_vc)
                    validate_vcs({"clock_vcs": [stamp], "n_proc": n_proc})
                    for p in range(n_proc):
                        c = int(stamp[p])
                        if upto is not None:
                            c = min(c, int(upto[p]))
                        dedup.advance(p, c)
                    continue
                for m in val:
                    at, pos = pos, pos + 1
                    if at < cover:
                        continue        # inside the snapshot's prefix
                    if upto is not None and m.ts > upto[m.process]:
                        continue        # past the point-in-time target
                    if not dedup.fresh(m.uid, m.process, m.ts):
                        n_deduped += 1
                        continue
                    np.add.at(flat[m.key], np.asarray(m.rows),
                              np.asarray(m.delta))
                    applied[m.process] += 1
                    n_replayed += 1
                    if m.ts > frontier[m.process]:
                        frontier[m.process] = m.ts

    return {
        "params": {k: flat[k].reshape(shapes[k]) for k in flat},
        "applied_parts": applied,
        "clock_vc": frontier,
        "clock": int(frontier.min()) + 1 if n_proc else 0,
        "n_replayed": n_replayed,
        "n_deduped": n_deduped,
        "from_snapshot": None if snap is None else snap.get("clock"),
    }
