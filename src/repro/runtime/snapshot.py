"""Snapshot/restore of the PS runtime's shard tables (failover, ROADMAP
"runtime follow-ups").

A snapshot captures the **master state** — every shard's dense row blocks,
with the global row ids they map to — at a quiesced point (after
``wait()``, or any moment under the per-shard locks; mid-run snapshots are
consistent per shard but may interleave with in-flight deliveries, exactly
like a parameter server checkpointing without a barrier).

Restore paths:

  * ``ServerShard.load_state(snap["shards"][sid])`` — a killed server shard
    rejoins with its partition intact (same ``n_shards``);
  * ``PSRuntime(..., restore_from=snap)`` — a fresh runtime resumes from
    the snapshot's master values (any ``n_shards``: the master is
    reassembled and re-partitioned), so a restarted server continues where
    the killed one stopped.  Because updates are additive, running clocks
    ``[0, a)`` then resuming for ``[a, b)`` lands on exactly the state of an
    uninterrupted ``[0, b)`` run — asserted in ``tests/test_snapshot.py``.

On-disk format: ``np.savez`` with a JSON header (version, n_shards, key
order, original shapes) plus one ``rows``/``values`` array pair per
(shard, key) — no pickled objects, so snapshots are portable across
refactors of the message/runtime classes.
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

SNAPSHOT_VERSION = 1


def take_snapshot(rt) -> dict:
    """Capture master shard state of a :class:`PSRuntime` (see module doc).

    Besides the dense row blocks, the snapshot is stamped with each shard's
    applied per-process vector clock (``clock_vcs``) and the completed-clock
    frontier those vcs imply (``clock``) — what lets a serving-tier replica
    seeded from a snapshot report an honest staleness before its in-stream
    bootstrap arrives.

    Elastic membership: only the *active* shards of the current epoch are
    captured (their row sets cover the master exactly), under the
    membership op lock so a snapshot can never interleave with a live
    re-partition's install window."""
    with rt.membership.op_lock:
        acts = [s for s in rt.shards if rt.partition.owns(s.sid)]
        vcs = [s.vc_snapshot() for s in acts]
        return {
            "version": SNAPSHOT_VERSION,
            "n_shards": len(acts),
            "n_proc": rt.n_proc,
            "clock": min(int(vc.min()) for vc in vcs) + 1,
            "shapes": {k: tuple(v) for k, v in rt._shapes.items()},
            "shards": [s.state() for s in acts],
            "clock_vcs": vcs,
        }


def assemble_master(snap: dict) -> Dict[str, np.ndarray]:
    """Reassemble the full flat (R, C) master value per key."""
    shapes = snap["shapes"]
    out: Dict[str, np.ndarray] = {}
    for key, shape in shapes.items():
        r = shape[0] if len(shape) else 1
        c = int(np.prod(shape[1:])) if len(shape) > 1 else 1
        full = np.zeros((r, c) if len(shape) else (1, 1), dtype=np.float64)
        seen = 0
        for part in snap["shards"]:
            piece = part[key]
            full[piece["rows"]] = piece["values"]
            seen += len(piece["rows"])
        if seen != full.shape[0]:
            raise ValueError(f"snapshot incomplete for {key!r}: "
                             f"{seen}/{full.shape[0]} rows")
        out[key] = full
    return out


def validate_vcs(snap: dict) -> None:
    """Refuse a snapshot whose vector-clock stamps are malformed or
    internally inconsistent (tampering, truncation, bit rot): every vc must
    be a 1-D integer array of ``n_proc`` entries, each in ``[-1, 2^48)``,
    and the stamped completed-clock frontier must equal the frontier the
    vcs imply.  A corrupted vc would let a serving replica stamp stale
    values as fresh, so a bad snapshot is rejected loudly instead."""
    vcs = snap.get("clock_vcs")
    if not vcs:
        return
    n_proc = snap.get("n_proc")
    for sid, vc in enumerate(vcs):
        a = np.asarray(vc)
        if (a.ndim != 1 or not np.issubdtype(a.dtype, np.integer)
                or (n_proc is not None and a.shape[0] != n_proc)):
            raise ValueError(
                f"snapshot vector clock for shard {sid} is malformed "
                f"(shape {a.shape}, dtype {a.dtype}); refusing to restore")
        if a.size and (int(a.min()) < -1 or int(a.max()) >= 1 << 48):
            raise ValueError(
                f"snapshot vector clock for shard {sid} has out-of-range "
                f"entries ({a.tolist()}); refusing a tampered snapshot")
    clock = snap.get("clock")
    if clock is not None:
        implied = min(int(np.asarray(vc).min()) for vc in vcs) + 1
        if clock != implied:
            raise ValueError(
                f"snapshot clock stamp {clock} contradicts its vector "
                f"clocks (implied {implied}); refusing a tampered snapshot")


def conservative_vc(snap: dict, n_shards: int, n_proc: int) -> np.ndarray:
    """Per-(shard, process) vector-clock seed for a serving-tier replica
    bootstrapping from this snapshot: the per-process minimum across the
    snapshot's shards, broadcast to ``n_shards``.  A valid lower bound for
    every current shard even when the shard count changed since the snapshot
    (the same re-partition-safety argument as :func:`assemble_master`);
    falls back to the all ``-1`` vc when the snapshot predates vc stamping
    or the process count differs."""
    validate_vcs(snap)
    vcs = snap.get("clock_vcs")
    if not vcs or snap.get("n_proc") != n_proc:
        return np.full((n_shards, n_proc), -1, dtype=np.int64)
    lo = np.min(np.stack(vcs), axis=0)
    return np.tile(lo, (n_shards, 1)).astype(np.int64)


def snapshot_params(snap: dict) -> Dict[str, np.ndarray]:
    """Snapshot master values in their original shapes — ready to pass as
    ``init_params`` of a resuming runtime (equivalent to ``restore_from``)."""
    master = assemble_master(snap)
    return {k: master[k].reshape(snap["shapes"][k]) for k in master}


def restore_into(rt, snap: dict) -> None:
    """Adopt snapshot master values into a freshly constructed runtime.

    Called from ``PSRuntime.__init__(restore_from=...)`` after the shards
    are built and before any client state exists: both the shard blocks and
    the runtime's x0 (which seeds every process cache) take the snapshot
    values, so eventual-consistency checks remain exact.
    """
    if snap.get("version") != SNAPSHOT_VERSION:
        raise ValueError(f"unsupported snapshot version {snap.get('version')}")
    validate_vcs(snap)
    master = assemble_master(snap)
    if set(master) != set(rt._x0):
        raise ValueError(f"snapshot keys {sorted(master)} != runtime keys "
                         f"{sorted(rt._x0)}")
    for key, full in master.items():
        if tuple(snap["shapes"][key]) != tuple(rt._shapes[key]):
            raise ValueError(f"snapshot shape mismatch for {key!r}: "
                             f"{snap['shapes'][key]} != {rt._shapes[key]}")
        rt._x0[key][...] = full
        for shard in rt.shards:
            rows = rt.partition.rows_of(key, shard.sid)
            shard.dense[key][...] = full[rows]


def save_snapshot(path, snap: dict) -> None:
    """Write a snapshot to ``path`` (``.npz``), no pickled objects."""
    keys: List[str] = sorted(snap["shapes"])
    header = {
        "version": snap["version"],
        "n_shards": snap["n_shards"],
        "n_proc": snap.get("n_proc"),
        "clock": snap.get("clock"),
        "keys": keys,
        "shapes": {k: list(snap["shapes"][k]) for k in keys},
    }
    arrays = {"header": np.frombuffer(
        json.dumps(header).encode(), dtype=np.uint8)}
    for sid, part in enumerate(snap["shards"]):
        for ki, key in enumerate(keys):
            arrays[f"s{sid}_k{ki}_rows"] = part[key]["rows"]
            arrays[f"s{sid}_k{ki}_values"] = part[key]["values"]
    for sid, vc in enumerate(snap.get("clock_vcs") or []):
        arrays[f"s{sid}_vc"] = vc
    np.savez(path, **arrays)


def load_snapshot(path) -> dict:
    """Inverse of :func:`save_snapshot`."""
    with np.load(path) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode())
        keys = header["keys"]
        shards = []
        vcs = []
        for sid in range(header["n_shards"]):
            part = {}
            for ki, key in enumerate(keys):
                part[key] = {"rows": z[f"s{sid}_k{ki}_rows"],
                             "values": z[f"s{sid}_k{ki}_values"]}
            shards.append(part)
            if f"s{sid}_vc" in z:
                vcs.append(z[f"s{sid}_vc"])
    out = {
        "version": header["version"],
        "n_shards": header["n_shards"],
        "shapes": {k: tuple(s) for k, s in header["shapes"].items()},
        "shards": shards,
    }
    if header.get("n_proc") is not None:
        out["n_proc"] = header["n_proc"]
    if header.get("clock") is not None:
        out["clock"] = header["clock"]
    if vcs:
        out["clock_vcs"] = vcs
    return out
