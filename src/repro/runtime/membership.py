"""Elastic shard membership: live re-partitioning of the PS runtime.

The paper's bounds (SSP clock bound, VAP value bound) are only production-
grade if they survive membership change — *Elastic Consistency* (Nadiradze
et al., 2001.05918) shows bounded-staleness SGD tolerates exactly the
transient divergence a live re-partition introduces, and this module makes
the runtime exploit that: shards can be added and removed **mid-run**, with
the consistency bounds asserted across (not just after) the migration.

Slot model
----------
``PSRuntime(n_shards=S, max_shards=M)`` provisions ``M`` shard *slots* at
construction — shard objects, threads, and channels (for every transport:
in-process queues, shm rings, tcp loopback) all exist up front, but only
``S`` slots are *active* in epoch 0.  Pre-provisioning is what makes
elasticity transport-uniform: forked clients inherit shm mappings and tcp
connections that cannot be created after the fork, while activation and
retirement are pure control-plane events.  Retired slots keep their threads
and channels until quiesce so in-flight deliveries and acks drain naturally.

Epoch protocol (one membership op = one epoch bump)
---------------------------------------------------
Shards always live in the parent process, so row migration never crosses
the wire — only the epoch *barrier* involves clients:

1. **Begin** — the manager enqueues ``EpochBeginMsg(epoch, part)`` to every
   involved shard slot (old ∪ new active), then announces
   ``EpochMsg(epoch, active)`` to every client over a designated active
   shard's FIFO channel.
2. **Swap + ack** — each client process, on receiving the announce, swaps
   its key→shard router atomically w.r.t. its own sends (a short
   ``route_lock`` critical section excludes in-flight flushes; routing is
   deferred to flush time so an SSP outbox filled under epoch e but flushed
   after the swap routes by e+1), then sends ``EpochAckMsg`` on every
   involved channel.  Channel FIFO makes the ack a barrier: no epoch-e
   update can follow it.
3. **Cut + handoff** — a shard active in epoch e that has collected acks
   from *every* client process will never see another epoch-e update; it
   freezes its partition (``state()`` + applied vector clock — the
   vc-stamped snapshot payload format) and hands it to the manager.  A
   *retiring* slot additionally broadcasts ``ClockMarker(clock=INF)`` to
   every client — FIFO-behind all deliveries it ever sent — so it stops
   constraining the clock frontier exactly when its stream is complete.
4. **Install** — the manager reassembles the master through the snapshot
   re-partition path (:func:`repro.runtime.snapshot.assemble_master`) and
   installs each new-active slot's dense partition plus a conservative
   vector-clock seed (element-wise min over contributors).  New-active
   slots install first, retirees disclaim last, so at every instant at
   least one shard's applied vc vouches for every applied update (the
   serving tier's staleness measurement stays conservative mid-migration).
5. **Replay** — updates/clocks stamped with the *next* epoch that raced
   ahead of the install were held FIFO at the shard; they replay through
   the normal apply/publish path, then the shard broadcasts *seeded*
   clock markers from its post-replay vc so clients' frontiers unblock
   (install happens only after every client acked, i.e. swapped — a seeded
   marker can never overtake its receiver's swap).

During the (short) freeze the clock-bound gate simply blocks — the same
mechanism that absorbs a straggler absorbs the migration — and the value
bound is untouched because delivery/ack accounting is key-global, not
partition-local.  No update is lost or double-applied: epoch-e updates are
applied by their epoch-e owner and included in the handoff; epoch-e+1
updates are held and replayed by their e+1 owner; the per-process counter
audit in ``PSRuntime._final_checks`` asserts exactly this.

Serving tier: the manager notifies listeners after install; the
:class:`~repro.runtime.serving.replica.ReplicaSet` re-subscribes every
replica to newly-active slots (the shard answers with an in-stream
re-bootstrap: dense partition + vc stamp, FIFO-before subsequent deltas)
and unsubscribes retired ones.
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime import trace as trace_mod
from repro.runtime.messages import (Channel, EpochBeginMsg, EpochMsg,
                                    InstallMsg)

log = logging.getLogger("repro.runtime.membership")

# "infinitely caught up": a retired slot's frontier contribution
INF_CLOCK = 1 << 60

_EMPTY_ROWS = np.empty(0, dtype=np.int64)


class Partition:
    """Epoch-stamped ownership map: row ``r`` of every key is owned by
    ``active[r % len(active)]`` and stored at local index ``r // len(active)``
    in the owner's dense block.

    Immutable; built deterministically from ``(epoch, active, row_counts)``
    so a forked client can reconstruct the parent's partition from the
    ``(epoch, active)`` pair an :class:`EpochMsg` carries.
    """

    def __init__(self, epoch: int, active: Sequence[int],
                 row_counts: Dict[str, int]):
        if not active:
            raise ValueError("a partition needs at least one active shard")
        self.epoch = epoch
        self.active: Tuple[int, ...] = tuple(active)
        self.A = len(self.active)
        self._index = {sid: i for i, sid in enumerate(self.active)}
        self._rows: Dict[str, List[np.ndarray]] = {}
        for key, r in row_counts.items():
            rows = np.arange(r, dtype=np.int64)
            self._rows[key] = [np.ascontiguousarray(rows[rows % self.A == i])
                               for i in range(self.A)]

    def owns(self, sid: int) -> bool:
        return sid in self._index

    def rows_of(self, key: str, sid: int) -> np.ndarray:
        """Global row ids of ``key`` owned by slot ``sid`` (empty if the
        slot is inactive in this epoch)."""
        i = self._index.get(sid)
        if i is None:
            return _EMPTY_ROWS
        return self._rows[key][i]

    def __repr__(self) -> str:
        return f"Partition(epoch={self.epoch}, active={self.active})"


@dataclass
class MembershipEvent:
    """One scripted membership change, fired when the global completed-clock
    frontier reaches ``clock``.  ``op`` is ``"add"`` (sid optional: the
    lowest free slot) or ``"remove"`` (sid required)."""
    clock: int
    op: str
    sid: Optional[int] = None


@dataclass
class MembershipPlan:
    """A scriptable schedule of membership events for tests and benches —
    pass as ``PSRuntime(membership_plan=...)``; a driver thread fires each
    event at its clock boundary.  ``results`` records ``(event, outcome)``
    pairs; events unreachable because the run ended first are ``"skipped"``."""
    events: List[MembershipEvent] = field(default_factory=list)
    results: List[Tuple[MembershipEvent, str]] = field(default_factory=list)

    @classmethod
    def parse(cls, spec: Sequence[Tuple[int, str, Optional[int]]]
              ) -> "MembershipPlan":
        """From ``[(clock, "add"|"remove", sid_or_None), ...]``."""
        evs = [MembershipEvent(c, op, sid) for c, op, sid in spec]
        return cls(sorted(evs, key=lambda e: e.clock))


class MembershipManager:
    """Parent-side coordinator of the epoch protocol (module docstring).

    ``op_lock`` (re-entrant) serializes membership ops and is the
    synchronization point for whole-master readers: ``master_value`` and
    ``take_snapshot`` hold it so they never observe a half-installed
    partition; the shard-thread periodic-snapshot path acquires it
    non-blocking and skips a cycle instead of deadlocking against an
    in-flight install.
    """

    def __init__(self, rt):
        self.rt = rt
        self.inbox: queue.Queue = queue.Queue()   # shard -> manager (in-parent)
        self.op_lock = threading.RLock()
        self.log: List[Tuple[int, Tuple[int, ...]]] = []   # (epoch, active)
        self._listeners: List[Callable] = []
        self._ctrl = [Channel(f"mm->s{s.sid}", s.inbox) for s in rt.shards]
        self._plan_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- listeners
    def add_listener(self, fn: Callable) -> None:
        """``fn(epoch, partition, added_sids, removed_sids)`` after each
        completed op (called on the op thread, after install everywhere)."""
        self._listeners.append(fn)

    # ------------------------------------------------------------------ ops
    def add_shard(self, sid: Optional[int] = None, timeout: float = 30.0) -> int:
        """Activate a dormant slot mid-run; returns its sid.  Blocks until
        the epoch is installed everywhere (rows migrated, clients swapped)."""
        with self.op_lock:
            old = self.rt.partition
            free = [s for s in range(self.rt.n_slots) if not old.owns(s)]
            if sid is None:
                if not free:
                    raise ValueError(
                        f"no free shard slot (all {self.rt.n_slots} active); "
                        "construct the runtime with a larger max_shards")
                sid = free[0]
            elif old.owns(sid):
                raise ValueError(f"shard slot {sid} is already active")
            elif not 0 <= sid < self.rt.n_slots:
                raise ValueError(f"shard slot {sid} out of range "
                                 f"(0..{self.rt.n_slots - 1})")
            self._run_op(tuple(sorted(old.active + (sid,))), timeout)
            return sid

    def remove_shard(self, sid: int, timeout: float = 30.0) -> None:
        """Retire an active slot mid-run: its rows migrate to the survivors
        via the vc-stamped snapshot re-partition path."""
        with self.op_lock:
            old = self.rt.partition
            if not old.owns(sid):
                raise ValueError(f"shard slot {sid} is not active")
            if old.A == 1:
                raise ValueError("cannot remove the last active shard")
            self._run_op(tuple(s for s in old.active if s != sid), timeout)

    def _run_op(self, new_active: Tuple[int, ...], timeout: float) -> None:
        rt = self.rt
        if not rt._started or rt._finished:
            raise RuntimeError("membership ops require a running runtime")
        deadline = time.monotonic() + timeout
        old = rt.partition
        epoch = old.epoch + 1
        part = Partition(epoch, new_active, rt._row_counts)
        involved = sorted(set(old.active) | set(new_active))
        added = [s for s in new_active if not old.owns(s)]
        removed = [s for s in old.active if s not in part._index]

        # 1) shards learn the pending epoch (enqueued before any client ack
        #    can arrive, so each shard processes Begin first)
        for sid in involved:
            rt._send(self._ctrl[sid], EpochBeginMsg(epoch, part))
        # 2) announce to every client over a surviving active shard's FIFO
        #    channel (the channel lock makes the parent-side send safe
        #    alongside the shard thread's own publishes)
        leader = min(set(old.active) & set(new_active), default=old.active[0])
        for p in range(rt.n_proc):
            rt._send(rt._chan_sp[leader][p],
                     EpochMsg(epoch, part.active, shard=leader))
        # 3) every old-active shard cuts once all clients acked and hands
        #    off its frozen partition + applied vector clock
        states: Dict[int, dict] = {}
        vcs: Dict[int, np.ndarray] = {}
        want = set(old.active)
        while set(states) < want:
            kind, sid, payload = self._next_msg(deadline, f"handoff {want}")
            if kind == "handoff" and sid in want:
                states[sid], vcs[sid] = payload
        # 4) reassemble through the snapshot re-partition path and install:
        #    new-active slots first, retirees disclaim last, so every
        #    applied update is vouched for by some shard's vc at all times
        from repro.runtime import snapshot as SNAP
        snap = {"shapes": {k: tuple(v) for k, v in rt._shapes.items()},
                "shards": [states[s] for s in old.active]}
        master = SNAP.assemble_master(snap)
        seed_vc = np.min(np.stack([vcs[s] for s in old.active]), axis=0)
        for sid in new_active:
            blocks = {key: np.ascontiguousarray(master[key][
                part.rows_of(key, sid)]) for key in master}
            rt._send(self._ctrl[sid], InstallMsg(epoch, part, blocks,
                                                 seed_vc.copy()))
        self._await_installs(set(new_active), epoch, deadline)
        for sid in removed:
            rt._send(self._ctrl[sid], InstallMsg(epoch, part, None,
                                                 seed_vc.copy()))
        self._await_installs(set(removed), epoch, deadline)
        rt.partition = part
        self.log.append((epoch, part.active))
        if rt.trace_on:
            rt._trace.point(trace_mod.EV_EPOCH, epoch, part.A)
        # durability tier: retiring slots already sealed their WAL segments
        # shard-side at the cut (step 3, stamped with their final vc); the
        # runtime hook just records the per-slot log positions of this cut
        hook = getattr(rt, "_wal_on_epoch", None)
        if hook is not None:
            hook(epoch, added, removed)
        for fn in self._listeners:
            fn(epoch, part, added, removed)

    def _next_msg(self, deadline: float, what: str):
        budget = deadline - time.monotonic()
        if budget <= 0:
            log.warning("membership op timed out waiting for %s "
                        "(epoch %d active)", what, self.rt.partition.epoch)
            raise RuntimeError(f"membership op timed out waiting for {what}")
        try:
            return self.inbox.get(timeout=budget)
        except queue.Empty:
            log.warning("membership op timed out waiting for %s "
                        "(epoch %d active)", what, self.rt.partition.epoch)
            raise RuntimeError(
                f"membership op timed out waiting for {what}") from None

    def _await_installs(self, sids: set, epoch: int, deadline: float) -> None:
        done: set = set()
        while done < sids:
            kind, sid, payload = self._next_msg(
                deadline, f"install confirms {sids - done}")
            if kind == "installed" and payload == epoch:
                done.add(sid)

    # ------------------------------------------------------------------ plan
    def start_plan(self, plan: MembershipPlan) -> None:
        """Launch the scripted-membership driver (called from start())."""
        self._plan_thread = threading.Thread(
            target=self._drive_plan, args=(plan,), name="ps-membership-plan",
            daemon=True)
        self._plan_thread.start()

    def _drive_plan(self, plan: MembershipPlan) -> None:
        rt = self.rt
        for ev in plan.events:
            while rt.completed_clock() < ev.clock:
                if not rt.running:
                    plan.results.append((ev, "skipped"))
                    break
                time.sleep(0.01)
            else:
                try:
                    if ev.op == "add":
                        self.add_shard(ev.sid)
                    elif ev.op == "remove":
                        self.remove_shard(ev.sid)
                    else:
                        raise ValueError(f"unknown membership op {ev.op!r}")
                    plan.results.append((ev, "ok"))
                except BaseException as e:
                    log.warning("scripted membership op %s(sid=%s) at clock "
                                "%d failed: %r — plan driver stopping",
                                ev.op, ev.sid, ev.clock, e)
                    plan.results.append((ev, f"error: {e!r}"))
                    rt._record_error(e)
                    return

    def finish_plan(self, timeout: float) -> None:
        if self._plan_thread is not None:
            self._plan_thread.join(timeout=max(0.1, timeout))
