"""Per-shard write-ahead delta log (durability tier, ROADMAP direction 5).

Each shard slot appends the update parts it applies — *after* the in-memory
apply, FIFO behind it — to an append-only segment file, then group-commits
the batch at clock boundaries: when the shard's applied vector clock moves
(a ClockMsg arrived), the buffered frames are written out followed by a
vc *stamp* record, exactly the serving-publish discipline (deltas FIFO,
then :class:`~repro.runtime.messages.ReplicaVcMsg`).  Recovery becomes
``snapshot + replay(log, upto_vc)`` (:func:`repro.runtime.snapshot.
recover_to_vc`): an empty snapshot (genesis) plus a full replay, or a
periodic snapshot plus the per-slot log *suffix* it does not already cover.

Wire format — one format for publish, migration, and disk
---------------------------------------------------------
A segment is a stream of the runtime's ordinary wire frames
(``u32 payload_len | payload``, :mod:`repro.runtime.transport`):

* **raw row-block frames** (:class:`~repro.runtime.transport.RowCodec`,
  the PR-6 zero-copy codec): the coalesced ``UpdateMsg`` runs of one apply
  cycle, each part's uid / origin process / ts / epoch / key / global row
  ids / f64 deltas all in the fixed 48-byte struct header — nothing else
  is needed to replay it with ``np.add.at`` onto a full-key buffer;
* **pickle-5 frames** (the fallback for everything that is not an f64 row
  block): the ``ReplicaVcMsg`` vc stamps, and any update part that is not
  raw-eligible;
* the ``EOF_LEN`` sentinel marks a *sealed* segment (clean close: seal at
  the epoch cut of a retiring slot, segment rotation, runtime teardown).
  A segment without it is torn — killed mid-write — and the reader
  recovers cleanly to the last complete record (:func:`read_segment`).

Segment files are named ``s{sid:02d}_p{start_part:012d}_g{gen:04d}.wal``
where ``start_part`` is the slot-global index of the segment's first logged
part (``gen`` only keeps names unique across seal/reopen cycles);
the name alone gives every record its exact position in the slot's log, so
a snapshot stamped with per-slot logged-part counts (``wal_parts``) marks
the exact per-slot prefix it covers — positional, not clock-fuzzy.

Durability policies (``RuntimeConfig(wal_fsync=...)``):

* ``"none"`` (default) — group-commit writes ``flush()`` to the OS page
  cache at each clock boundary; survives process kills, not host power
  loss.  This is the hot-path configuration: no fsync ever sits between
  two applies (seal/rotation still fsync).
* ``"boundary"`` — ``fsync`` after every group commit; survives power
  loss to the last completed clock boundary, at the cost the bench gate
  in ``benchmarks/bench_wal.py`` quantifies.

The writer is single-threaded by construction: only the owning shard's
thread calls :meth:`WalWriter.log_parts` (under the shard lock, so the
logged-part counters stay consistent with the dense state a snapshot
captures), :meth:`WalWriter.commit` and :meth:`WalWriter.seal`; the
metrics collector reads the counters racily like every other shard
counter.
"""
from __future__ import annotations

import logging
import os
import re
import struct
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.messages import DeliverMsg, ReplicaVcMsg, UpdateMsg
from repro.runtime.transport import (EOF_LEN, RAW_MAGIC, RowCodec,
                                     decode_payload, encode_frame, eof_frame)

_U32 = struct.Struct("<I")

log = logging.getLogger("repro.runtime.wal")

FSYNC_POLICIES: Tuple[str, ...] = ("none", "boundary")

_SEG_RE = re.compile(r"^s(\d+)_p(\d+)_g(\d+)\.wal$")


def segment_name(sid: int, start_part: int, gen: int) -> str:
    """``start_part`` positions the segment in the slot's log; ``gen`` is a
    per-writer monotone counter that keeps names unique when a slot seals
    and reopens without logging new parts in between (kill + rejoin)."""
    return f"s{sid:02d}_p{start_part:012d}_g{gen:04d}.wal"


class WalWriter:
    """Append-only per-slot delta log (module docstring).

    ``parts`` / ``applied`` / ``max_ts`` are the slot's durability marks:
    total parts logged, per-origin-process part counts, and the per-process
    maximum update timestamp logged — bumped in :meth:`log_parts` under the
    same shard lock as the dense apply, so a snapshot reading them with the
    dense state (``ServerShard.durability_cut``) captures an exact log
    prefix.
    """

    def __init__(self, dir_path: str, sid: int, codec: RowCodec,
                 n_proc: int, fsync: str = "none",
                 segment_bytes: int = 1 << 22):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"unknown wal fsync policy {fsync!r}; "
                             f"choose from {FSYNC_POLICIES}")
        self.dir = dir_path
        self.sid = sid
        self.codec = codec
        self.n_proc = n_proc
        self.fsync = fsync
        self.segment_bytes = max(1, int(segment_bytes))
        # durability marks (single-writer: the shard thread, under its lock)
        self.parts = 0                    # parts logged (pending + written)
        self.applied = np.zeros(n_proc, dtype=np.int64)
        self.max_ts = np.full(n_proc, -1, dtype=np.int64)
        self._written = 0                 # parts written to segment files
        self._pending: List[bytes] = []   # encoded frames awaiting commit
        self._pending_t0 = 0.0            # monotonic ts of oldest pending
        self._stamp_prefix: Optional[bytes] = None  # cached stamp wire prefix
        self._file = None
        self._seg_size = 0
        # metrics (racy readers: repro.runtime.metrics)
        self.m_commits = 0
        self.m_bytes = 0
        self.m_segments = 0
        self.m_fsync_s = 0.0

    # ---------------------------------------------------------------- append
    def log_parts(self, run: List[UpdateMsg]) -> None:
        """Append one apply cycle's update parts (FIFO-behind the apply;
        called under the shard lock).  Frames are *encoded to owned bytes*
        immediately — ring-backed zero-copy views are only valid while the
        cycle's frame pins are held — but not written until :meth:`commit`
        (group commit at the clock boundary)."""
        if not run:
            return
        if not self._pending:
            self._pending_t0 = time.monotonic()
        for item in self.codec.frames(run, None):
            if isinstance(item, list):    # raw frame: list of buffers
                # join() reads the buffer views directly — ONE copy into
                # owned bytes, no per-piece bytes() materialization
                self._pending.append(b"".join(item))
            else:                         # pickle fallback: already bytes
                self._pending.append(item)
        for m in run:
            self.applied[m.process] += 1
            if m.ts > self.max_ts[m.process]:
                self.max_ts[m.process] = m.ts
        self.parts += len(run)

    def _stamp_frame(self, vc: np.ndarray) -> bytes:
        """Encoded vc-stamp frame (the serving-publish record shape).

        Every stamp this writer emits has an identical wire prefix — same
        shard id, same ``(n_proc,)`` int64 vc — with only the trailing
        out-of-band buffer (the vc values) changing, so the prefix is
        computed once with :func:`encode_frame` and reused on the commit
        hot path.  The cacheability assumption is checked byte-for-byte on
        the first stamp; if the frame does not end with the raw vc bytes
        (e.g. a pickle that inlines the array), every stamp falls back to
        a full encode.
        """
        vc = np.ascontiguousarray(vc, dtype=np.int64)
        raw = vc.tobytes()
        if self._stamp_prefix is None:
            full = encode_frame([ReplicaVcMsg(self.sid, vc.copy())])
            self._stamp_prefix = (full[:-len(raw)]
                                  if full.endswith(raw) else b"")
            return full
        if not self._stamp_prefix:       # b"" sentinel: not cacheable
            return encode_frame([ReplicaVcMsg(self.sid, vc.copy())])
        return self._stamp_prefix + raw

    def commit(self, vc: np.ndarray) -> None:
        """Group commit at a clock boundary: write the pending frames plus
        a vc stamp (FIFO-after every part it covers, like the publish
        stream), then apply the fsync policy and rotate if the segment
        outgrew ``segment_bytes``."""
        frames = self._pending
        self._pending = []
        frames.append(self._stamp_frame(vc))
        self._write(frames)
        self._written = self.parts
        self.m_commits += 1
        if self.fsync == "boundary":
            self._do_fsync()
        if self._seg_size >= self.segment_bytes:
            self._close_segment()

    def seal(self, vc: Optional[np.ndarray] = None) -> None:
        """Flush everything, optionally stamp a final vc, write the EOF
        sentinel, fsync, and close the current segment.  Called at the
        epoch cut of a retiring slot and at runtime teardown; idempotent —
        a later :meth:`log_parts`/:meth:`commit` (slot re-activation)
        simply opens the next segment."""
        frames = self._pending
        self._pending = []
        if vc is not None and (frames or self._file is not None
                               or self.parts > self._written):
            frames.append(self._stamp_frame(vc))
        if not frames and self._file is None:
            return
        self._write(frames)
        self._written = self.parts
        self._close_segment()

    def marks(self) -> dict:
        """The durability marks a snapshot stores (read under the shard
        lock for consistency with the dense state)."""
        return {"parts": self.parts,
                "applied": self.applied.copy(),
                "max_ts": self.max_ts.copy()}

    @property
    def pending_age_s(self) -> float:
        """Age of the oldest uncommitted frame (wal append lag)."""
        if not self._pending:
            return 0.0
        return max(0.0, time.monotonic() - self._pending_t0)

    # -------------------------------------------------------------- plumbing
    def _ensure_open(self):
        if self._file is None:
            os.makedirs(self.dir, exist_ok=True)
            # the new segment starts at the first not-yet-written part
            path = os.path.join(self.dir, segment_name(
                self.sid, self._written, self.m_segments))
            self._file = open(path, "ab")
            self._seg_size = 0
            self.m_segments += 1
        return self._file

    def _write(self, frames: List[bytes]) -> None:
        if not frames:
            return
        f = self._ensure_open()
        # one buffer, one write(), one flush() syscall: every GIL release
        # on this path is a chance for a worker thread to steal the shard
        # thread's quantum, so syscall count is the hot-path cost driver
        blob = frames[0] if len(frames) == 1 else b"".join(frames)
        f.write(blob)
        f.flush()
        self._seg_size += len(blob)
        self.m_bytes += len(blob)

    def _do_fsync(self) -> None:
        t0 = time.monotonic()
        os.fsync(self._file.fileno())
        self.m_fsync_s += time.monotonic() - t0

    def _close_segment(self) -> None:
        if self._file is None:
            return
        self._file.write(eof_frame())
        self._file.flush()
        self._do_fsync()
        self._file.close()
        self._file = None
        self._seg_size = 0


# ---------------------------------------------------------------------------
# read side (recovery)
# ---------------------------------------------------------------------------


def wal_segments(dir_path: str) -> Dict[int, List[Tuple[int, str]]]:
    """List a wal directory's segments: ``{sid: [(start_part, path), ...]}``
    sorted by start position (log order) per slot."""
    out: Dict[int, List[Tuple[int, str]]] = {}
    if not os.path.isdir(dir_path):
        return out
    by: Dict[int, List[Tuple[int, int, str]]] = {}
    for name in os.listdir(dir_path):
        m = _SEG_RE.match(name)
        if m:
            sid, start, gen = (int(m.group(1)), int(m.group(2)),
                               int(m.group(3)))
            by.setdefault(sid, []).append(
                (start, gen, os.path.join(dir_path, name)))
    for sid, segs in by.items():
        segs.sort()
        out[sid] = [(start, path) for start, _, path in segs]
    return out


def read_segment(path: str, codec: RowCodec) -> Tuple[list, bool]:
    """Decode one segment into ``(records, sealed)``.

    ``records`` is a list of ``("parts", [UpdateMsg, ...])`` and
    ``("vc", ReplicaVcMsg)`` entries in log order; ``sealed`` is True when
    the EOF sentinel closed the stream.  A *torn tail* — the file truncated
    mid-record by a kill — stops the decode cleanly at the last complete
    record; bytes *after* the EOF sentinel, or a record that is present but
    undecodable, are corruption and raise."""
    with open(path, "rb") as f:
        data = f.read()
    mv = memoryview(data)
    n = len(data)
    out: list = []
    off = 0
    sealed = False
    while True:
        if off + 4 > n:
            break                              # torn: partial length prefix
        plen = _U32.unpack_from(mv, off)[0]
        if plen == EOF_LEN:
            if off + 4 != n:
                raise ValueError(f"wal segment {path!r}: data after EOF")
            sealed = True
            break
        if off + 4 + plen > n:
            break                              # torn: partial payload
        payload = mv[off + 4:off + 4 + plen]
        off += 4 + plen
        if plen >= 4 and _U32.unpack_from(payload, 0)[0] == RAW_MAGIC:
            out.append(("parts", codec.decode_raw(payload)))
            continue
        run: List[UpdateMsg] = []
        for msg in decode_payload(bytes(payload)):
            if isinstance(msg, ReplicaVcMsg):
                if run:
                    out.append(("parts", run))
                    run = []
                out.append(("vc", msg))
            elif isinstance(msg, (UpdateMsg, DeliverMsg)):
                run.append(msg)                # pickle-5 fallback parts
            else:
                raise ValueError(f"wal segment {path!r}: unexpected "
                                 f"record {type(msg).__name__}")
        if run:
            out.append(("parts", run))
    if not sealed and off < n:
        log.warning("wal segment %s: torn tail — %d trailing byte(s) of an "
                    "incomplete record dropped, recovered cleanly to the "
                    "last complete record (%d kept)",
                    path, n - off, len(out))
    return out, sealed


def prune_segments(dir_path: str,
                   covered_parts: Dict[int, int]) -> List[str]:
    """Delete segments *fully covered* by a snapshot's per-slot logged-part
    marks: segment ``[start, next_start)`` is removable iff a successor
    segment exists and ``next_start <= covered_parts[sid]`` (every part in
    it is positionally inside the snapshot's prefix).  A slot's last
    segment is never deleted — its start position anchors the log.  Returns
    the removed paths."""
    removed: List[str] = []
    for sid, segs in wal_segments(dir_path).items():
        cov = int(covered_parts.get(sid, 0))
        for (start, path), (next_start, _) in zip(segs, segs[1:]):
            if next_start <= cov:
                os.remove(path)
                removed.append(path)
    return removed
