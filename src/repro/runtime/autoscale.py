"""Autoscaling control loop over the elastic runtime (PR 7 tentpole).

The observability layer (:mod:`repro.runtime.metrics`) measures where the
paper's bounded inconsistency is spending its slack; this module *acts* on
it, closing the loop ROADMAP direction 2 describes.  An :class:`Autoscaler`
thread polls a private :class:`~repro.runtime.metrics.MetricsHub` and
drives three actuators:

  * **shard scaling** via the PR-4 :class:`MembershipManager` — when the
    windowed apply load across active shards is imbalanced past
    ``split_imbalance`` (one hot shard gating every client's clock
    frontier), it activates a dormant slot: the round-robin re-partition
    *splits* the hot shard's rows across more owners.  When the coldest
    active shard's load falls below ``drain_max_rows_s`` it *drains* that
    slot back into the survivors (a near-idle slot still costs a frontier
    constraint and per-clock fan-out — consolidation is the rebalance that
    pays on a host with fewer cores than slots);
  * **replica scaling** via :meth:`ReadGateway.add_replica` /
    ``remove_replica`` — the windowed escalation rate (reads that missed
    their staleness SLO on every replica and fell back to the master) is
    the SLO-violation signal: past ``escalation_hi`` a replica is added,
    and after ``drain_patience`` consecutive calm windows below
    ``escalation_lo`` the least-loaded one is drained;
  * **SLO-aware admission** via :meth:`ReadGateway.set_shed_fresh` — when
    the master is hot (windowed apply-lock wait fraction past
    ``shed_lock_wait_frac``; ``fresh`` reads contend on exactly those
    locks), the gateway sheds ``fresh`` reads with
    :class:`~repro.runtime.serving.gateway.ReadShedError` instead of
    piling onto the master, releasing at half the threshold (hysteresis).

Decisions are separated from actuation: :meth:`Autoscaler.decide` is a
pure function of one :class:`RuntimeMetrics` snapshot (unit-testable on
synthetic metrics); the loop thread applies them with a cooldown between
membership ops and records every action (and failure) in ``.actions``.
The paper's Lemma bounds and the zero-lost/duplicated-update audit hold
*while* the autoscaler churns membership — ``tests/chaos.py`` runs it
under Zipf-skewed bursty load and asserts exactly that.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.runtime.metrics import MetricsHub, RuntimeMetrics

log = logging.getLogger("repro.runtime.autoscale")


@dataclass
class AutoscalePolicy:
    """Policy knobs of the control loop (documented in README
    "Metrics & autoscaling")."""
    interval: float = 0.25        # metrics poll period (s)
    cooldown: float = 1.5         # min s between membership ops
    # --- shard split/drain (load imbalance) ---
    split_imbalance: float = 1.6  # max/mean windowed rows/s across active
    split_min_rows_s: float = 500.0   # hot shard must carry real load
    drain_max_rows_s: float = 50.0    # drain an active shard whose windowed
                                      # load falls below this (a cold slot
                                      # still costs a frontier constraint
                                      # and per-clock fan-out)
    min_shards: int = 1
    max_shards: Optional[int] = None  # None -> every provisioned slot
    # --- replica scaling (SLO-violation / escalation rate) ---
    escalation_hi: float = 0.15   # windowed escalations/read: scale up
    escalation_lo: float = 0.01   # windowed escalations/read: calm
    drain_patience: int = 3       # calm windows before draining a replica
    min_replicas: int = 1
    max_replicas: int = 4
    min_window_reads: int = 5     # ignore rate noise below this many reads
    # --- admission (shed fresh reads while the master is hot) ---
    shed_lock_wait_frac: float = 0.25  # windowed apply-lock wait / wall
    # --- ops ---
    op_timeout: float = 10.0      # membership op budget (autoscaler ops
                                  # race the run's natural quiesce; a late
                                  # op may time out and is just recorded)


@dataclass
class AutoscaleAction:
    wall_s: float                 # seconds since runtime start
    kind: str                     # "add_shard" | "remove_shard" |
    detail: str                   # "add_replica" | "remove_replica" |
    ok: bool                      # "shed_fresh"
    error: Optional[str] = None


@dataclass
class _GwState:
    calm_windows: int = 0


class Autoscaler:
    """Drives shard membership, the replica set, and gateway admission
    from observed load (module docstring).  ``gateway`` is optional — a
    write-only runtime still gets shard split/drain."""

    def __init__(self, rt, gateway=None,
                 policy: Optional[AutoscalePolicy] = None):
        self.rt = rt
        self.gateway = gateway
        self.policy = policy or AutoscalePolicy()
        self.hub = MetricsHub(rt)      # private rate window: callers using
        self.actions: List[AutoscaleAction] = []   # rt.metrics() don't skew it
        self._prev_lock_wait = 0.0
        self._gw_state: Dict[int, _GwState] = {}
        self._last_op = 0.0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- decisions
    def decide(self, m: RuntimeMetrics) -> List[Tuple]:
        """Pure policy: one metrics snapshot -> list of decisions
        (``("add_shard",)``, ``("remove_shard", sid)``,
        ``("add_replica", gw_index)``, ``("remove_replica", gw_index)``,
        ``("shed_fresh", gw_index, bool)``)."""
        pol = self.policy
        out: List[Tuple] = []
        active = m.active_shards()
        if active:
            rates = [s.rows_per_s for s in active]
            cap = pol.max_shards if pol.max_shards is not None else len(
                m.shards)
            if (len(active) < cap
                    and m.shard_imbalance() >= pol.split_imbalance
                    and max(rates) >= pol.split_min_rows_s):
                out.append(("add_shard",))
            elif len(active) > pol.min_shards:
                cold = m.coldest_shard()
                if (cold is not None
                        and cold.rows_per_s < pol.drain_max_rows_s):
                    out.append(("remove_shard", cold.sid))
        # master-hot signal: windowed apply-lock wait across every shard as
        # a fraction of the window (fresh reads contend on those locks)
        lock_wait = sum(s.apply_lock_wait_s for s in m.shards)
        wait_frac = max(0.0, lock_wait - self._prev_lock_wait) / m.window_s
        self._prev_lock_wait = lock_wait
        for i, gw in enumerate(m.gateways):
            st = self._gw_state.setdefault(i, _GwState())
            window_reads = m.window_s * gw.reads_per_s
            if window_reads >= self.policy.min_window_reads:
                if (gw.escalation_rate >= pol.escalation_hi
                        and gw.n_live_replicas < pol.max_replicas):
                    st.calm_windows = 0
                    out.append(("add_replica", i))
                elif gw.escalation_rate <= pol.escalation_lo:
                    st.calm_windows += 1
                    if (st.calm_windows >= pol.drain_patience
                            and gw.n_live_replicas > pol.min_replicas):
                        st.calm_windows = 0
                        out.append(("remove_replica", i))
                else:
                    st.calm_windows = 0
            if wait_frac > pol.shed_lock_wait_frac and not gw.shedding_fresh:
                out.append(("shed_fresh", i, True))
            elif (gw.shedding_fresh
                  and wait_frac < pol.shed_lock_wait_frac / 2):
                out.append(("shed_fresh", i, False))
        return out

    # ------------------------------------------------------------- actuation
    def _record(self, kind: str, detail: str, ok: bool,
                error: Optional[str] = None) -> None:
        self.actions.append(AutoscaleAction(
            time.monotonic() - (self.rt._t0 or time.monotonic()),
            kind, detail, ok, error))

    def _apply(self, decisions: List[Tuple]) -> None:
        rt = self.rt
        pol = self.policy
        now = time.monotonic()
        for dec in decisions:
            kind = dec[0]
            try:
                if kind in ("add_shard", "remove_shard"):
                    # membership ops pay a cooldown (each one freezes the
                    # partition briefly) and only make sense on a live run
                    if now - self._last_op < pol.cooldown or not rt.running:
                        continue
                    self._last_op = now
                    if kind == "add_shard":
                        sid = rt.add_shard(timeout=pol.op_timeout)
                        self._record(kind, f"activated slot {sid}", True)
                    else:
                        rt.remove_shard(dec[1], timeout=pol.op_timeout)
                        self._record(kind, f"drained slot {dec[1]}", True)
                elif kind == "add_replica":
                    rep = self.gateway.add_replica()
                    self._record(kind, f"replica {rep.rid}", True)
                elif kind == "remove_replica":
                    rep = self.gateway.remove_replica()
                    if rep is not None:
                        self._record(kind, f"replica {rep.rid}", True)
                elif kind == "shed_fresh":
                    self.gateway.set_shed_fresh(dec[2])
                    self._record(kind, f"shed={dec[2]}", True)
            except BaseException as e:
                # an op racing the run's quiesce (or a raced slot pick) is
                # an expected loss, never an error of the run itself
                log.warning("autoscaler op %s %r failed: %r (expected when "
                            "racing the run's quiesce; recorded, not fatal)",
                            kind, dec, e)
                self._record(kind, repr(dec), False, repr(e))

    def step(self) -> List[Tuple]:
        """One poll cycle: collect, decide, apply.  Returns the decisions
        (the chaos harness and tests call this directly)."""
        decisions = self.decide(self.hub.collect())
        self._apply(decisions)
        return decisions

    # ------------------------------------------------------------- lifecycle
    def _loop(self) -> None:
        while not self._stop.is_set() and self.rt.running:
            try:
                self.step()
            except BaseException:
                # a torn metrics read mid-teardown must not kill the loop
                if self._stop.is_set() or not self.rt.running:
                    break
            self._stop.wait(self.policy.interval)

    def start(self) -> "Autoscaler":
        if self._thread is not None:
            raise RuntimeError("autoscaler already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="ps-autoscaler", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def __enter__(self) -> "Autoscaler":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- reporting
    def summary(self) -> Dict[str, int]:
        """Action counts by kind (successful only)."""
        out: Dict[str, int] = {}
        for a in self.actions:
            if a.ok:
                out[a.kind] = out.get(a.kind, 0) + 1
        return out
