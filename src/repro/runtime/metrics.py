"""Unified runtime metrics (PR 7 observability layer).

One typed read surface over every stats counter the runtime grew across
PRs 1-6 — ``RunStats`` (client-side update/block counters),
``GatewayStats`` (serving reads), replica ``pub_drops``/``pub_resyncs``
(publish backpressure), snapshot and membership counters — plus the
per-shard / per-process *load* counters this PR adds for the autoscaler:

    m = rt.metrics()            # -> RuntimeMetrics (plain dataclass tree)
    m.shards[0].updates_per_s   # windowed apply rate of shard slot 0
    m.shard_imbalance()         # max/mean load across active shards
    m.gateways[0].escalation_rate

Collection discipline (the "low-overhead" contract):

  * every hot-path counter is **single-writer**: owned by exactly one
    thread (the shard thread, one worker's ClientProcess under its cond,
    one replica's ingest thread) and bumped without any new lock;
  * the collector reads them **racily** — int/float loads are atomic under
    the GIL, and a slightly torn view across counters only wobbles a rate
    estimate, never the correctness audits (which run on the quiesced
    state);
  * client processes snapshot their counters **at clock boundaries** and
    piggyback them on the :class:`~repro.runtime.messages.ClockMsg` they
    already send (``ClockMsg.load``), so in proc mode the load data rides
    the existing channel/pipe machinery — no side channel, no extra wakeups;
  * rates are computed by :class:`MetricsHub` against the previous
    ``collect()`` call's snapshot (first call: since runtime start).

The legacy surfaces (``rt.stats``, ``gateway.stats``, ``rset.pub_drops``,
``rt.snapshots``...) keep working but are **deprecated** as read APIs:
new code should consume ``rt.metrics()``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# indices of the ClockMsg.load counter vector (one float64 per slot; the
# array is tiny and rides the already-pickled control message)
LOAD_UPDATES = 0          # Incs applied by this process so far
LOAD_BLOCK_CLOCK = 1      # cumulative seconds blocked in the clock gate
LOAD_BLOCK_VALUE = 2      # cumulative seconds blocked in the value gate
LOAD_LEN = 3


@dataclass
class ShardMetrics:
    """One shard slot's load and publish health."""
    sid: int
    active: bool                  # owns rows under the current partition
    epoch: int                    # membership epoch the slot last adopted
    inbox_depth: int              # channel depth: messages queued, unread
    parts_applied: int            # update parts applied (audit counter)
    rows_applied: int             # row-updates applied (vectorized adds)
    bytes_applied: int            # delta bytes applied to the dense blocks
    apply_lock_wait_s: float      # cumulative wait for the dense-block lock
    applied_parts: List[int]      # per origin process (audit counter)
    clock_min: int                # min applied vc entry (-1 before clock 1)
    pub_pending: int              # publish messages coalesced, not yet sent
    pub_drops: int                # publish cycles dropped on a full sink
    pub_resyncs: int              # successful in-stream re-bootstraps
    publish_lag_s: float          # age of the oldest unpublished cycle
    updates_per_s: float = 0.0    # windowed: parts applied / s
    rows_per_s: float = 0.0       # windowed: row-updates applied / s
    # durability tier (repro.runtime.wal) — zeros when wal_dir is unset
    wal_parts: int = 0            # update parts logged (pending + written)
    wal_commits: int = 0          # group commits (clock boundaries hit)
    wal_bytes: int = 0            # bytes written to segment files
    wal_segments: int = 0         # segment files opened by this writer
    wal_fsync_s: float = 0.0      # cumulative fsync time (policy cost)
    wal_append_lag_s: float = 0.0 # age of the oldest uncommitted frame


@dataclass
class ProcessMetrics:
    """One client process's load, snapshotted at its last clock boundary
    and shipped on the ClockMsg it already sends (proc mode: over the
    wire; queue mode: over the in-process channels — same path)."""
    process: int
    clock: int                    # boundary the snapshot was taken at
    n_updates: int
    block_time_clock: float
    block_time_value: float
    updates_per_s: float = 0.0    # windowed


@dataclass
class ReplicaMetrics:
    rid: int
    staleness: int                # clocks behind the live master frontier
    reads: int
    deltas_applied: int
    bytes_ingested: int
    poisoned: bool
    stale: bool                   # marked for drop-and-resync by a shard


@dataclass
class GatewayMetrics:
    n_reads: int
    n_replica_reads: int
    n_master_reads: int
    n_escalations: int
    n_shed: int                   # fresh reads refused by admission control
    n_cache_hits: int             # served from the gateway read cache
    reads_by_slo: Dict[str, int]  # per-SLO read counts ("0", "3", "any", ...)
    max_served_staleness: int
    block_time: float
    reads_per_replica: Dict[int, int]
    shedding_fresh: bool          # admission control currently engaged
    n_live_replicas: int = 0      # replicas in the serving rotation
    reads_per_s: float = 0.0      # windowed
    escalations_per_s: float = 0.0
    escalation_rate: float = 0.0  # windowed escalations / reads (SLO misses
                                  # that had to fall back to the master)


@dataclass
class MembershipMetrics:
    epoch: int
    active: Tuple[int, ...]
    n_slots: int
    n_ops: int                    # completed add/remove operations


@dataclass
class SnapshotMetrics:
    n_snapshots: int
    snapshot_every: int
    last_clock: int               # frontier of the latest snapshot (or -1)


@dataclass
class RunMetrics:
    """The client-side RunStats counters, unified.  In proc mode the
    mid-run values come from the ClockMsg load piggyback (the children own
    their RunStats until wait() merges them)."""
    n_updates: int
    n_messages: int
    bytes_sent: int
    n_ack_msgs: int
    n_acked_updates: int
    block_time_clock: float
    block_time_value: float
    max_observed_staleness: int
    max_unsynced_mag: float
    max_update_mag: float
    max_halfsync_mag: float
    n_violations: int


@dataclass
class RuntimeMetrics:
    """One consistent-enough snapshot of everything the runtime measures.

    ``shards``/``processes`` always populate; ``replicas``/``gateways``
    only when a serving tier is attached to this runtime."""
    t: float                      # monotonic collection timestamp
    wall_s: float                 # seconds since rt.start()
    window_s: float               # rate window (since previous collect())
    clock: int                    # global applied-clock frontier
    transport: str
    metrics_enabled: bool
    run: RunMetrics
    membership: MembershipMetrics
    snapshots: SnapshotMetrics
    shards: List[ShardMetrics] = field(default_factory=list)
    processes: List[ProcessMetrics] = field(default_factory=list)
    replicas: List[ReplicaMetrics] = field(default_factory=list)
    gateways: List[GatewayMetrics] = field(default_factory=list)
    # tracing tier (repro.runtime.trace): whether sampled event tracing is
    # on, and how many events the bounded rings have dropped so far (0 is
    # the healthy steady state; a growing count means the rings are
    # undersized for the sample rate)
    trace_enabled: bool = False
    trace_dropped: int = 0

    # ------------------------------------------------------------- derived
    def active_shards(self) -> List[ShardMetrics]:
        return [s for s in self.shards if s.active]

    def total_updates_per_s(self) -> float:
        return sum(s.updates_per_s for s in self.shards)

    def shard_imbalance(self) -> float:
        """max/mean windowed load across active shards (1.0 = balanced;
        the autoscaler's split trigger)."""
        rates = [s.rows_per_s for s in self.active_shards()]
        if not rates:
            return 1.0
        mean = sum(rates) / len(rates)
        if mean <= 0.0:
            return 1.0
        return max(rates) / mean

    def hottest_shard(self) -> Optional[ShardMetrics]:
        act = self.active_shards()
        return max(act, key=lambda s: s.rows_per_s) if act else None

    def coldest_shard(self) -> Optional[ShardMetrics]:
        act = self.active_shards()
        return min(act, key=lambda s: s.rows_per_s) if act else None


def slo_key(slo) -> str:
    """Bucket label for the per-SLO read counters ("fresh", "any", "0",
    "1", ...)."""
    if slo is None:
        return "any"
    if isinstance(slo, str):
        return slo
    return str(int(slo))


class MetricsHub:
    """Collects :class:`RuntimeMetrics` from a live runtime and computes
    windowed rates against its previous collection.  One hub per runtime
    (``rt.metrics()`` delegates here); creating extra hubs is fine — each
    keeps its own rate window."""

    def __init__(self, rt):
        self.rt = rt
        self._prev_t: Optional[float] = None
        self._prev_shard: Dict[int, Tuple[int, int]] = {}   # sid -> (parts, rows)
        self._prev_proc: Dict[int, int] = {}                # pid -> n_updates
        self._prev_gw: Dict[int, Tuple[int, int]] = {}      # id -> (reads, esc)

    # ---------------------------------------------------------------- parts
    def _collect_run(self, loads: Dict[int, Tuple[int, np.ndarray]]
                     ) -> RunMetrics:
        rt = self.rt
        st = rt.stats
        n_updates = st.n_updates
        block_c = st.block_time_clock
        block_v = st.block_time_value
        if loads:
            # proc mode mid-run: the children own their RunStats; the
            # piggybacked boundary snapshots are the live view.  max():
            # after wait() merged the finals, stats dominates the (older)
            # boundary snapshots.
            n_updates = max(n_updates,
                            int(sum(v[1][LOAD_UPDATES]
                                    for v in loads.values())))
            block_c = max(block_c, float(sum(v[1][LOAD_BLOCK_CLOCK]
                                             for v in loads.values())))
            block_v = max(block_v, float(sum(v[1][LOAD_BLOCK_VALUE]
                                             for v in loads.values())))
        return RunMetrics(
            n_updates=n_updates,
            n_messages=st.n_messages,
            bytes_sent=st.bytes_sent,
            n_ack_msgs=st.n_ack_msgs,
            n_acked_updates=st.n_acked_updates,
            block_time_clock=block_c,
            block_time_value=block_v,
            max_observed_staleness=st.max_observed_staleness,
            max_unsynced_mag=st.max_unsynced_mag,
            max_update_mag=st.max_update_mag,
            max_halfsync_mag=st.max_halfsync_mag,
            n_violations=len(st.violations),
        )

    def _collect_shard(self, s, now: float, dt: float) -> ShardMetrics:
        w = s.wal
        parts = int(s.applied_parts.sum())
        rows = int(s.m_rows_applied)
        try:
            pending = sum(len(v) for v in s._pub.values())
        except RuntimeError:                   # racy dict resize: skip once
            pending = 0
        last_pub = s.m_last_publish
        lag = max(0.0, now - last_pub) if (pending and last_pub) else 0.0
        with s.lock:
            active = s.part.owns(s.sid)
            clock_min = int(s.clock_vc.min())
        prev_parts, prev_rows = self._prev_shard.get(s.sid, (0, 0))
        self._prev_shard[s.sid] = (parts, rows)
        return ShardMetrics(
            sid=s.sid,
            active=active,
            epoch=s.epoch,
            inbox_depth=s.inbox.qsize(),
            parts_applied=parts,
            rows_applied=rows,
            bytes_applied=int(s.m_bytes_applied),
            apply_lock_wait_s=float(s.m_lock_wait),
            applied_parts=[int(x) for x in s.applied_parts],
            clock_min=clock_min,
            pub_pending=pending,
            pub_drops=s.pub_drops,
            pub_resyncs=s.pub_resyncs,
            publish_lag_s=lag,
            updates_per_s=max(0, parts - prev_parts) / dt,
            rows_per_s=max(0, rows - prev_rows) / dt,
            # wal counters: single-writer (the shard thread), racy reads
            # here exactly like the other shard counters
            wal_parts=int(w.parts) if w is not None else 0,
            wal_commits=int(w.m_commits) if w is not None else 0,
            wal_bytes=int(w.m_bytes) if w is not None else 0,
            wal_segments=int(w.m_segments) if w is not None else 0,
            wal_fsync_s=float(w.m_fsync_s) if w is not None else 0.0,
            wal_append_lag_s=(float(w.pending_age_s)
                              if w is not None else 0.0),
        )

    def _collect_procs(self, loads: Dict[int, Tuple[int, np.ndarray]],
                       dt: float) -> List[ProcessMetrics]:
        out = []
        for pid in sorted(loads):
            clock, vec = loads[pid]
            n_upd = int(vec[LOAD_UPDATES])
            prev = self._prev_proc.get(pid, 0)
            self._prev_proc[pid] = n_upd
            out.append(ProcessMetrics(
                process=pid, clock=clock, n_updates=n_upd,
                block_time_clock=float(vec[LOAD_BLOCK_CLOCK]),
                block_time_value=float(vec[LOAD_BLOCK_VALUE]),
                updates_per_s=max(0, n_upd - prev) / dt))
        return out

    def _collect_serving(self, dt: float
                         ) -> Tuple[List[ReplicaMetrics],
                                    List[GatewayMetrics]]:
        reps: List[ReplicaMetrics] = []
        gws: List[GatewayMetrics] = []
        for rset in list(getattr(self.rt, "_replica_sets", ())):
            mvc = rset.master_vc()
            stale = rset.stale_replicas
            for rep in list(rset.replicas):
                reps.append(ReplicaMetrics(
                    rid=rep.rid,
                    staleness=rset.staleness(rep.vc, mvc),
                    reads=rep.reads,
                    deltas_applied=rep.deltas_applied,
                    bytes_ingested=rep.bytes_ingested,
                    poisoned=rep.poisoned,
                    stale=rep.rid in stale))
        for gw in list(getattr(self.rt, "_gateways", ())):
            with gw._slock:
                st = gw.stats
                reads = st.n_reads
                esc = st.n_escalations
                by_slo = dict(st.reads_by_slo)
                per_rep = dict(st.reads_per_replica)
                gm = GatewayMetrics(
                    n_reads=reads,
                    n_replica_reads=st.n_replica_reads,
                    n_master_reads=st.n_master_reads,
                    n_escalations=esc,
                    n_shed=st.n_shed,
                    n_cache_hits=st.n_cache_hits,
                    reads_by_slo=by_slo,
                    max_served_staleness=st.max_served_staleness,
                    block_time=st.block_time,
                    reads_per_replica=per_rep,
                    shedding_fresh=gw.shed_fresh,
                    n_live_replicas=gw.replicas.n_live)
            p_reads, p_esc = self._prev_gw.get(id(gw), (0, 0))
            self._prev_gw[id(gw)] = (reads, esc)
            d_reads = max(0, reads - p_reads)
            gm.reads_per_s = d_reads / dt
            gm.escalations_per_s = max(0, esc - p_esc) / dt
            gm.escalation_rate = (max(0, esc - p_esc) / d_reads
                                  if d_reads else 0.0)
            gws.append(gm)
        return reps, gws

    # -------------------------------------------------------------- collect
    def collect(self) -> RuntimeMetrics:
        rt = self.rt
        now = time.monotonic()
        t0 = rt._t0 or now
        dt = max(now - (self._prev_t if self._prev_t is not None else t0),
                 1e-6)
        self._prev_t = now
        # per-process boundary snapshots: latest clock wins across shards
        # (every active shard receives every ClockMsg)
        loads: Dict[int, Tuple[int, np.ndarray]] = {}
        for s in rt.shards:
            for pid, entry in list(s.proc_load.items()):
                if pid not in loads or entry[0] > loads[pid][0]:
                    loads[pid] = entry
        membership = MembershipMetrics(
            epoch=rt.partition.epoch,
            active=tuple(rt.partition.active),
            n_slots=rt.n_slots,
            n_ops=len(rt.membership.log))     # one log entry per completed op
        with rt._snap_lock:
            snaps = SnapshotMetrics(
                n_snapshots=len(rt.snapshots),
                snapshot_every=rt.snapshot_every,
                last_clock=rt.snapshots[-1][0] if rt.snapshots else -1)
        shards = [self._collect_shard(s, now, dt) for s in rt.shards]
        reps, gws = self._collect_serving(dt)
        return RuntimeMetrics(
            t=now,
            wall_s=now - t0,
            window_s=dt,
            clock=rt.completed_clock(),
            transport=rt.transport_kind,
            metrics_enabled=rt.metrics_on,
            run=self._collect_run(loads),
            membership=membership,
            snapshots=snaps,
            shards=shards,
            processes=self._collect_procs(loads, dt),
            replicas=reps,
            gateways=gws,
            trace_enabled=rt.trace_on,
            trace_dropped=(rt._trace.dropped() if rt.trace_on else 0),
        )
