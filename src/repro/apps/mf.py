"""SGD matrix factorization on the asynchronous parameter server.

The shared state lives in two PS keys — ``U`` (n_users × rank) and ``V``
(n_items × rank) — the classic factor tables sharded by every PS paper's
collaborative-filtering workload.  Each clock a worker computes the
regularized squared-loss gradient of its rating shard against its (possibly
stale / bound-gated) view and emits ``-lr * grad`` as the delta, so the
whole run is distributed gradient descent whose convergence degrades
gracefully — and measurably — with staleness.  That measured degradation
is what :mod:`benchmarks.bench_convergence` plots per consistency policy.

Like LDA, the same application runs on the executable spec
(``backend="sim"``, where :class:`~repro.core.server.NetworkModel` delays
and stragglers make staleness real) and on the live threaded runtime
(``backend="runtime"``).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.policies import Policy
from repro.core.server import AsyncPS, NetworkModel


def synthetic_ratings(n_users: int = 60, n_items: int = 40, rank: int = 4,
                      density: float = 0.3, noise: float = 0.1,
                      seed: int = 0) -> np.ndarray:
    """Low-rank ground truth + gaussian noise, observed at ``density``.

    Returns an (n_obs, 3) float array of (user, item, rating) rows.
    """
    # decorrelated from run_mf's factor init, which hashes the same seed
    rng = np.random.default_rng([seed, 0xDA7A])
    ustar = rng.normal(0.0, 1.0, (n_users, rank)) / np.sqrt(rank)
    vstar = rng.normal(0.0, 1.0, (n_items, rank)) / np.sqrt(rank)
    full = ustar @ vstar.T + rng.normal(0.0, noise, (n_users, n_items))
    mask = rng.random((n_users, n_items)) < density
    users, items = np.nonzero(mask)
    return np.column_stack([users, items, full[users, items]]).astype(float)


def rmse(ratings: np.ndarray, U: np.ndarray, V: np.ndarray) -> float:
    u = ratings[:, 0].astype(int)
    i = ratings[:, 1].astype(int)
    pred = np.sum(U[u] * V[i], axis=1)
    return float(np.sqrt(np.mean((pred - ratings[:, 2]) ** 2)))


def _grad_shard(shard: np.ndarray, U: np.ndarray, V: np.ndarray,
                reg: float) -> Tuple[np.ndarray, np.ndarray]:
    """Full gradient of the shard's regularized squared loss at (U, V)."""
    u = shard[:, 0].astype(int)
    i = shard[:, 1].astype(int)
    err = np.sum(U[u] * V[i], axis=1) - shard[:, 2]        # (n_obs,)
    gU = np.zeros_like(U)
    gV = np.zeros_like(V)
    np.add.at(gU, u, err[:, None] * V[i])
    np.add.at(gV, i, err[:, None] * U[u])
    n = max(len(shard), 1)
    gU = gU / n + reg * U
    gV = gV / n + reg * V
    return gU, gV


def run_mf(ratings: np.ndarray, n_users: int, n_items: int, rank: int,
           policy: Policy, n_workers: int, n_clocks: int,
           lr: float = 1.0, reg: float = 1e-3, seed: int = 0,
           network: Optional[NetworkModel] = None, straggler=None,
           collect_stats: bool = False, backend: str = "sim",
           threads_per_process: int = 1, n_shards: int = 2,
           timeout: float = 300.0):
    """Returns the per-clock full-data RMSE list (and stats if asked).

    Worker 0 records the RMSE of its *view* at the top of every period —
    the stale view a worker actually optimizes against, which is exactly
    the quantity the convergence-vs-staleness benchmark compares across
    policies.
    """
    rng = np.random.default_rng(seed)
    shards = [ratings[w::n_workers] for w in range(n_workers)]
    # init away from the U=V=0 saddle, where the MF gradient vanishes
    u0 = rng.normal(0.0, 0.3, (n_users, rank))
    v0 = rng.normal(0.0, 0.3, (n_items, rank))
    losses: List[float] = []

    def update_fn(w: int, clock: int, view, wrng: np.random.Generator):
        U = view.get("U")
        V = view.get("V")
        if w == 0:
            losses.append(rmse(ratings, U, V))
        gU, gV = _grad_shard(shards[w], U, V, reg)
        return {"U": -lr * gU, "V": -lr * gV}

    if backend == "sim":
        ps = AsyncPS(n_workers, policy, {"U": u0, "V": v0},
                     network=network or NetworkModel(seed=seed),
                     straggler=straggler, seed=seed)
        stats = ps.run(update_fn, n_clocks)
    elif backend == "runtime":
        from repro.runtime import PSRuntime, RuntimeConfig
        rt = PSRuntime(RuntimeConfig(n_workers, policy, {"U": u0, "V": v0},
                       n_shards=n_shards,
                       threads_per_process=threads_per_process, seed=seed))
        stats = rt.run(update_fn, n_clocks, timeout=timeout)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if collect_stats:
        return losses, stats
    return losses
