"""Collapsed-Gibbs LDA on the asynchronous parameter server (paper §5).

The shared state lives in two PS keys — ``word_topic`` (V × K counts) and
``topic`` (K counts) — exactly the tables YahooLDA/Petuum shard; per-document
topic counts and assignments are worker-local.  Each clock a worker sweeps
its document shard with collapsed Gibbs against its (possibly stale /
value-bounded) view and emits the count deltas, which is the paper's
evaluation workload for the consistency models.

The same application runs on all three implementations of the spec:

  * ``backend="sim"``      — the deterministic event-driven simulator
                             (:class:`repro.core.server.AsyncPS`);
  * ``backend="runtime"``  — the real threaded PS
                             (:class:`repro.runtime.PSRuntime`);
  * :func:`run_lda_spmd`   — the SPMD sync layer (:mod:`repro.core.sync`),
                             replicas synchronized with named-axis
                             collectives under ``jax.vmap``.

``snapshot_trajectory=True`` switches the log-likelihood recording to
*period-start snapshots*: each worker captures its own doc-topic state and
worker 0 captures the PS view at the top of every period, before sweeping.
Those captures are worker-local, so the resulting trajectory is free of
cross-thread races — under BSP (with ``barrier_reads`` on the runtime) all
three backends produce element-wise identical trajectories, which the
conformance suite asserts.  Count deltas are integers, so float accumulation
is exact and order-independent.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.policies import Policy
from repro.core.server import AsyncPS, NetworkModel
from repro.data.lda_corpus import LDACorpus


class _WorkerState:
    def __init__(self, docs, n_topics: int, rng: np.random.Generator):
        self.docs = docs
        self.assign = [rng.integers(0, n_topics, size=len(d)) for d in docs]
        self.doc_topic = np.zeros((len(docs), n_topics), dtype=np.float64)
        for i, zs in enumerate(self.assign):
            np.add.at(self.doc_topic[i], zs, 1.0)


def _initial_counts(states: List[_WorkerState], vocab: int, K: int):
    wt = np.zeros((vocab, K))
    tc = np.zeros(K)
    for st in states:
        for d, zs in zip(st.docs, st.assign):
            np.add.at(wt, (d, zs), 1.0)
            np.add.at(tc, zs, 1.0)
    return wt, tc


def _init_states(corpus: LDACorpus, n_topics: int, n_workers: int, seed: int):
    rng = np.random.default_rng(seed)
    shards = [list(range(w, corpus.n_docs, n_workers))
              for w in range(n_workers)]
    states = [_WorkerState([corpus.docs[i] for i in sh], n_topics, rng)
              for sh in shards]
    wt0, tc0 = _initial_counts(states, corpus.vocab_size, n_topics)
    return shards, states, wt0, tc0


def log_likelihood(corpus: LDACorpus, wt: np.ndarray, tc: np.ndarray,
                   doc_topic: np.ndarray, doc_ids, alpha: float,
                   beta: float) -> float:
    """doc_topic rows follow the order of doc_ids (concatenated shards)."""
    V, K = wt.shape
    phi = (wt + beta) / (tc + V * beta)[None, :]           # (V, K)
    ll = 0.0
    for row, gid in enumerate(doc_ids):
        d = corpus.docs[gid]
        theta = doc_topic[row] + alpha
        theta = theta / theta.sum()
        p = phi[d] @ theta
        ll += float(np.log(np.maximum(p, 1e-12)).sum())
    return ll


def _gibbs_sweep(st: _WorkerState, wt: np.ndarray, tc: np.ndarray,
                 V: int, alpha: float, beta: float,
                 wrng: np.random.Generator):
    """One collapsed-Gibbs sweep over a worker's shard; returns count deltas."""
    K = tc.shape[0]
    d_wt = np.zeros_like(wt)
    d_tc = np.zeros_like(tc)
    for di, doc in enumerate(st.docs):
        dt = st.doc_topic[di]
        zs = st.assign[di]
        for ti, word in enumerate(doc):
            z = zs[ti]
            # remove current assignment (local view)
            dt[z] -= 1
            d_wt[word, z] -= 1
            d_tc[z] -= 1
            nw = np.maximum(wt[word] + d_wt[word] + beta, beta)
            nt = np.maximum(tc + d_tc + V * beta, V * beta)
            p = (dt + alpha) * nw / nt
            p = np.maximum(p, 1e-12)
            z_new = wrng.choice(K, p=p / p.sum())
            zs[ti] = z_new
            dt[z_new] += 1
            d_wt[word, z_new] += 1
            d_tc[z_new] += 1
    return d_wt, d_tc


class _Snapshots:
    """Period-start captures, written by each worker under distinct keys."""

    def __init__(self):
        self.doc: Dict[Tuple[int, int], np.ndarray] = {}   # (worker, clock)
        self.view: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}  # clock

    def trajectory(self, corpus: LDACorpus, shards, n_workers: int,
                   n_clocks: int, alpha: float, beta: float) -> List[float]:
        ids = [i for sh in shards for i in sh]
        lls = []
        for c in range(n_clocks):
            wt, tc = self.view[c]
            dt_all = np.concatenate([self.doc[(w, c)]
                                     for w in range(n_workers)])
            lls.append(log_likelihood(corpus, wt, tc, dt_all, ids,
                                      alpha, beta))
        return lls


def _make_update_fn(states: List[_WorkerState], V: int, alpha: float,
                    beta: float, snapshots: Optional[_Snapshots] = None):
    def update_fn(w: int, clock: int, view, wrng: np.random.Generator):
        st = states[w]
        wt = view.get("word_topic")
        tc = view.get("topic")
        if snapshots is not None:
            # worker-local + before the sweep: race-free and deterministic
            snapshots.doc[(w, clock)] = st.doc_topic.copy()
            if w == 0:
                snapshots.view[clock] = (wt.copy(), tc.copy())
        d_wt, d_tc = _gibbs_sweep(st, wt, tc, V, alpha, beta, wrng)
        return {"word_topic": d_wt, "topic": d_tc}
    return update_fn


def run_lda(corpus: LDACorpus, n_topics: int, policy: Policy,
            n_workers: int, n_clocks: int, alpha: float = 0.1,
            beta: float = 0.01, seed: int = 0,
            network: Optional[NetworkModel] = None,
            straggler=None, collect_stats: bool = False,
            backend: str = "sim", threads_per_process: int = 1,
            n_shards: int = 2, barrier_reads: bool = False,
            snapshot_trajectory: bool = False, timeout: float = 300.0):
    """Returns the per-clock corpus log-likelihood list (and stats if asked).

    ``backend="sim"`` runs the event-driven simulator (``network`` /
    ``straggler`` model the cluster); ``backend="runtime"`` runs the real
    threaded PS (``threads_per_process`` / ``n_shards`` / ``barrier_reads``
    configure it; latency is wall-clock, so ``network`` and ``straggler`` are
    ignored).
    """
    V, K = corpus.vocab_size, n_topics
    shards, states, wt0, tc0 = _init_states(corpus, n_topics, n_workers, seed)

    snapshots = _Snapshots() if snapshot_trajectory else None
    update_fn = _make_update_fn(states, V, alpha, beta, snapshots)

    lls: List[float] = []

    # wrap update_fn to record the log-likelihood once per full clock
    # (legacy recording: approximate under the threaded runtime, where peer
    # doc-topic states are mid-sweep; use snapshot_trajectory for exactness)
    def wrapped(w, clock, view, wrng):
        out = update_fn(w, clock, view, wrng)
        if w == 0 and snapshots is None:
            wt = view.get("word_topic")
            tc = view.get("topic")
            dt_all = np.concatenate([s.doc_topic for s in states])
            ids = [i for sh in shards for i in sh]
            lls.append(log_likelihood(corpus, wt, tc, dt_all, ids, alpha, beta))
        return out

    if backend == "sim":
        # a clock sweeps the worker's shard once: compute time ∝ tokens owned
        # (per-token Gibbs cost normalized to 1ms) — strong scaling shrinks it
        tokens_of = [sum(len(d) for d in st.docs) for st in states]
        ps = AsyncPS(n_workers, policy,
                     {"word_topic": wt0, "topic": tc0},
                     network=network or NetworkModel(seed=seed),
                     compute_time=lambda w: 0.001 * tokens_of[w],
                     straggler=straggler, seed=seed)
        stats = ps.run(wrapped, n_clocks)
    elif backend == "runtime":
        from repro.runtime import PSRuntime, RuntimeConfig
        rt = PSRuntime(RuntimeConfig(n_workers, policy,
                       {"word_topic": wt0, "topic": tc0},
                       n_shards=n_shards,
                       threads_per_process=threads_per_process,
                       seed=seed, barrier_reads=barrier_reads))
        stats = rt.run(wrapped, n_clocks, timeout=timeout)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if snapshots is not None:
        lls = snapshots.trajectory(corpus, shards, n_workers, n_clocks,
                                   alpha, beta)
    if collect_stats:
        return lls, stats
    return lls


class _DictView:
    """Minimal ViewHandle over plain arrays (the SPMD replica's params)."""

    def __init__(self, arrays: Dict[str, np.ndarray]):
        self._a = arrays
        self.gets = 0

    def get(self, key: str) -> np.ndarray:
        self.gets += 1
        return self._a[key].copy()

    def keys(self):
        return list(self._a.keys())


def run_lda_spmd(corpus: LDACorpus, n_topics: int, n_workers: int,
                 n_clocks: int, policy: Optional[Policy] = None,
                 alpha: float = 0.1, beta: float = 0.01, seed: int = 0
                 ) -> List[float]:
    """LDA on the SPMD sync layer (:mod:`repro.core.sync`).

    Each worker is a data-parallel replica holding a drifting copy of the
    count tables; per clock the host computes the Gibbs deltas from each
    replica's view, then :func:`repro.core.sync.apply_and_sync` runs under
    ``jax.vmap(axis_name="data")`` so the named-axis collectives execute
    without a multi-device mesh.  Counts are small integers — exact in
    float32 — so under BSP the trajectory is element-wise identical to the
    simulator's and the threaded runtime's (the conformance suite's point).

    Returns the period-start snapshot trajectory (see module docstring).
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import policies as P
    from repro.core import sync

    policy = policy or P.bsp()
    V = corpus.vocab_size
    shards, states, wt0, tc0 = _init_states(corpus, n_topics, n_workers, seed)
    snapshots = _Snapshots()
    update_fn = _make_update_fn(states, V, alpha, beta, snapshots)
    rngs = [np.random.default_rng(seed * 7919 + w) for w in range(n_workers)]

    one = {"word_topic": jnp.asarray(wt0, jnp.float32),
           "topic": jnp.asarray(tc0, jnp.float32)}
    params = jax.tree.map(lambda x: jnp.stack([x] * n_workers), one)
    sync_states = jax.tree.map(lambda x: jnp.stack([x] * n_workers),
                               sync.init_sync_state(one))

    @functools.partial(jax.jit, static_argnames=("pol",))
    def step(p, s, u, pol):
        f = jax.vmap(
            lambda pp, ss, uu: sync.apply_and_sync(pp, ss, uu, pol,
                                                   dp_axes=("data",)),
            axis_name="data")
        return f(p, s, u)

    for clock in range(n_clocks):
        host = {k: np.asarray(v, dtype=np.float64)
                for k, v in params.items()}                 # (P, ...) views
        ups = []
        for w in range(n_workers):
            view = _DictView({"word_topic": host["word_topic"][w],
                              "topic": host["topic"][w]})
            ups.append(update_fn(w, clock, view, rngs[w]))
        u = {k: jnp.stack([jnp.asarray(up[k], jnp.float32) for up in ups])
             for k in params}
        params, sync_states, _ = step(params, sync_states, u, policy)

    return snapshots.trajectory(corpus, shards, n_workers, n_clocks,
                                alpha, beta)
