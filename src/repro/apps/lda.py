"""Collapsed-Gibbs LDA on the asynchronous parameter server (paper §5).

The shared state lives in two PS keys — ``word_topic`` (V × K counts) and
``topic`` (K counts) — exactly the tables YahooLDA/Petuum shard; per-document
topic counts and assignments are worker-local.  Each clock a worker sweeps
its document shard with collapsed Gibbs against its (possibly stale /
value-bounded) view and emits the count deltas, which is the paper's
evaluation workload for the consistency models.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.policies import Policy
from repro.core.server import AsyncPS, NetworkModel
from repro.data.lda_corpus import LDACorpus


class _WorkerState:
    def __init__(self, docs, n_topics: int, rng: np.random.Generator):
        self.docs = docs
        self.assign = [rng.integers(0, n_topics, size=len(d)) for d in docs]
        self.doc_topic = np.zeros((len(docs), n_topics), dtype=np.float64)
        for i, zs in enumerate(self.assign):
            np.add.at(self.doc_topic[i], zs, 1.0)


def _initial_counts(states: List[_WorkerState], vocab: int, K: int):
    wt = np.zeros((vocab, K))
    tc = np.zeros(K)
    for st in states:
        for d, zs in zip(st.docs, st.assign):
            np.add.at(wt, (d, zs), 1.0)
            np.add.at(tc, zs, 1.0)
    return wt, tc


def log_likelihood(corpus: LDACorpus, wt: np.ndarray, tc: np.ndarray,
                   doc_topic: np.ndarray, doc_ids, alpha: float,
                   beta: float) -> float:
    """doc_topic rows follow the order of doc_ids (concatenated shards)."""
    V, K = wt.shape
    phi = (wt + beta) / (tc + V * beta)[None, :]           # (V, K)
    ll = 0.0
    for row, gid in enumerate(doc_ids):
        d = corpus.docs[gid]
        theta = doc_topic[row] + alpha
        theta = theta / theta.sum()
        p = phi[d] @ theta
        ll += float(np.log(np.maximum(p, 1e-12)).sum())
    return ll


def run_lda(corpus: LDACorpus, n_topics: int, policy: Policy,
            n_workers: int, n_clocks: int, alpha: float = 0.1,
            beta: float = 0.01, seed: int = 0,
            network: Optional[NetworkModel] = None,
            straggler=None, collect_stats: bool = False):
    """Returns the per-clock corpus log-likelihood list (and stats if asked)."""
    rng = np.random.default_rng(seed)
    V, K = corpus.vocab_size, n_topics
    shards = [list(range(w, corpus.n_docs, n_workers)) for w in range(n_workers)]
    states = [_WorkerState([corpus.docs[i] for i in sh], K, rng)
              for sh in shards]
    wt0, tc0 = _initial_counts(states, V, K)

    lls: List[float] = []

    def update_fn(w: int, clock: int, view, wrng: np.random.Generator):
        st = states[w]
        wt = view.get("word_topic")
        tc = view.get("topic")
        d_wt = np.zeros_like(wt)
        d_tc = np.zeros_like(tc)
        for di, doc in enumerate(st.docs):
            dt = st.doc_topic[di]
            zs = st.assign[di]
            for ti, word in enumerate(doc):
                z = zs[ti]
                # remove current assignment (local view)
                dt[z] -= 1
                d_wt[word, z] -= 1
                d_tc[z] -= 1
                nw = np.maximum(wt[word] + d_wt[word] + beta, beta)
                nt = np.maximum(tc + d_tc + V * beta, V * beta)
                p = (dt + alpha) * nw / nt
                p = np.maximum(p, 1e-12)
                z_new = wrng.choice(K, p=p / p.sum())
                zs[ti] = z_new
                dt[z_new] += 1
                d_wt[word, z_new] += 1
                d_tc[z_new] += 1
        return {"word_topic": d_wt, "topic": d_tc}

    # a clock sweeps the worker's shard once: compute time ∝ tokens owned
    # (per-token Gibbs cost normalized to 1ms) — strong scaling shrinks it
    tokens_of = [sum(len(d) for d in st.docs) for st in states]
    ps = AsyncPS(n_workers, policy,
                 {"word_topic": wt0, "topic": tc0},
                 network=network or NetworkModel(seed=seed),
                 compute_time=lambda w: 0.001 * tokens_of[w],
                 straggler=straggler, seed=seed)

    # wrap update_fn to record the log-likelihood once per full clock
    done_clocks = [0]
    orig = update_fn

    def wrapped(w, clock, view, wrng):
        out = orig(w, clock, view, wrng)
        if w == 0:
            wt = view.get("word_topic")
            tc = view.get("topic")
            dt_all = np.concatenate([s.doc_topic for s in states])
            ids = [i for sh in shards for i in sh]
            lls.append(log_likelihood(corpus, wt, tc, dt_all, ids, alpha, beta))
        return out

    stats = ps.run(wrapped, n_clocks)
    if collect_stats:
        return lls, stats
    return lls
