"""Distributed logistic regression on the asynchronous parameter server.

One PS key — the weight vector ``w`` — and a row-sharded synthetic binary
classification problem: each clock a worker computes the L2-regularized
logistic-loss gradient of its shard against its (possibly stale /
bound-gated) view and emits ``-lr * grad``.  The convex objective makes
the staleness penalty clean to read off the loss curve, which is why this
is the second workload of :mod:`benchmarks.bench_convergence`.

Runs on the executable spec (``backend="sim"``) and on the live threaded
runtime (``backend="runtime"``), exactly like :mod:`repro.apps.mf`.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.policies import Policy
from repro.core.server import AsyncPS, NetworkModel


def synthetic_classification(n: int = 400, d: int = 20, noise: float = 0.5,
                             seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Linearly separable-ish labels from a planted weight vector."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0.0, 1.0, (n, d))
    wstar = rng.normal(0.0, 1.0, d)
    y = np.where(X @ wstar + rng.normal(0.0, noise, n) > 0.0, 1.0, -1.0)
    return X, y


def log_loss(X: np.ndarray, y: np.ndarray, w: np.ndarray,
             reg: float = 0.0) -> float:
    m = y * (X @ w)
    return float(np.mean(np.logaddexp(0.0, -m)) + 0.5 * reg * w @ w)


def _grad_shard(X: np.ndarray, y: np.ndarray, w: np.ndarray,
                reg: float) -> np.ndarray:
    m = y * (X @ w)
    s = -y / (1.0 + np.exp(m))                       # d loss / d margin
    return X.T @ s / max(len(y), 1) + reg * w


def run_logreg(X: np.ndarray, y: np.ndarray, policy: Policy,
               n_workers: int, n_clocks: int, lr: float = 0.5,
               reg: float = 1e-3, seed: int = 0,
               network: Optional[NetworkModel] = None, straggler=None,
               collect_stats: bool = False, backend: str = "sim",
               threads_per_process: int = 1, n_shards: int = 2,
               timeout: float = 300.0):
    """Returns the per-clock full-data log-loss list (and stats if asked).

    Worker 0 records the loss of its view at the top of every period, the
    same recording convention as :func:`repro.apps.mf.run_mf`.
    """
    d = X.shape[1]
    Xs = [X[w::n_workers] for w in range(n_workers)]
    ys = [y[w::n_workers] for w in range(n_workers)]
    losses: List[float] = []

    def update_fn(w: int, clock: int, view, wrng: np.random.Generator):
        wv = view.get("w")
        if w == 0:
            losses.append(log_loss(X, y, wv, reg))
        return {"w": -lr * _grad_shard(Xs[w], ys[w], wv, reg)}

    x0 = {"w": np.zeros(d)}
    if backend == "sim":
        ps = AsyncPS(n_workers, policy, x0,
                     network=network or NetworkModel(seed=seed),
                     straggler=straggler, seed=seed)
        stats = ps.run(update_fn, n_clocks)
    elif backend == "runtime":
        from repro.runtime import PSRuntime, RuntimeConfig
        rt = PSRuntime(RuntimeConfig(n_workers, policy, x0,
                       n_shards=n_shards,
                       threads_per_process=threads_per_process, seed=seed))
        stats = rt.run(update_fn, n_clocks, timeout=timeout)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if collect_stats:
        return losses, stats
    return losses
