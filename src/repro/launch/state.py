"""Train state: per-replica parameters + optimizer + consistency sync state.

GLOBAL layout: every leaf carries a leading ``dp`` axis sharded over the
data-parallel mesh axes — each data-parallel replica owns a (drifting) copy,
which is exactly the paper's per-worker parameter replica.  Per-device
memory equals plain replication (DESIGN.md §3).  Inside shard_map the local
slice has leading dim 1; steps squeeze/unsqueeze it uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.sync import SyncState, init_sync_state
from repro.models import model as M
from repro.models.common import instantiate_tree
from repro.optim import OptState, init_opt_state

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: OptState
    sync: SyncState
    step: jnp.ndarray


def init_local_state(cfg: ModelConfig, tcfg: TrainConfig, tp: int,
                     key: jax.Array) -> TrainState:
    """Single-replica state (no dp axis)."""
    defs = M.model_defs(cfg, tp)
    params = instantiate_tree(defs, key)
    sdt = jnp.dtype(tcfg.state_dtype) if tcfg.state_dtype != "float32" else None
    return TrainState(
        params=params,
        opt=init_opt_state(params, tcfg.optimizer, dtype=sdt),
        sync=init_sync_state(params, hierarchy=tcfg.hierarchical_sync,
                             compress="bf16" if tcfg.quantize_sync else None,
                             dtype=sdt),
        step=jnp.zeros((), jnp.int32),
    )


def add_dp_axis(state: TrainState, dp: int) -> TrainState:
    """Broadcast one replica's state to `dp` identical replicas (the paper's
    common x0)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (dp,) + x.shape), state)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, tp: int, dp: int,
                     key: jax.Array) -> TrainState:
    return add_dp_axis(init_local_state(cfg, tcfg, tp, key), dp)


def squeeze_dp(state: TrainState) -> TrainState:
    return jax.tree.map(lambda x: x[0], state)


def unsqueeze_dp(state: TrainState) -> TrainState:
    return jax.tree.map(lambda x: x[None], state)
