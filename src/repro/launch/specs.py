"""Partition specs and global shapes for state, batches, and caches.

The "dp" marker stands for the data-parallel mesh axes and is resolved to
``('data',)`` or ``('pod', 'data')`` per mesh.  ``input_specs`` returns
ShapeDtypeStructs with attached NamedShardings — the dry-run lowers against
them without allocating anything.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.launch import mesh as mesh_lib
from repro.launch.state import TrainState, init_local_state
from repro.core.sync import SyncState
from repro.models import model as M
from repro.models.common import ParamDef, pspec_tree
from repro.optim import OptState

PyTree = Any
DP = "dp"


def _resolve(spec, dp_axes: Tuple[str, ...]) -> P:
    parts = []
    for s in spec:
        if s == DP:
            parts.append(dp_axes if len(dp_axes) != 1 else dp_axes[0])
        else:
            parts.append(s)
    return P(*parts)


def resolve_tree(specs: PyTree, dp_axes: Tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda s: _resolve(s, dp_axes), specs,
                        is_leaf=lambda x: isinstance(x, P))


def shardings(specs: PyTree, mesh) -> PyTree:
    dp_axes = mesh_lib.dp_axes_of(mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, _resolve(s, dp_axes)),
                        specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train-state specs
# ---------------------------------------------------------------------------


def train_state_pspecs(cfg: ModelConfig, tcfg: TrainConfig, tp: int) -> TrainState:
    """Spec tree mirroring TrainState (leaves: PartitionSpec with DP marker,
    leading dp axis on every leaf)."""
    defs = M.model_defs(cfg, tp)
    psp = pspec_tree(defs)
    abs_local = jax.eval_shape(
        lambda k: init_local_state(cfg, tcfg, tp, k), jax.random.key(0))

    def mirror(abs_sub: PyTree) -> PyTree:
        return jax.tree.map(
            lambda s, p: p if s.ndim > 0 else P(), abs_sub, psp)

    spec = TrainState(
        params=psp,
        opt=OptState(mu=mirror(abs_local.opt.mu), nu=mirror(abs_local.opt.nu),
                     count=P()),
        sync=SyncState(delta=mirror(abs_local.sync.delta),
                       residual=mirror(abs_local.sync.residual),
                       pod_pending=mirror(abs_local.sync.pod_pending),
                       steps_since_sync=P(), sync_count=P(),
                       max_update_mag=P(), max_update_l2=P()),
        step=P(),
    )
    return jax.tree.map(lambda s: P(DP, *s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig, tp: int,
                         dp: int) -> TrainState:
    abs_local = jax.eval_shape(
        lambda k: init_local_state(cfg, tcfg, tp, k), jax.random.key(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dp,) + s.shape, s.dtype), abs_local)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def _batch_dp(B: int, dp_total: int) -> Optional[str]:
    """Shard batch over dp only when divisible (long_500k has B=1)."""
    return DP if dp_total > 1 and B % dp_total == 0 else None


def train_batch_specs(cfg: ModelConfig, shape: InputShape, dp_total: int,
                      ) -> Tuple[Dict, Dict]:
    B, S = shape.global_batch, shape.seq_len
    bspec = _batch_dp(B, dp_total)
    # ids/labels are REPLICATED over the model axis (vocab-parallel embedding)
    abst = {"ids": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    spec = {"ids": P(bspec, None), "labels": P(bspec, None)}
    if cfg.frontend is not None:
        abst["extra_emb"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend.n_embeds, cfg.d_model), jnp.dtype(cfg.dtype))
        spec["extra_emb"] = P(bspec, None, None)
    return abst, spec


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape, dp_total: int,
                        ) -> Tuple[Dict, Dict]:
    B, S = shape.global_batch, shape.seq_len
    bspec = _batch_dp(B, dp_total)
    abst = {"ids": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    spec = {"ids": P(bspec, None)}
    if cfg.frontend is not None:
        abst["extra_emb"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend.n_embeds, cfg.d_model), jnp.dtype(cfg.dtype))
        spec["extra_emb"] = P(bspec, None, None)
    return abst, spec


def decode_batch_specs(cfg: ModelConfig, shape: InputShape, dp_total: int,
                       ) -> Tuple[Dict, Dict]:
    B = shape.global_batch
    bspec = _batch_dp(B, dp_total)
    abst = {"ids": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32)}
    spec = {"ids": P(bspec, None), "pos": P(bspec)}
    return abst, spec


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def _attn_cache_pspec(cfg: ModelConfig, bspec) -> Dict[str, P]:
    if cfg.mla is not None:
        return {"latent": P(bspec, None, None), "k_rope": P(bspec, None, None),
                "pos": P(bspec, None)}
    if cfg.tp_strategy == "head":
        return {"k": P(bspec, None, "model", None),
                "v": P(bspec, None, "model", None), "pos": P(bspec, None)}
    if cfg.tp_strategy == "seq":
        return {"k": P(bspec, "model", None, None),
                "v": P(bspec, "model", None, None), "pos": P(bspec, "model")}
    return {"k": P(bspec, None, None, None), "v": P(bspec, None, None, None),
            "pos": P(bspec, None)}


def _rec_cache_pspec(cfg: ModelConfig, bspec) -> Dict[str, P]:
    if cfg.recurrent.kind == "rglru":
        if cfg.tp_strategy == "head":
            return {"h": P(bspec, "model"), "conv": P(bspec, None, "model")}
        return {"h": P(bspec, None), "conv": P(bspec, None, None)}
    return {"h": P(bspec, None, None, None), "conv": P(bspec, None, None)}


def model_cache_pspecs(cfg: ModelConfig, B: int, dp_total: int,
                       long_ctx: bool = False) -> Dict:
    bspec = _batch_dp(B, dp_total)
    metas = M.layer_metas(cfg, long_ctx)
    prefix, unit, n_units, tail = M.group_layers(cfg, metas)

    def block(meta):
        return (_attn_cache_pspec(cfg, bspec) if meta.kind == "attn"
                else _rec_cache_pspec(cfg, bspec))

    def stack(spec):
        return jax.tree.map(lambda s: P(None, *s), spec,
                            is_leaf=lambda x: isinstance(x, P))

    return {"prefix": [block(m) for m in prefix],
            "scan": [stack(block(m)) for m in unit],
            "tail": [block(m) for m in tail]}


def global_cache_abstract(cfg: ModelConfig, shape: InputShape, dp_total: int,
                          tp: int, long_ctx: bool = False) -> Dict:
    """Global ShapeDtypeStructs for the decode caches: take the LOCAL cache
    defs and expand each dim by the size of the mesh axis its spec names."""
    B = shape.global_batch
    bspec = _batch_dp(B, dp_total)
    b_loc = B // dp_total if bspec is not None else B
    local = M.model_cache_defs(cfg, tp, b_loc, shape.seq_len, long_ctx)
    specs = model_cache_pspecs(cfg, B, dp_total, long_ctx)

    def globalize(sds: jax.ShapeDtypeStruct, spec: P) -> jax.ShapeDtypeStruct:
        shape_ = list(sds.shape)
        for i, ax in enumerate(spec):
            if ax == DP:
                shape_[i] *= dp_total
            elif ax == "model":
                shape_[i] *= tp
        return jax.ShapeDtypeStruct(tuple(shape_), sds.dtype)

    return jax.tree.map(globalize, local, specs,
                        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
