import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, record memory/cost/collective analysis for the roofline.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 combos
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results are cached incrementally in results/dryrun/<arch>__<shape>__<mesh>.json
(delete a file to redo it).  Each record holds:
  * memory_analysis  — per-device argument/output/temp/peak bytes (proves fit)
  * cost_analysis    — HLO FLOPs + bytes accessed (roofline compute/memory)
  * collectives      — per-op-kind byte totals parsed from the compiled HLO
                       (roofline collective term; cost_analysis lacks these)
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.configs import (ARCHS, ConsistencySpec, TrainConfig, get_config,
                           get_shape)
from repro.configs.shapes import INPUT_SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch import specs as S
from repro.launch import steps

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(txt: str) -> int:
    """Total bytes of every typed shape literal in an HLO op line."""
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum OUTPUT shape bytes of every collective op, by kind (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "%name = <shape(s)> <kind>(" — the op kind right before '('
        m = re.search(r"=\s+(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", ls)
        if not m:
            continue
        kind, phase = m.group(2), m.group(3)
        if phase == "-done":
            continue           # started ops already counted
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    d["peak_bytes_estimate"] = (
        d.get("argument_size_in_bytes", 0) + d.get("temp_size_in_bytes", 0)
        + max(0, d.get("output_size_in_bytes", 0) - d.get("alias_size_in_bytes", 0)))
    return d


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {}
    for k, v in ca.items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            out[k] = float(v)
    return out


def layer_counts(cfg, long_ctx: bool):
    """(n_layers for a 1-unit variant, n_layers for a 0-unit variant,
    n_units of the full config)."""
    from repro.models import model as M
    metas = M.layer_metas(cfg, long_ctx)
    prefix, unit, n_units, tail = M.group_layers(cfg, metas)
    start, period, tail_len = len(prefix), len(unit), len(tail)
    return start + period + tail_len, start + tail_len, n_units


def build_lowerable(arch: str, shape_name: str, mesh, consistency: str,
                    staleness: int, vthr: float, unroll: bool = False,
                    n_layers_override=None, state_dtype: str = "float32"):
    """Returns (fn, example_args) ready for jit(...).lower(*args)."""
    cfg = get_config(arch)
    if n_layers_override is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers_override)
    shape = get_shape(shape_name)
    long_ctx = shape_name == "long_500k"
    dp_total = mesh_lib.dp_size(mesh)
    tp = mesh_lib.tp_size(mesh)

    def sds_with(specs_tree, abstract_tree_):
        sh = S.shardings(specs_tree, mesh)
        return jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            abstract_tree_, sh)

    if shape.mode == "train":
        tcfg = TrainConfig(
            arch=arch, shape=shape_name, state_dtype=state_dtype,
            consistency=ConsistencySpec(model=consistency, staleness=staleness,
                                        value_bound=vthr))
        # donation ON: the deployable configuration aliases state in/out,
        # which is what the memory_analysis should reflect
        fn = steps.make_train_step(cfg, tcfg, mesh, donate=True, unroll=unroll)
        state_abs = S.abstract_train_state(cfg, tcfg, tp, dp_total)
        state_spec = S.train_state_pspecs(cfg, tcfg, tp)
        batch_abs, batch_spec = S.train_batch_specs(cfg, shape, dp_total)
        args = (sds_with(state_spec, state_abs), sds_with(batch_spec, batch_abs))
        return fn, args

    from repro.models import model as M
    from repro.models.common import pspec_tree
    defs = M.model_defs(cfg, tp, long_ctx)
    param_abs = jax.tree.map(lambda d: d.abstract(), defs,
                             is_leaf=lambda x: hasattr(x, "abstract"))
    param_spec = pspec_tree(defs)

    if shape.mode == "prefill":
        fn = steps.make_prefill_step(cfg, mesh, shape, long_ctx, unroll=unroll)
        batch_abs, batch_spec = S.prefill_batch_specs(cfg, shape, dp_total)
        args = (sds_with(param_spec, param_abs), sds_with(batch_spec, batch_abs))
        return fn, args

    # decode
    fn = steps.make_serve_step(cfg, mesh, shape, long_ctx, unroll=unroll)
    batch_abs, batch_spec = S.decode_batch_specs(cfg, shape, dp_total)
    cache_abs = S.global_cache_abstract(cfg, shape, dp_total, tp, long_ctx)
    cache_spec = S.model_cache_pspecs(cfg, shape.global_batch, dp_total, long_ctx)
    args = (sds_with(param_spec, param_abs),
            sds_with(cache_spec, cache_abs),
            sds_with(batch_spec, batch_abs))
    return fn, args


def run_one(arch: str, shape_name: str, multi_pod: bool, consistency: str,
            staleness: int, vthr: float, save: bool = True,
            state_dtype: str = "float32") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    sfx = "" if state_dtype == "float32" else f"__{state_dtype}"
    tag = f"{arch}__{shape_name}__{mesh_name}__{consistency}{sfx}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, tag + ".json")
    if save and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            print(f"[cached] {tag}")
            return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "consistency": consistency, "staleness": staleness, "vthr": vthr,
           "ok": False}
    t0 = time.time()
    try:
        # Pass A — full model, scan-over-layers (the deployable program):
        # memory analysis + compile-success proof.
        fn, args = build_lowerable(arch, shape_name, mesh, consistency,
                                   staleness, vthr, state_dtype=state_dtype)
        lowered = fn.lower(*args)
        rec["lower_seconds"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = time.time() - t1
        rec["memory"] = _mem_dict(compiled)
        rec["cost_scan_raw"] = _cost_dict(compiled)

        # Passes B/C — 1-unit and 0-unit variants, UNROLLED (cost_analysis
        # does not count while-loop bodies at all, so layer work must appear
        # outside any loop).  Per-layer terms are recovered by differencing
        # and scaled by n_units — cost terms are exactly linear in the layer
        # count (sync/optimizer collectives scale with the parameter count,
        # which the difference captures).
        long_ctx = shape_name == "long_500k"
        cfg_full = get_config(arch)
        n1, n0, n_units = layer_counts(cfg_full, long_ctx)
        rec["n_units"] = n_units
        t2 = time.time()
        fn1, args1 = build_lowerable(arch, shape_name, mesh, consistency,
                                     staleness, vthr, unroll=True,
                                     n_layers_override=n1,
                                     state_dtype=state_dtype)
        comp1 = fn1.lower(*args1).compile()
        cost1, coll1 = _cost_dict(comp1), collective_bytes(comp1.as_text())
        fn0, args0 = build_lowerable(arch, shape_name, mesh, consistency,
                                     staleness, vthr, unroll=True,
                                     n_layers_override=n0,
                                     state_dtype=state_dtype)
        comp0 = fn0.lower(*args0).compile()
        cost0, coll0 = _cost_dict(comp0), collective_bytes(comp0.as_text())
        rec["compile_seconds_units"] = time.time() - t2

        def scale(v0, v1):
            return max(0.0, v0 + n_units * (v1 - v0))

        rec["cost"] = {k: scale(cost0.get(k, 0.0), cost1.get(k, 0.0))
                       for k in set(cost0) | set(cost1)}
        rec["collectives"] = {
            "bytes": {k: int(scale(coll0["bytes"].get(k, 0),
                                   coll1["bytes"].get(k, 0)))
                      for k in coll1["bytes"]},
            "counts": {k: int(scale(coll0["counts"].get(k, 0),
                                    coll1["counts"].get(k, 0)))
                       for k in coll1["counts"]},
        }
        rec["collectives"]["total_bytes"] = sum(rec["collectives"]["bytes"].values())
        rec["ok"] = True
        print(f"[ok] {tag}: compile {rec['compile_seconds']:.1f}s "
              f"peak/device={rec['memory'].get('peak_bytes_estimate', 0)/2**30:.2f}GiB "
              f"flops={rec['cost'].get('flops', 0):.3e} "
              f"coll={rec['collectives']['total_bytes']/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {tag}: {rec['error']}")
    if save:
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) combination")
    ap.add_argument("--consistency", default="cvap",
                    choices=["bsp", "ssp", "cap", "vap", "cvap"])
    ap.add_argument("--staleness", type=int, default=3)
    ap.add_argument("--vthr", type=float, default=0.05)
    ap.add_argument("--state-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in sorted(ARCHS):
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    n_ok = 0
    for a, s in combos:
        rec = run_one(a, s, args.multi_pod, args.consistency, args.staleness,
                      args.vthr, state_dtype=args.state_dtype)
        n_ok += bool(rec.get("ok"))
    print(f"\n{n_ok}/{len(combos)} combinations compiled successfully")
    if n_ok < len(combos):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
