"""Production meshes (DESIGN.md §6).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS --xla_force_host_platform_device_count
*before* any jax import (see dryrun.py) to obtain 256/512 host devices.
"""
from __future__ import annotations

from typing import Tuple

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=...)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    Replication checking is disabled in both spellings (the step functions
    use explicit collectives).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; 2 pods when multi_pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes_of(mesh):
        out *= mesh.shape[a]
    return out


def tp_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
