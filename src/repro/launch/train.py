"""Training driver.

On real hardware this runs under the production mesh; on CPU it runs
single-device with the reduced ("smoke") architecture variants, which is
what the end-to-end example uses:

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 200 --consistency cvap --staleness 4 --vthr 0.05
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import (ARCHS, ConsistencySpec, TrainConfig, get_config,
                           reduced_config)
from repro.core.sync import force_sync
from repro.data import SyntheticLM, batches
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.launch.state import init_train_state
from repro.models.common import ShardCtx


def run(tcfg: TrainConfig, cfg, mesh=None, batch_size: int = 8,
        seq_len: int = 64, log=print):
    dp = mesh_lib.dp_size(mesh) if mesh is not None else 1
    tp = mesh_lib.tp_size(mesh) if mesh is not None else 1
    state = init_train_state(cfg, tcfg, tp=tp, dp=dp,
                             key=jax.random.key(tcfg.seed))
    step_fn = steps.make_train_step(cfg, tcfg, mesh)
    source = SyntheticLM(cfg.vocab_size, seed=tcfg.seed)
    it = batches(source, batch_size, seq_len)
    history = []
    t0 = time.time()
    rng = np.random.default_rng(tcfg.seed)
    for i in range(tcfg.steps):
        b = next(it)
        batch = {"ids": jnp.asarray(b["ids"]), "labels": jnp.asarray(b["labels"])}
        if cfg.frontend is not None:
            batch["extra_emb"] = jnp.asarray(
                rng.normal(0, 0.02, (batch_size, cfg.frontend.n_embeds,
                                     cfg.d_model)), jnp.dtype(cfg.dtype))
        state, metrics = step_fn(state, batch)
        if i % tcfg.log_every == 0 or i == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall"] = time.time() - t0
            history.append(m)
            log(f"step {i:5d} loss={m['loss']:.4f} xent={m['xent']:.4f} "
                f"synced={m['synced']:.0f} lr={m['lr']:.2e}")
        if (tcfg.checkpoint_dir and tcfg.checkpoint_every
                and i and i % tcfg.checkpoint_every == 0):
            _checkpoint(tcfg, state, i)
    if tcfg.checkpoint_dir:
        _checkpoint(tcfg, state, tcfg.steps)
    return state, history


def _checkpoint(tcfg: TrainConfig, state, step: int) -> None:
    # sync replicas first: checkpoints hold the fully-synchronized state
    params = jax.tree.map(lambda x: x[0], state.params)
    sync = jax.tree.map(lambda x: x[0], state.sync)
    params, _ = force_sync(params, sync, ())
    save_checkpoint(tcfg.checkpoint_dir, step, params,
                    metadata={"arch": tcfg.arch,
                              "consistency": tcfg.consistency.model})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced CPU-runnable variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adam",
                    choices=["sgd", "momentum", "adam"])
    ap.add_argument("--consistency", default="bsp",
                    choices=["bsp", "ssp", "cap", "vap", "cvap"])
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--vthr", type=float, default=0.0)
    ap.add_argument("--quantize-sync", action="store_true")
    ap.add_argument("--hierarchical-sync", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--history-out", default="")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    tcfg = TrainConfig(
        arch=args.arch, steps=args.steps, lr=args.lr, optimizer=args.optimizer,
        seed=args.seed,
        consistency=ConsistencySpec(model=args.consistency,
                                    staleness=args.staleness,
                                    value_bound=args.vthr),
        quantize_sync=args.quantize_sync,
        hierarchical_sync=args.hierarchical_sync,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    _, history = run(tcfg, cfg, mesh=None, batch_size=args.batch,
                     seq_len=args.seq)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=2)


if __name__ == "__main__":
    main()
