"""jit + shard_map step factories: train_step, prefill_step, serve_step.

``train_step`` is where the paper's technique lives in the TPU runtime:
grads → local optimizer update → ``core.sync.apply_and_sync`` (read-my-writes
apply + policy-triggered delta all-reduce over the data-parallel axes).

Gradients of model-axis-replicated leaves (routers, norm scales, seq-TP
projections) are psum'd over the model axis so replicated copies stay
bitwise identical (Megatron rule); model-sharded leaves' grads are already
complete.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.core import policies as pol
from repro.core.sync import apply_and_sync
from repro.launch import mesh as mesh_lib
from repro.launch import specs as S
from repro.launch.state import TrainState, squeeze_dp, unsqueeze_dp
from repro.models import model as M
from repro.models.common import ParamDef, ShardCtx
from repro.optim import optimizer_update
from repro.optim.schedule import constant, linear_warmup

PyTree = Any


def make_ctx(mesh) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    return ShardCtx(model_axis="model", dp_axes=mesh_lib.dp_axes_of(mesh),
                    tp=mesh_lib.tp_size(mesh))


def _replicated_leaf_mask(cfg: ModelConfig, tp: int) -> PyTree:
    """True for leaves with no 'model' sharding (grads need a model psum)."""
    defs = M.model_defs(cfg, tp)
    return jax.tree.map(
        lambda d: "model" not in (d.shard or ()), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    donate: bool = True, unroll: bool = False):
    """Returns jitted (state, batch) -> (state, metrics)."""
    ctx = make_ctx(mesh)
    policy = pol.from_spec(tcfg.consistency)
    lr_fn = linear_warmup(tcfg.lr, tcfg.warmup_steps, constant(tcfg.lr))
    opt_fn = optimizer_update(tcfg.optimizer)
    rep_mask = _replicated_leaf_mask(cfg, ctx.tp)
    all_axes = tuple(ctx.dp_axes) + ((ctx.model_axis,) if ctx.model_axis else ())
    pod_axis = "pod" if (mesh is not None and "pod" in mesh.axis_names) else None

    def local_step(state: TrainState, batch: Dict):
        st = squeeze_dp(state)

        def loss_fn(p):
            return M.lm_loss(cfg, ctx, p, batch["ids"], batch["labels"],
                             extra_emb=batch.get("extra_emb"),
                             remat=tcfg.remat, unroll=unroll)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(st.params)
        if ctx.model_axis is not None:
            # Every model shard computes the (identical) loss redundantly, so
            # each local grad carries a tp× seed multiplicity; replicated
            # leaves additionally need their per-copy partials summed.
            # Universal rule (validated per-leaf against single-device grads
            # in tests/test_distributed.py): (psum if replicated else id)/tp.
            grads = jax.tree.map(
                lambda g, rep: (ctx.psum_model(g) if rep else g) / ctx.tp,
                grads, rep_mask)
        lr = lr_fn(st.step)
        update, opt = opt_fn(grads, st.opt, lr,
                             weight_decay=tcfg.weight_decay, params=st.params)
        params, sync_state, synced = apply_and_sync(
            st.params, st.sync, update, policy, ctx.dp_axes,
            compress="bf16" if tcfg.quantize_sync else None,
            hierarchy=tcfg.hierarchical_sync, pod_axis=pod_axis,
            trigger_axes=all_axes)
        new = TrainState(params=params, opt=opt, sync=sync_state,
                         step=st.step + 1)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "xent": metrics["xent"].astype(jnp.float32),
            "aux": metrics["aux"],
            "synced": synced.astype(jnp.float32),
            "grad_norm": jnp.sqrt(sum(jnp.vdot(g, g).real
                                      for g in jax.tree.leaves(grads))).astype(jnp.float32),
            "lr": lr,
        }
        if all_axes:
            out_metrics = jax.tree.map(
                lambda m: lax.pmean(m, all_axes), out_metrics)
        return unsqueeze_dp(new), out_metrics

    if mesh is None:
        return jax.jit(local_step, donate_argnums=(0,) if donate else ())

    dp_axes = mesh_lib.dp_axes_of(mesh)
    state_spec = S.resolve_tree(S.train_state_pspecs(cfg, tcfg, ctx.tp), dp_axes)
    bdp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    batch_spec = {"ids": P(bdp, None), "labels": P(bdp, None)}
    if cfg.frontend is not None:
        batch_spec["extra_emb"] = P(bdp, None, None)
    metrics_spec = {k: P() for k in ("loss", "xent", "aux", "synced",
                                     "grad_norm", "lr")}
    f = mesh_lib.shard_map(local_step, mesh=mesh,
                           in_specs=(state_spec, batch_spec),
                           out_specs=(state_spec, metrics_spec))
    return jax.jit(f, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Prefill / serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape,
                      long_ctx: bool = False, unroll: bool = False):
    """(params, batch) -> (next_token (B,), caches)."""
    ctx = make_ctx(mesh)

    def local_prefill(params, batch):
        logits, caches = M.prefill(cfg, ctx, params, batch["ids"],
                                   capacity=shape.seq_len,
                                   extra_emb=batch.get("extra_emb"),
                                   long_ctx=long_ctx, unroll=unroll)
        nxt = M.sample_greedy(ctx, logits)
        return nxt, caches

    if mesh is None:
        return jax.jit(local_prefill)

    dp_axes = mesh_lib.dp_axes_of(mesh)
    dp_total = mesh_lib.dp_size(mesh)
    defs = M.model_defs(cfg, ctx.tp, long_ctx)
    from repro.models.common import pspec_tree
    param_spec = S.resolve_tree(pspec_tree(defs), dp_axes)
    babs, bspec = S.prefill_batch_specs(cfg, shape, dp_total)
    bspec = S.resolve_tree(bspec, dp_axes)
    cache_spec = S.resolve_tree(
        S.model_cache_pspecs(cfg, shape.global_batch, dp_total, long_ctx), dp_axes)
    bdp = bspec["ids"][0]
    out_specs = (P(bdp), cache_spec)
    f = mesh_lib.shard_map(local_prefill, mesh=mesh,
                           in_specs=(param_spec, bspec), out_specs=out_specs)
    return jax.jit(f)


def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                    long_ctx: bool = False, unroll: bool = False):
    """(params, caches, batch{ids,pos}) -> (next_token (B,), caches)."""
    ctx = make_ctx(mesh)

    def local_serve(params, caches, batch):
        logits, new_caches = M.decode_step(cfg, ctx, params, batch["ids"],
                                           batch["pos"], caches,
                                           long_ctx=long_ctx, unroll=unroll)
        nxt = M.sample_greedy(ctx, logits)
        return nxt, new_caches

    if mesh is None:
        return jax.jit(local_serve)

    dp_axes = mesh_lib.dp_axes_of(mesh)
    dp_total = mesh_lib.dp_size(mesh)
    defs = M.model_defs(cfg, ctx.tp, long_ctx)
    from repro.models.common import pspec_tree
    param_spec = S.resolve_tree(pspec_tree(defs), dp_axes)
    babs, bspec = S.decode_batch_specs(cfg, shape, dp_total)
    bspec = S.resolve_tree(bspec, dp_axes)
    cache_spec = S.resolve_tree(
        S.model_cache_pspecs(cfg, shape.global_batch, dp_total, long_ctx), dp_axes)
    bdp = bspec["pos"][0] if len(bspec["pos"]) else None
    f = mesh_lib.shard_map(local_serve, mesh=mesh,
                           in_specs=(param_spec, cache_spec, bspec),
                           out_specs=(P(bdp), cache_spec))
    return jax.jit(f, donate_argnums=(1,))    # caches are update-in-place
