"""Model assembly: blocks → scan groups → language model.

Layer grouping: the per-layer metadata (mixer kind, attention window, MoE?)
repeats with a short period (1 for uniform stacks, 2 for Gemma-2's
local/global alternation, 3 for RecurrentGemma's rec/rec/attn).  Layers are
stacked per unit-position and iterated with ``lax.scan`` (keeps the HLO and
compile times small at 40+ layers); non-periodic leading/trailing layers
(DeepSeek's dense layer 0, RecurrentGemma's 38 = 12·3 + 2 tail) are unrolled
prefix/tail.

Forward modes:
  * full   — train / prefill: sequence-sharded residual (b, s/tp, d)
  * decode — one token (b, 1, d) against per-layer caches

The LM head is vocab-sharded; cross-entropy uses a distributed logsumexp
over the model axis, chunked over the sequence so the (b, s, V/tp) logits
are never materialized at once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as ffn
from repro.models import recurrent as rec
from repro.models.common import (ParamDef, ShardCtx, apply_norm, norm_defs)

PyTree = Any


# ---------------------------------------------------------------------------
# Layer metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerMeta:
    kind: str                     # "attn" | "rec"
    window: Optional[int]         # attention window (None = full)
    use_moe: bool
    d_ff: int                     # dense FFN width (0 = no FFN)


def layer_metas(cfg: ModelConfig, long_ctx: bool = False) -> List[LayerMeta]:
    kinds = cfg.layer_kinds()
    metas = []
    attn_idx = 0
    for i, kind in enumerate(kinds):
        window = None
        if kind == "attn":
            if cfg.attn_kind == "swa":
                window = cfg.window
            elif cfg.attn_kind == "alternating":
                window = cfg.window if attn_idx % 2 == 0 else None
            attn_idx += 1
            if long_ctx and window is None:
                window = cfg.long_context_window   # bounded-memory long-context mode
        use_moe = cfg.moe is not None and kind == "attn" and i >= cfg.moe.first_dense_layers
        if cfg.moe is not None and not use_moe and kind == "attn":
            d_ff = cfg.moe.d_ff_dense
        else:
            d_ff = cfg.d_ff
        metas.append(LayerMeta(kind, window, use_moe, d_ff))
    return metas


def group_layers(cfg: ModelConfig, metas: List[LayerMeta],
                 ) -> Tuple[List[LayerMeta], List[LayerMeta], int, List[LayerMeta]]:
    """-> (prefix, unit, n_units, tail)."""
    start = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    period = len(cfg.layer_pattern)
    if cfg.attn_kind == "alternating":
        period = int(np.lcm(period, 2))
    body = metas[start:]
    n_units = len(body) // period
    tail_start = start + n_units * period
    prefix = metas[:start]
    unit = metas[start:start + period] if n_units else []
    tail = metas[tail_start:]
    return prefix, unit, n_units, tail


# ---------------------------------------------------------------------------
# Block defs / fwd
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, tp: int, meta: LayerMeta) -> Dict:
    defs: Dict[str, Any] = {"ln1": norm_defs(cfg.norm_kind, cfg.d_model)}
    if meta.kind == "attn":
        defs["mix"] = attn.attn_defs(cfg, tp)
    elif cfg.recurrent.kind == "rglru":
        defs["mix"] = rec.rglru_defs(cfg, tp)
    else:
        defs["mix"] = rec.mamba2_defs(cfg, tp)
    if cfg.post_norm:
        defs["post_ln1"] = norm_defs(cfg.norm_kind, cfg.d_model)
    if meta.d_ff or meta.use_moe:
        defs["ln2"] = norm_defs(cfg.norm_kind, cfg.d_model)
        defs["ffn"] = (ffn.moe_defs(cfg, tp) if meta.use_moe
                       else ffn.mlp_defs(cfg, tp, d_ff=meta.d_ff))
        if cfg.post_norm:
            defs["post_ln2"] = norm_defs(cfg.norm_kind, cfg.d_model)
    return defs


def block_fwd(cfg: ModelConfig, ctx: ShardCtx, mixer_ctx: ShardCtx,
              meta: LayerMeta, p: Dict, x: jnp.ndarray, *,
              cache: Optional[Dict], pos: Optional[jnp.ndarray],
              ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    seqpar = pos is None
    h = apply_norm(cfg.norm_kind, x, p["ln1"])
    if meta.kind == "attn":
        mix, new_cache = attn.attn_fwd(cfg, mixer_ctx, p["mix"], h,
                                       window=meta.window, cache=cache, pos=pos)
    elif cfg.recurrent.kind == "rglru":
        mix, new_cache = rec.rglru_fwd(cfg, mixer_ctx, p["mix"], h,
                                       cache=cache, pos=pos)
    else:
        mix, new_cache = rec.mamba2_fwd(cfg, mixer_ctx, p["mix"], h,
                                        cache=cache, pos=pos)
    if cfg.post_norm:
        mix = apply_norm(cfg.norm_kind, mix, p["post_ln1"])
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if meta.d_ff or meta.use_moe:
        h = apply_norm(cfg.norm_kind, x, p["ln2"])
        if meta.use_moe:
            y, aux = ffn.moe_fwd(cfg, mixer_ctx, p["ffn"], h)
        else:
            y = ffn.mlp_fwd(cfg, mixer_ctx, p["ffn"], h, sequence_parallel=seqpar)
        if cfg.post_norm:
            y = apply_norm(cfg.norm_kind, y, p["post_ln2"])
        x = x + y
    return x, new_cache, aux


def block_cache_defs(cfg: ModelConfig, tp: int, meta: LayerMeta,
                     batch_local: int, capacity: int):
    if meta.kind == "attn":
        cap = min(capacity, meta.window) if meta.window else capacity
        return attn.cache_defs(cfg, tp, batch_local, cap)
    if cfg.recurrent.kind == "rglru":
        return rec.rglru_cache_defs(cfg, tp, batch_local)
    return rec.mamba2_cache_defs(cfg, tp, batch_local)


# ---------------------------------------------------------------------------
# Whole-model defs
# ---------------------------------------------------------------------------


def stack_defs(defs: PyTree, n: int) -> PyTree:
    def s(d: ParamDef) -> ParamDef:
        shard = d.shard if d.shard else (None,) * len(d.shape)
        return dataclasses.replace(d, shape=(n,) + tuple(d.shape),
                                   shard=(None,) + tuple(shard))
    return jax.tree.map(s, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def model_defs(cfg: ModelConfig, tp: int, long_ctx: bool = False) -> Dict:
    metas = layer_metas(cfg, long_ctx)
    prefix, unit, n_units, tail = group_layers(cfg, metas)
    d = cfg.d_model
    vp = cfg.padded_vocab(tp)
    defs: Dict[str, Any] = {
        "embed": ParamDef((vp, d), ("model", None), init="embed",
                          scale=1.0 / np.sqrt(d)),
        "final_norm": norm_defs(cfg.norm_kind, d),
        "prefix": [block_defs(cfg, tp, m) for m in prefix],
        "scan": (stack_defs([block_defs(cfg, tp, m) for m in unit], n_units)
                 if n_units else []),
        "tail": [block_defs(cfg, tp, m) for m in tail],
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((d, vp), (None, "model"))
    return defs


def model_cache_defs(cfg: ModelConfig, tp: int, batch_local: int,
                     capacity: int, long_ctx: bool = False) -> Dict:
    metas = layer_metas(cfg, long_ctx)
    prefix, unit, n_units, tail = group_layers(cfg, metas)

    def stack(c):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_units,) + s.shape, s.dtype), c)

    return {
        "prefix": [block_cache_defs(cfg, tp, m, batch_local, capacity) for m in prefix],
        "scan": [stack(block_cache_defs(cfg, tp, m, batch_local, capacity))
                 for m in unit],
        "tail": [block_cache_defs(cfg, tp, m, batch_local, capacity) for m in tail],
    }


def empty_cache_tree(defs: PyTree) -> PyTree:
    def mk(s: jax.ShapeDtypeStruct):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, attn.POS_SENTINEL, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(mk, defs)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mixer_ctx(cfg: ModelConfig, ctx: ShardCtx) -> ShardCtx:
    # replicated strategy: mixers/FFN see no model axis (vocab still sharded);
    # seq_ssm keeps the axis (the SSD state prefix-combine needs it)
    return ShardCtx() if cfg.tp_strategy == "replicated" else ctx


def embed_tokens(cfg: ModelConfig, ctx: ShardCtx, params: Dict,
                 ids: jnp.ndarray, seq_shard: bool) -> jnp.ndarray:
    """Vocab-parallel embedding.  ids: (b, s) — REPLICATED over the model
    axis (each shard masked-looks-up its vocab slice for all tokens).  The
    partial embeddings are merged with a reduce-scatter straight into the
    sequence-parallel residual layout (Megatron-SP) or a psum when the
    residual stays full-sequence."""
    table = params["embed"]
    if ctx.model_axis is not None:
        vloc = table.shape[0]
        start = ctx.index() * vloc
        loc = ids - start
        ok = (loc >= 0) & (loc < vloc)
        e = jnp.where(ok[..., None], table[jnp.clip(loc, 0, vloc - 1)], 0)
        e = ctx.scatter_seq(e) if seq_shard else ctx.psum_model(e)
    else:
        e = table[ids]
    if cfg.norm_kind == "gemma_rmsnorm":            # gemma scales embeddings
        e = e * jnp.asarray(np.sqrt(cfg.d_model), e.dtype)
    return e.astype(jnp.dtype(cfg.dtype))


def _frontend_override(cfg: ModelConfig, ctx: ShardCtx, x: jnp.ndarray,
                       extra_emb: Optional[jnp.ndarray],
                       positions: jnp.ndarray) -> jnp.ndarray:
    """Replace the first n_embeds positions with provided frontend embeddings
    (VLM patches / audio conditioning) — DESIGN.md §5."""
    if cfg.frontend is None or extra_emb is None:
        return x
    n = cfg.frontend.n_embeds
    idx = jnp.clip(positions, 0, n - 1)                       # (s_loc,)
    override = jnp.take(extra_emb, idx, axis=1).astype(x.dtype)
    return jnp.where((positions < n)[None, :, None], override, x)


def forward(cfg: ModelConfig, ctx: ShardCtx, params: Dict, ids: jnp.ndarray, *,
            extra_emb: Optional[jnp.ndarray] = None,
            caches: Optional[Dict] = None,
            pos: Optional[jnp.ndarray] = None,
            long_ctx: bool = False,
            remat: bool = True,
            unroll: bool = False,
            ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (hidden (b, s_loc, d), new_caches, aux_loss)."""
    metas = layer_metas(cfg, long_ctx)
    prefix, unit, n_units, tail = group_layers(cfg, metas)
    mctx = _mixer_ctx(cfg, ctx)
    compute_dt = jnp.dtype(cfg.dtype)
    params = jax.tree.map(lambda a: a.astype(compute_dt)
                          if a.dtype == jnp.float32 and a.ndim > 1 else a, params)

    seq_sharded = (pos is None
                   and cfg.tp_strategy in ("head", "seq", "seq_ssm")
                   and ctx.model_axis is not None)
    x = embed_tokens(cfg, ctx, params, ids, seq_shard=seq_sharded)
    if pos is None:
        s_loc = x.shape[1]
        positions = (ctx.index() * s_loc if seq_sharded else 0) + jnp.arange(
            s_loc, dtype=jnp.int32)
        x = _frontend_override(cfg, ctx, x, extra_emb, positions)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, list] = {"prefix": [], "scan": [], "tail": []}

    def run_block(meta, p, x, cache):
        return block_fwd(cfg, ctx, mctx, meta, p, x, cache=cache, pos=pos)

    # --- prefix (unrolled) ----------------------------------------------------
    for i, meta in enumerate(prefix):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, aux = run_block(meta, params["prefix"][i], x, c)
        aux_total += aux
        new_caches["prefix"].append(nc)

    # --- scanned units ----------------------------------------------------------
    if n_units and unroll:
        # python-loop over units: big HLO, but per-layer FLOPs/collectives
        # appear explicitly (cost_analysis counts while-loop bodies ONCE, so
        # the dry-run/roofline lowers this form — EXPERIMENTS.md §Dry-run)
        unit_params = params["scan"]
        body = (lambda f: jax.checkpoint(f)) if remat else (lambda f: f)
        def unit_fn(x, aux_acc, p_unit, c_unit):
            ncs = []
            for j, meta in enumerate(unit):
                x, nc, aux = run_block(meta, p_unit[j], x, c_unit[j])
                aux_acc = aux_acc + aux
                ncs.append(nc)
            return x, aux_acc, ncs

        for u in range(n_units):
            p_unit = jax.tree.map(lambda a: a[u], unit_params)
            c_unit = (jax.tree.map(lambda a: a[u], caches["scan"])
                      if caches is not None else [None] * len(unit))
            x, aux_total, ncs = body(unit_fn)(x, aux_total, p_unit, c_unit)
            if caches is not None:
                new_caches["scan"].append(ncs)
        if caches is not None:
            # restack unit caches to the (n_units, ...) layout scan produces
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                   *new_caches["scan"])
            new_caches["scan"] = stacked
    elif n_units:
        unit_params = params["scan"]
        if caches is None:

            def unit_body(carry, p_unit):
                x, aux_acc = carry
                for j, meta in enumerate(unit):
                    x, _, aux = run_block(meta, p_unit[j], x, None)
                    aux_acc = aux_acc + aux
                return (x, aux_acc), None

            body = jax.checkpoint(unit_body) if remat else unit_body
            (x, aux_total), _ = lax.scan(body, (x, aux_total), unit_params)
        else:

            def unit_body_c(carry, xs_):
                x, aux_acc = carry
                p_unit, c_unit = xs_
                ncs = []
                for j, meta in enumerate(unit):
                    x, nc, aux = run_block(meta, p_unit[j], x, c_unit[j])
                    aux_acc = aux_acc + aux
                    ncs.append(nc)
                return (x, aux_acc), ncs

            body = jax.checkpoint(unit_body_c) if remat else unit_body_c
            (x, aux_total), scan_caches = lax.scan(
                body, (x, aux_total), (unit_params, caches["scan"]))
            new_caches["scan"] = scan_caches

    # --- tail (unrolled) ----------------------------------------------------------
    for i, meta in enumerate(tail):
        c = caches["tail"][i] if caches is not None else None
        x, nc, aux = run_block(meta, params["tail"][i], x, c)
        aux_total += aux
        new_caches["tail"].append(nc)

    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    return x, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# Head / loss / decode
# ---------------------------------------------------------------------------


def head_matrix(cfg: ModelConfig, params: Dict) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"].T          # (d, V_loc)
    return params["head"]


def lm_loss(cfg: ModelConfig, ctx: ShardCtx, params: Dict, ids: jnp.ndarray,
            labels: jnp.ndarray, *, extra_emb: Optional[jnp.ndarray] = None,
            remat: bool = True, chunk: int = 256, unroll: bool = False,
            ) -> Tuple[jnp.ndarray, Dict]:
    """Mean next-token cross-entropy (labels < 0 are masked).

    ids/labels: (b, s) — full sequence, replicated over the model axis
    (the embedding reduce-scatters into the seq-parallel residual).
    """
    x, _, aux = forward(cfg, ctx, params, ids, extra_emb=extra_emb,
                        remat=remat, unroll=unroll)
    w = head_matrix(cfg, params).astype(x.dtype)

    # Vocab-parallel cross-entropy: logits are vocab-sharded, so every model
    # shard needs ALL tokens — gather the sequence-sharded residual first,
    # then reduce the logsumexp over the model axis.
    seq_sharded = (cfg.tp_strategy in ("head", "seq", "seq_ssm")
                   and ctx.model_axis is not None)
    if seq_sharded:
        x = ctx.gather_seq(x, compress=cfg.compress_gathers)
    b, s, d = x.shape

    n_chunks = max(1, s // chunk)
    cs = s // n_chunks
    xs = x[:, :n_chunks * cs].reshape(b, n_chunks, cs, d).swapaxes(0, 1)
    ls = labels[:, :n_chunks * cs].reshape(b, n_chunks, cs).swapaxes(0, 1)

    vloc = w.shape[1]
    start = ctx.index() * vloc

    def chunk_loss(xc, lc):
        logits = (xc @ w).astype(jnp.float32)                 # (b, cs, V_loc)
        if cfg.final_softcap is not None:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        # max-shift is a constant wrt the gradient (softmax is shift
        # invariant) — pmax has no JVP rule, so sever the tangent first
        mx = ctx.pmax_model(lax.stop_gradient(logits.max(-1)))
        se = ctx.psum_model(jnp.exp(logits - mx[..., None]).sum(-1))
        lse = mx + jnp.log(se)
        loc = lc - start
        ok = (loc >= 0) & (loc < vloc)
        ll = jnp.take_along_axis(logits, jnp.clip(loc, 0, vloc - 1)[..., None],
                                 axis=-1)[..., 0]
        ll = ctx.psum_model(jnp.where(ok, ll, 0.0))
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    fn = jax.checkpoint(chunk_loss) if remat else chunk_loss

    def body(acc, inp):
        l, n = fn(*inp)
        return (acc[0] + l, acc[1] + n), None

    (tot, n), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)), (xs, ls))
    # after the gather every model shard summed over the SAME tokens (the
    # per-token lse/ll were completed with psum inside chunk_loss)
    loss = tot / jnp.maximum(n, 1.0)
    metrics = {"xent": loss, "aux": aux, "tokens": n}
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_coef * aux
    return loss, metrics


def prefill(cfg: ModelConfig, ctx: ShardCtx, params: Dict, ids: jnp.ndarray,
            capacity: int, *, extra_emb: Optional[jnp.ndarray] = None,
            long_ctx: bool = False, unroll: bool = False,
            ) -> Tuple[jnp.ndarray, Dict]:
    """Run the full prompt, fill caches, return last-position logits."""
    b, s_loc = ids.shape
    cache_defs = model_cache_defs(cfg, ctx.tp if ctx.model_axis else 1, b,
                                  capacity, long_ctx)
    caches = empty_cache_tree(cache_defs)
    x, new_caches, _ = forward(cfg, ctx, params, ids, extra_emb=extra_emb,
                               caches=caches, long_ctx=long_ctx, remat=False,
                               unroll=unroll)
    last = x[:, -1:, :]
    if (cfg.tp_strategy in ("head", "seq", "seq_ssm")
            and ctx.model_axis is not None):
        # the last position lives on the last seq shard: gather it
        lastg = ctx.gather_seq(last, axis=1)
        last = lastg[:, -1:, :]
    logits = (last @ head_matrix(cfg, params).astype(last.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits[:, 0], new_caches


def decode_step(cfg: ModelConfig, ctx: ShardCtx, params: Dict,
                ids: jnp.ndarray, pos: jnp.ndarray, caches: Dict, *,
                long_ctx: bool = False, unroll: bool = False,
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  ids: (b, 1); pos: (b,).  Returns (logits (b, V_loc),
    new caches)."""
    x, new_caches, _ = forward(cfg, ctx, params, ids, caches=caches, pos=pos,
                               long_ctx=long_ctx, remat=False, unroll=unroll)
    logits = (x @ head_matrix(cfg, params).astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits[:, 0], new_caches


def sample_greedy(ctx: ShardCtx, logits_loc: jnp.ndarray) -> jnp.ndarray:
    """Greedy sampling with vocab-sharded logits: global argmax via pmax."""
    vloc = logits_loc.shape[-1]
    local_best = jnp.max(logits_loc, axis=-1)
    local_idx = jnp.argmax(logits_loc, axis=-1) + ctx.index() * vloc
    gbest = ctx.pmax_model(local_best)
    winner = jnp.where(local_best >= gbest, local_idx, -1)
    return ctx.pmax_model(winner).astype(jnp.int32)
