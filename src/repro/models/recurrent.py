"""Recurrent mixers: RG-LRU (RecurrentGemma) and Mamba-2 SSD blocks.

Both support a full-sequence mode (train/prefill — scan or chunked SSD) and
a single-token decode mode carrying a recurrent state "cache":
  RG-LRU : {h (b, w_loc), conv (b, cw-1, w_loc)}
  Mamba2 : {h (b, nh, hd, ds), conv (b, cw-1, w + 2·g·ds)}

Sharding: RG-LRU width shards over ``model`` (the recurrence is element-wise
diagonal, so the scan needs no cross-device communication); gates are
block-diagonal with blocks aligned to the shard.  Mamba2-130m is tiny and
uses the replicated strategy (DESIGN.md §6) — its mixer sees no model axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ShardCtx, activation

RGLRU_NUM_BLOCKS = 16     # gate block-diagonal blocks (divides widths & tp)


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# ---------------------------------------------------------------------------


def rglru_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    r = cfg.recurrent
    w = r.width
    nb = RGLRU_NUM_BLOCKS
    blk = w // nb
    shard = cfg.tp_strategy == "head"
    sh1 = (None, "model") if shard else (None, None)
    sh0 = ("model", None) if shard else (None, None)
    shb = ("model", None, None) if shard else (None, None, None)
    shv = ("model",) if shard else (None,)
    return {
        "w_x": ParamDef((d, w), sh1),
        "w_gate": ParamDef((d, w), sh1),
        "conv_w": ParamDef((r.conv_width, w), (None, "model") if shard else (None, None),
                           scale=0.3),
        "conv_b": ParamDef((w,), shv, init="zeros"),
        "w_a": ParamDef((nb, blk, blk), shb),
        "b_a": ParamDef((w,), shv, init="zeros"),
        "w_i": ParamDef((nb, blk, blk), shb),
        "b_i": ParamDef((w,), shv, init="zeros"),
        "a_param": ParamDef((w,), shv, init="ones", scale=1.0),
        "w_out": ParamDef((w, d), sh0),
    }


def _block_linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (..., nb_loc*blk); w: (nb_loc, blk, blk); b: (nb_loc*blk,)."""
    nb, blk = w.shape[0], w.shape[1]
    xs = x.reshape(*x.shape[:-1], nb, blk)
    y = jnp.einsum("...nw,nwv->...nv", xs, w)
    return y.reshape(*x.shape[:-1], nb * blk) + b


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv. x: (b, l, c); w: (cw, c); state: (b, cw-1, c)."""
    cw = w.shape[0]
    pad = (jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(cw))
    return out + b


def rglru_fwd(cfg: ModelConfig, ctx: ShardCtx, p: Dict, x: jnp.ndarray, *,
              cache: Optional[Dict] = None, pos: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (b, s_loc, d) seq-sharded.  Full mode gathers the sequence (the
    recurrence needs temporal order), computes the width shard, scatters
    back.  Decode mode is a single step against the state cache."""
    from repro.kernels.rglru_scan import ops as rg_ops

    shard = cfg.tp_strategy == "head" and ctx.model_axis is not None
    if pos is None:
        xg = ctx.gather_seq(x) if shard else x               # (b, s, d)
        gate = activation("gelu", xg @ p["w_gate"])          # (b, s, w_loc)
        xb = xg @ p["w_x"]
        xb = _causal_conv(xb, p["conv_w"], p["conv_b"])
        r_g = jax.nn.sigmoid(_block_linear(xb, p["w_a"], p["b_a"]))
        i_g = jax.nn.sigmoid(_block_linear(xb, p["w_i"], p["b_i"]))
        h, h_last = rg_ops.rglru(xb, r_g, i_g, p["a_param"])
        y = (gate * h) @ p["w_out"]                          # partial if sharded
        y = ctx.scatter_seq(y) if shard else y
        new_cache = None
        if cache is not None:
            cw = p["conv_w"].shape[0]
            # conv state = last cw-1 raw inputs (pre-conv xb inputs)
            raw = (xg @ p["w_x"])[:, -(cw - 1):]
            new_cache = {"h": h_last.astype(cache["h"].dtype),
                         "conv": raw.astype(cache["conv"].dtype)}
        return y, new_cache

    # ---- decode ----
    gate = activation("gelu", x @ p["w_gate"])               # (b, 1, w_loc)
    raw = x @ p["w_x"]                                       # (b, 1, w_loc)
    conv_in = jnp.concatenate([cache["conv"].astype(raw.dtype), raw], axis=1)
    cw = p["conv_w"].shape[0]
    xb = jnp.einsum("btc,tc->bc", conv_in[:, -cw:], p["conv_w"]) + p["conv_b"]
    r_g = jax.nn.sigmoid(_block_linear(xb, p["w_a"], p["b_a"]))
    i_g = jax.nn.sigmoid(_block_linear(xb, p["w_i"], p["b_i"]))
    _, h_new = rg_ops.rglru_step(cache["h"], xb, r_g, i_g, p["a_param"])
    y = (gate[:, 0] * h_new.astype(gate.dtype)) @ p["w_out"]
    if shard:
        y = ctx.psum_model(y)
    new_cache = {"h": h_new.astype(cache["h"].dtype),
                 "conv": conv_in[:, -(cw - 1):].astype(cache["conv"].dtype)}
    return y[:, None, :], new_cache


def rglru_cache_defs(cfg: ModelConfig, tp: int, batch_local: int,
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    r = cfg.recurrent
    w_loc = r.width // tp if cfg.tp_strategy == "head" else r.width
    dt = jnp.dtype(cfg.dtype)
    return {
        "h": jax.ShapeDtypeStruct((batch_local, w_loc), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch_local, r.conv_width - 1, w_loc), dt),
    }


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def mamba2_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    r = cfg.recurrent
    w = r.width
    nh = w // r.head_dim
    gs = r.n_groups * r.d_state
    conv_dim = w + 2 * gs
    return {
        # in_proj order: [z (w) | x (w) | B (gs) | C (gs) | dt (nh)]
        "w_in": ParamDef((d, 2 * w + 2 * gs + nh), (None, None)),
        "conv_w": ParamDef((r.conv_width, conv_dim), (None, None), scale=0.3),
        "conv_b": ParamDef((conv_dim,), (None,), init="zeros"),
        "A_log": ParamDef((nh,), (None,), init="ones"),
        "D": ParamDef((nh,), (None,), init="ones"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "norm": ParamDef((w,), (None,), init="ones"),
        "w_out": ParamDef((w, d), (None, None)),
    }


def _mamba_split(cfg, h):
    r = cfg.recurrent
    w = r.width
    gs = r.n_groups * r.d_state
    nh = w // r.head_dim
    z = h[..., :w]
    xBC = h[..., w:w + w + 2 * gs]
    dt = h[..., w + w + 2 * gs:]
    return z, xBC, dt, w, gs, nh


def mamba2_fwd(cfg: ModelConfig, ctx: ShardCtx, p: Dict, x: jnp.ndarray, *,
               cache: Optional[Dict] = None, pos: Optional[jnp.ndarray] = None,
               ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (b, s, d) (replicated strategy: full sequence on every device) or
    (b, s/tp, d) under the sequence-parallel "seq_ssm" strategy."""
    from repro.kernels.ssd_scan import ops as ssd_ops

    if (cfg.tp_strategy == "seq_ssm" and ctx.model_axis is not None
            and pos is None):
        return _mamba2_fwd_seqpar(cfg, ctx, p, x, cache=cache)

    r = cfg.recurrent
    hd, ds, ng = r.head_dim, r.d_state, r.n_groups
    proj = x @ p["w_in"]
    z, xBC, dt_raw, w, gs, nh = _mamba_split(cfg, proj)
    rep = nh // ng

    if pos is None:
        b, s, _ = x.shape
        xBC = activation("silu", _causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs = xBC[..., :w].reshape(b, s, nh, hd)
        B = xBC[..., w:w + gs].reshape(b, s, ng, ds)         # group granularity —
        C = xBC[..., w + gs:].reshape(b, s, ng, ds)          # ssd_chunked broadcasts
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, state = ssd_ops.ssd_scan(xs, dt, A, B, C, chunk=min(r.chunk_size, s))
        y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(b, s, w)
        # gated RMSNorm (Mamba-2)
        y = _gated_rmsnorm(y, z, p["norm"])
        out = y @ p["w_out"]
        new_cache = None
        if cache is not None:
            cw = p["conv_w"].shape[0]
            new_cache = {"h": state.astype(cache["h"].dtype),
                         "conv": xBC_raw_tail(proj, cfg, cw).astype(cache["conv"].dtype)}
        return out, new_cache

    # ---- decode: single token ----
    b = x.shape[0]
    raw = xBC[:, 0]                                          # (b, conv_dim)
    conv_in = jnp.concatenate([cache["conv"].astype(raw.dtype),
                               raw[:, None]], axis=1)
    cw = p["conv_w"].shape[0]
    xBC1 = jnp.einsum("btc,tc->bc", conv_in[:, -cw:], p["conv_w"]) + p["conv_b"]
    xBC1 = activation("silu", xBC1)
    xs = xBC1[:, :w].reshape(b, nh, hd)
    B = jnp.repeat(xBC1[:, w:w + gs].reshape(b, ng, ds), rep, axis=1)
    C = jnp.repeat(xBC1[:, w + gs:].reshape(b, ng, ds), rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_ops.ssd_step(cache["h"].astype(jnp.float32), xs, dt, A, B, C)
    y = y + xs * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(b, 1, w)
    y = _gated_rmsnorm(y, z, p["norm"])
    out = y @ p["w_out"]
    new_cache = {"h": h_new.astype(cache["h"].dtype),
                 "conv": conv_in[:, -(cw - 1):].astype(cache["conv"].dtype)}
    return out, new_cache


def _mamba2_fwd_seqpar(cfg: ModelConfig, ctx: ShardCtx, p: Dict,
                       x: jnp.ndarray, *, cache=None):
    """Sequence-parallel SSD (beyond-paper, EXPERIMENTS.md §Perf pair 1).

    The residual is sequence-sharded (b, s/tp, d) over the model axis, so
    each device runs the SSD over its own sequence slice only (1/tp of the
    replicated strategy's FLOPs and HBM traffic).  Cross-device causality is
    restored with two tiny collectives:
      * the causal-conv left halo — ppermute of (b, cw-1, conv_dim);
      * the inter-slice SSD state — each device scans from a zero state,
        all-gathers its (outgoing state S_j, slice decay logD_j), forms the
        true incoming state by a prefix combine (the same associativity the
        chunked scan uses), and adds the decayed correction C_t·S_in.
    """
    from jax import lax
    from repro.kernels.ssd_scan import ops as ssd_ops

    if cache is not None:
        raise NotImplementedError("seq_ssm is a training-path optimization")
    r = cfg.recurrent
    hd, ds, ng = r.head_dim, r.d_state, r.n_groups
    f32 = jnp.float32
    proj = x @ p["w_in"]
    z, xBC, dt_raw, w, gs, nh = _mamba_split(cfg, proj)
    b, s_loc, _ = x.shape
    tp = ctx.tp
    idx = ctx.index()

    # causal-conv halo from the left neighbour (device m-1)
    cw = p["conv_w"].shape[0]
    tail = xBC[:, -(cw - 1):, :]
    perm = [(i, (i + 1) % tp) for i in range(tp)]
    halo = lax.ppermute(tail, ctx.model_axis, perm)
    halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
    xBC = activation("silu", _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                          state=halo))

    xs = xBC[..., :w].reshape(b, s_loc, nh, hd)
    B = xBC[..., w:w + gs].reshape(b, s_loc, ng, ds)
    C = xBC[..., w + gs:].reshape(b, s_loc, ng, ds)
    dt = jax.nn.softplus(dt_raw.astype(f32) + p["dt_bias"].astype(f32))
    A = -jnp.exp(p["A_log"].astype(f32))

    # local scan from zero state
    y0, S_out = ssd_ops.ssd_scan(xs, dt, A, B, C,
                                 chunk=min(r.chunk_size, s_loc))
    logD = jnp.sum(dt * A[None, None, :], axis=1)            # (b, nh) f32

    # prefix-combine the slice states across devices (tiny: tp×(b,nh,hd,ds))
    S_all = lax.all_gather(S_out, ctx.model_axis)            # (tp, b, nh, p, n)
    logD_all = lax.all_gather(logD, ctx.model_axis)          # (tp, b, nh)
    cum = jnp.cumsum(logD_all, axis=0)                       # inclusive
    # S_in = sum_{j<m} exp(cum[m-1] - cum[j]) * S_j   (decay through (j, m))
    j_idx = jnp.arange(tp)
    cum_m1 = jnp.where(idx > 0, cum[jnp.maximum(idx - 1, 0)], 0.0)  # (b, nh)
    # mask BEFORE exp: exp of a large positive (j >= m) would overflow and
    # poison gradients through the where
    expo = jnp.where((j_idx < idx)[:, None, None], cum_m1[None] - cum, -1e30)
    wgt = jnp.exp(expo)                                      # (tp, b, nh)
    S_in = jnp.einsum("jbh,jbhpn->bhpn", wgt, S_all)         # f32

    # correction: the incoming state decays to position t by exp(A_cum[t])
    A_cum = jnp.cumsum(dt * A[None, None, :], axis=1)        # (b, s_loc, nh)
    hg = nh // ng
    y_corr = jnp.einsum("bsgn,bghpn->bsghp", C.astype(f32),
                        S_in.reshape(b, ng, hg, hd, ds)
                        ).reshape(b, s_loc, nh, hd)
    y_corr = y_corr * jnp.exp(A_cum)[..., None]
    y = y0.astype(f32) + y_corr
    y = y + xs.astype(f32) * p["D"].astype(f32)[None, None, :, None]
    y = y.reshape(b, s_loc, w).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm"])
    return y @ p["w_out"], None


def xBC_raw_tail(proj: jnp.ndarray, cfg: ModelConfig, cw: int) -> jnp.ndarray:
    """Last cw-1 pre-conv xBC inputs (the decode conv state)."""
    r = cfg.recurrent
    w = r.width
    gs = r.n_groups * r.d_state
    xBC = proj[..., w:w + w + 2 * gs]
    return xBC[:, -(cw - 1):]


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    f32 = jnp.float32
    g = y.astype(f32) * jax.nn.silu(z.astype(f32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + 1e-6) * scale.astype(f32)).astype(y.dtype)


def mamba2_cache_defs(cfg: ModelConfig, tp: int, batch_local: int,
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    r = cfg.recurrent
    w = r.width
    nh = w // r.head_dim
    gs = r.n_groups * r.d_state
    dt = jnp.dtype(cfg.dtype)
    return {
        "h": jax.ShapeDtypeStruct((batch_local, nh, r.head_dim, r.d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch_local, r.conv_width - 1, w + 2 * gs), dt),
    }
