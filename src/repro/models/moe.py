"""Feed-forward layers: dense (gated / plain) MLP and expert-parallel MoE.

MoE (DESIGN.md §6): experts are sharded over the ``model`` axis (E/tp per
device).  Tokens are routed top-k with a capacity factor, packed into
(E, C) dispatch buffers, exchanged with ``all_to_all`` so each device
receives the tokens bound for ITS experts from every peer, run through the
local experts as one batched einsum, exchanged back and combined with the
router weights.  Dropped tokens (over capacity) contribute zero — the
residual stream carries them unchanged.

The router auxiliary load-balance loss (Switch-style f·p) is returned so the
trainer can add ``router_aux_coef``·aux to the task loss.  Router state drifts
between consistency syncs; the VAP bound caps that drift (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import ParamDef, ShardCtx, activation


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, tp: int, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    shard_ff = cfg.tp_strategy in ("head", "seq")    # d_ff shards in both
    sh1 = (None, "model") if shard_ff else (None, None)
    sh0 = ("model", None) if shard_ff else (None, None)
    defs = {
        "w_in": ParamDef((d, ff), sh1),
        "w_out": ParamDef((ff, d), sh0),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((d, ff), sh1)
    return defs


def mlp_fwd(cfg: ModelConfig, ctx: ShardCtx, p: Dict, x: jnp.ndarray,
            sequence_parallel: bool = True) -> jnp.ndarray:
    """x: (b, s_loc, d) seq-sharded (head/seq strategies) or full (replicated).

    d_ff is column-sharded; with sequence-parallel residuals we all-gather
    the sequence in, reduce-scatter the partial output back.
    """
    shard_ff = cfg.tp_strategy in ("head", "seq") and ctx.model_axis is not None
    if shard_ff and sequence_parallel:
        xg = ctx.gather_seq(x, compress=cfg.compress_gathers)
    else:
        xg = x
    h = xg @ p["w_in"]
    if cfg.gated_mlp:
        h = activation(cfg.act, h) * (xg @ p["w_gate"])
    else:
        h = activation(cfg.act, h)
    y = h @ p["w_out"]                                   # partial sums if sharded
    if shard_ff:
        y = ctx.scatter_seq(y) if sequence_parallel else ctx.psum_model(y)
    return y


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    m: MoEConfig = cfg.moe
    de = m.d_expert
    defs = {
        "router": ParamDef((d, m.n_experts), (None, None), scale=0.1),
        "w_in": ParamDef((m.n_experts, d, de), ("model", None, None)),
        "w_out": ParamDef((m.n_experts, de, d), ("model", None, None)),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((m.n_experts, d, de), ("model", None, None))
    if m.n_shared_experts:
        sh = {
            "w_in": ParamDef((d, m.d_shared), (None, "model")),
            "w_out": ParamDef((m.d_shared, d), ("model", None)),
        }
        if cfg.gated_mlp:
            sh["w_gate"] = ParamDef((d, m.d_shared), (None, "model"))
        defs["shared"] = sh
    return defs


def moe_fwd(cfg: ModelConfig, ctx: ShardCtx, p: Dict, x: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s_loc, d).  Returns (y, aux_loss)."""
    m: MoEConfig = cfg.moe
    b, s_loc, d = x.shape
    T = b * s_loc                                        # local tokens
    E, K = m.n_experts, m.top_k
    xt = x.reshape(T, d)

    # --- routing (f32 for numerics) ------------------------------------------
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)      # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e f_e * p_e  (f = token fraction)
    token_frac = jnp.zeros(E).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    prob_frac = probs.mean(0)
    aux = E * jnp.sum(token_frac * prob_frac)

    # --- dispatch packing -----------------------------------------------------
    C = max(1, int(np.ceil(T * K * m.capacity_factor / E)))
    flat_expert = expert_ids.reshape(-1)                 # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    # rank of each (token, k) within its expert, in token order
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)      # (T*K, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)                 # exclusive
    rank_in_e = jnp.take_along_axis(ranks, flat_expert[:, None], 1)[:, 0]
    keep = rank_in_e < C
    slot = jnp.where(keep, flat_expert * C + rank_in_e, E * C)    # overflow bin

    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].add(
        jnp.repeat(xt, K, axis=0) * keep[:, None].astype(xt.dtype))
    buf = buf[:-1].reshape(E, C, d)

    # --- expert parallel exchange --------------------------------------------
    ep = ctx.model_axis is not None
    if ep:
        tp = ctx.tp
        # (E, C, d) -> (E/tp, C*tp, d): each device receives its experts'
        # tokens from every peer
        buf = ctx.all_to_all(buf, split_axis=0, concat_axis=1)
    e_loc = buf.shape[0]

    # --- local experts ---------------------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if cfg.gated_mlp:
        h = activation(cfg.act, h) * jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    else:
        h = activation(cfg.act, h)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_out"])

    if ep:
        out = ctx.all_to_all(out, split_axis=1, concat_axis=0)    # back to (E, C, d)

    # --- combine ----------------------------------------------------------------
    out_flat = jnp.concatenate([out.reshape(E * C, d),
                                jnp.zeros((1, d), out.dtype)], 0)
    gathered = out_flat[slot]                                     # (T*K, d)
    weighted = gathered * (flat_gate * keep).astype(gathered.dtype)[:, None]
    y = weighted.reshape(T, K, d).sum(1).reshape(b, s_loc, d)

    # --- shared experts ---------------------------------------------------------
    if m.n_shared_experts:
        sp = p["shared"]
        xg = ctx.gather_seq(x) if ep else x
        h = xg @ sp["w_in"]
        if cfg.gated_mlp:
            h = activation(cfg.act, h) * (xg @ sp["w_gate"])
        else:
            h = activation(cfg.act, h)
        ys = h @ sp["w_out"]
        ys = ctx.scatter_seq(ys) if ep else ys
        y = y + ys

    return y, aux.astype(jnp.float32)
