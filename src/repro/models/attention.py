"""Attention: GQA (head-TP and seq-TP layouts), MLA, sliding windows, caches.

Layouts (DESIGN.md §6) — the residual stream is always sequence-sharded
``(b, s/tp, d)`` over the ``model`` axis:

* **head-TP**: all-gather the sequence, project local q-heads (kv heads
  duplicated up to tp when n_kv < tp), attend, out-project to a partial sum,
  reduce-scatter back to ``s/tp``.
* **seq-TP** (head counts not divisible by tp): projections are replicated;
  q stays on the local sequence shard, k/v are all-gathered; no output
  collective.  Decode shards the KV cache over the model axis by *slot* and
  combines partial attention with a distributed logsumexp.

The jnp attention core is the oracle the Pallas flash kernel is validated
against; on CPU (and in the dry-run) the core itself runs, chunked over
query blocks and *banded* for sliding windows so compiled FLOPs/memory stay
honest.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import (ParamDef, ShardCtx, apply_rope, kv_eff_heads,
                                 softcap)

NEG_INF = -1e30
POS_SENTINEL = np.int32(2**30)   # k-slot "empty" marker (always masked out)


# ---------------------------------------------------------------------------
# Core attention (jnp oracle; chunked + banded)
# ---------------------------------------------------------------------------


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                   window: Optional[int] = None,
                   cap: Optional[float] = None,
                   chunk: int = 512) -> jnp.ndarray:
    """Masked multi-head attention.

    q: (b, sq, kvh, G, dh)   — GQA: G query heads per kv head
    k,v: (b, skv, kvh, dh)
    q_pos: (sq,) or (b, sq); k_pos: (skv,) or (b, skv) — absolute positions;
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window).
    """
    b, sq, kvh, G, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (b, sq))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (b, skv))

    def attend(qc, qpc, kc, vc, kpc):
        # qc: (b, cq, kvh, G, dh); kc/vc: (b, sk, kvh, dh)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        m = kpc[:, None, None, None, :] <= qpc[:, None, None, :, None]
        if window is not None:
            m &= kpc[:, None, None, None, :] > (qpc[:, None, None, :, None] - window)
        s = jnp.where(m, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        w = jnp.where(m.any(-1, keepdims=True), w, 0.0)   # fully-masked rows
        return jnp.einsum("bkgqs,bskd->bqkgd", w, vc.astype(jnp.float32)).astype(q.dtype)

    if sq <= chunk:
        return attend(q, q_pos, k, v, k_pos)

    n_chunks = sq // chunk
    if sq % chunk:
        raise ValueError(f"sq={sq} not divisible by chunk={chunk}")
    # banded k slice: chunk c needs k positions in (c*chunk - window, (c+1)*chunk)
    banded = window is not None and skv == sq and window + chunk < skv
    band = (min((window // chunk + 1) * chunk + chunk, skv)) if banded else skv

    qs = q.reshape(b, n_chunks, chunk, kvh, G, dh)
    qps = q_pos.reshape(b, n_chunks, chunk)

    def per_chunk(c):
        qc, qpc = qs[:, c], qps[:, c]
        if banded:
            start = jnp.clip(c * chunk + chunk - band, 0, skv - band)
            kc = lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpc = lax.dynamic_slice_in_dim(k_pos, start, band, axis=1)
        else:
            kc, vc, kpc = k, v, k_pos
        return attend(qc, qpc, kc, vc, kpc)

    out = lax.map(per_chunk, jnp.arange(n_chunks))          # (n, b, chunk, ...)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, kvh, G, v.shape[-1])


def attention_core_dispatch(*args, **kw):
    """Hook point: the Pallas flash-attention kernel replaces this on TPU
    (see repro.kernels.flash_attention.ops)."""
    from repro.kernels.flash_attention import ops as fa_ops
    return fa_ops.flash_attention(*args, **kw)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    d, hq, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    if cfg.mla is not None:
        return mla_defs(cfg, tp)
    head_tp = cfg.tp_strategy == "head"
    sh1 = (None, "model") if head_tp else (None, None)
    sh0 = ("model", None) if head_tp else (None, None)
    if head_tp:
        kv_eff, rep = kv_eff_heads(cfg.n_kv_heads, tp)
    else:
        kv_eff, rep = cfg.n_kv_heads, 1
    defs = {
        "wq": ParamDef((d, hq * dh), sh1),
        "wk": ParamDef((d, kv_eff * dh), sh1,
                       init="kv_dup" if rep > 1 else "fan_in",
                       kv_base_heads=cfg.n_kv_heads, kv_rep=rep),
        "wv": ParamDef((d, kv_eff * dh), sh1,
                       init="kv_dup" if rep > 1 else "fan_in",
                       kv_base_heads=cfg.n_kv_heads, kv_rep=rep),
        "wo": ParamDef((hq * dh, d), sh0),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((dh,), (None,), init="ones")
        defs["k_norm"] = ParamDef((dh,), (None,), init="ones")
    return defs


def mla_defs(cfg: ModelConfig, tp: int) -> Dict[str, ParamDef]:
    m = cfg.mla
    d, hq = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    sh1 = (None, "model")
    return {
        "wq": ParamDef((d, hq * qd), sh1),
        "w_dkv": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim), (None, None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": ParamDef((m.kv_lora_rank, hq * m.qk_nope_head_dim), sh1),
        "w_uv": ParamDef((m.kv_lora_rank, hq * m.v_head_dim), sh1),
        "wo": ParamDef((hq * m.v_head_dim, d), ("model", None)),
    }


# ---------------------------------------------------------------------------
# Cache definitions
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, tp: int, batch_local: int,
               capacity: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Per-attention-layer KV cache (LOCAL shapes).  ``capacity`` is the ring
    size (min(seq_len, window) in long-context mode)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "latent": jax.ShapeDtypeStruct((batch_local, capacity, m.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((batch_local, capacity, m.qk_rope_head_dim), dt),
            "pos": jax.ShapeDtypeStruct((batch_local, capacity), jnp.int32),
        }
    dh = cfg.d_head
    if cfg.tp_strategy == "head":
        kv_eff, _ = kv_eff_heads(cfg.n_kv_heads, tp)
        kv_loc, cap_loc = kv_eff // tp, capacity
    else:   # seq-TP / replicated: shard cache slots over the model axis
        kv_loc = cfg.n_kv_heads
        cap_loc = capacity // tp if cfg.tp_strategy == "seq" else capacity
    return {
        "k": jax.ShapeDtypeStruct((batch_local, cap_loc, kv_loc, dh), dt),
        "v": jax.ShapeDtypeStruct((batch_local, cap_loc, kv_loc, dh), dt),
        "pos": jax.ShapeDtypeStruct((batch_local, cap_loc), jnp.int32),
    }


def empty_cache(defs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, jnp.ndarray]:
    out = {}
    for k, s in defs.items():
        if k == "pos":
            out[k] = jnp.full(s.shape, POS_SENTINEL, dtype=s.dtype)
        else:
            out[k] = jnp.zeros(s.shape, s.dtype)
    return out


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _qk_normalize(x, scale):
    """Qwen3/OLMoE-style per-head RMS norm over the head dim."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + 1e-6)
    return (x * scale.astype(jnp.float32)).astype(dt)


def attn_fwd(cfg: ModelConfig, ctx: ShardCtx, p: Dict, x: jnp.ndarray, *,
             window: Optional[int], cache: Optional[Dict] = None,
             pos: Optional[jnp.ndarray] = None,
             ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (b, s_loc, d) seq-sharded residual.  Two modes:
       * full (pos None): train/prefill over the whole sequence; if `cache`
         is given (prefill), it is filled with the last `capacity` k/v.
       * decode (pos (b,)): single new token against the cache.
    Returns (y (b, s_loc, d), new_cache)."""
    if cfg.mla is not None:
        return mla_fwd(cfg, ctx, p, x, window=window, cache=cache, pos=pos)
    if pos is None:
        return _gqa_full(cfg, ctx, p, x, window=window, cache=cache)
    return _gqa_decode(cfg, ctx, p, x, window=window, cache=cache, pos=pos)


def _gqa_full(cfg, ctx, p, x, *, window, cache):
    head_tp = cfg.tp_strategy == "head" and ctx.model_axis is not None
    seq_tp = cfg.tp_strategy == "seq" and ctx.model_axis is not None
    b, s_loc, d = x.shape
    dh = cfg.d_head
    tp = ctx.tp if (head_tp or seq_tp) else 1
    s = s_loc * (ctx.tp if (head_tp or seq_tp) else 1)

    if head_tp:
        hq_loc = cfg.n_heads // ctx.tp
        kv_eff, _ = kv_eff_heads(cfg.n_kv_heads, ctx.tp)
        kv_loc = kv_eff // ctx.tp
        xg = ctx.gather_seq(x, compress=cfg.compress_gathers)   # (b, s, d)
        q = _split_heads(xg @ p["wq"], hq_loc, dh)
        k = _split_heads(xg @ p["wk"], kv_loc, dh)
        v = _split_heads(xg @ p["wv"], kv_loc, dh)
        positions = jnp.arange(s, dtype=jnp.int32)
        q_pos = k_pos = positions
    else:
        hq_loc, kv_loc = cfg.n_heads, cfg.n_kv_heads
        local_pos = (ctx.index() * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
                     if seq_tp else jnp.arange(s_loc, dtype=jnp.int32))
        q = _split_heads(x @ p["wq"], hq_loc, dh)
        k_loc = _split_heads(x @ p["wk"], kv_loc, dh)
        v_loc = _split_heads(x @ p["wv"], kv_loc, dh)
        if cfg.qk_norm:
            q = _qk_normalize(q, p["q_norm"])
            k_loc = _qk_normalize(k_loc, p["k_norm"])
        k_loc = apply_rope(k_loc, local_pos, cfg.rope_theta)
        q = apply_rope(q, local_pos, cfg.rope_theta)
        k = ctx.gather_seq(k_loc) if seq_tp else k_loc       # (b, s, kv, dh)
        v = ctx.gather_seq(v_loc) if seq_tp else v_loc
        q_pos = local_pos
        k_pos = jnp.arange(s, dtype=jnp.int32)

    if head_tp:
        if cfg.qk_norm:
            q = _qk_normalize(q, p["q_norm"])
            k = _qk_normalize(k, p["k_norm"])
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)

    G = hq_loc // kv_loc
    qg = q.reshape(b, q.shape[1], kv_loc, G, dh)
    o = attention_core(qg, k, v, q_pos, k_pos, window=window,
                       cap=cfg.attn_softcap)
    o = o.reshape(b, o.shape[1], hq_loc * dh)

    if head_tp:
        y = o @ p["wo"]                                      # (b, s, d) partial
        y = ctx.scatter_seq(y)                               # (b, s_loc, d)
    else:
        y = o @ p["wo"]                                      # (b, s_loc, d)

    new_cache = None
    if cache is not None:
        new_cache = _fill_cache_from_full(cfg, ctx, cache, k, v, k_pos,
                                          head_tp=head_tp, seq_tp=seq_tp)
    return y, new_cache


def _fill_cache_from_full(cfg, ctx, cache, k, v, k_pos, *, head_tp, seq_tp):
    """Prefill: write the last `capacity` keys into the ring cache."""
    capacity_total = cache["pos"].shape[1] * (ctx.tp if seq_tp else 1)
    s = k.shape[1]
    take = min(s, capacity_total)
    k_last, v_last = k[:, s - take:], v[:, s - take:]
    pos_last = k_pos[s - take:]
    slots = pos_last % capacity_total                        # (take,)
    b = k.shape[0]
    ring_k = jnp.zeros((b, capacity_total) + k.shape[2:], k.dtype)
    ring_v = jnp.zeros_like(ring_k)
    ring_p = jnp.full((b, capacity_total), POS_SENTINEL, jnp.int32)
    ring_k = ring_k.at[:, slots].set(k_last)
    ring_v = ring_v.at[:, slots].set(v_last)
    ring_p = ring_p.at[:, slots].set(jnp.broadcast_to(pos_last[None], (b, take)))
    if seq_tp:   # keep only this device's slot shard
        cap_loc = cache["pos"].shape[1]
        start = ctx.index() * cap_loc
        ring_k = lax.dynamic_slice_in_dim(ring_k, start, cap_loc, axis=1)
        ring_v = lax.dynamic_slice_in_dim(ring_v, start, cap_loc, axis=1)
        ring_p = lax.dynamic_slice_in_dim(ring_p, start, cap_loc, axis=1)
    return {"k": ring_k.astype(cache["k"].dtype),
            "v": ring_v.astype(cache["v"].dtype),
            "pos": ring_p}


def _ring_insert(cache_arr, new, slot):
    """cache (b, C, …); new (b, 1, …); slot (b,) — one-hot blend write."""
    C = cache_arr.shape[1]
    onehot = jnp.arange(C, dtype=jnp.int32)[None, :] == slot[:, None]   # (b, C)
    oh = onehot.reshape(onehot.shape + (1,) * (cache_arr.ndim - 2))
    return jnp.where(oh, new.astype(cache_arr.dtype), cache_arr)


def _gqa_decode(cfg, ctx, p, x, *, window, cache, pos):
    """x: (b, 1, d); pos: (b,) absolute position of the new token."""
    head_tp = cfg.tp_strategy == "head" and ctx.model_axis is not None
    seq_tp = cfg.tp_strategy == "seq" and ctx.model_axis is not None
    b = x.shape[0]
    dh = cfg.d_head
    if head_tp:
        hq_loc = cfg.n_heads // ctx.tp
        kv_eff, _ = kv_eff_heads(cfg.n_kv_heads, ctx.tp)
        kv_loc = kv_eff // ctx.tp
    else:
        hq_loc, kv_loc = cfg.n_heads, cfg.n_kv_heads

    q = _split_heads(x @ p["wq"], hq_loc, dh)                # (b, 1, hq_loc, dh)
    k_new = _split_heads(x @ p["wk"], kv_loc, dh)
    v_new = _split_heads(x @ p["wv"], kv_loc, dh)
    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_norm"])
        k_new = _qk_normalize(k_new, p["k_norm"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    cap_loc = cache["pos"].shape[1]
    capacity_total = cap_loc * (ctx.tp if seq_tp else 1)
    slot = (pos % capacity_total).astype(jnp.int32)          # (b,)

    if seq_tp:
        # cache slots sharded over the model axis: write if the slot is mine
        start = ctx.index() * cap_loc
        local_slot = slot - start
        mine = (local_slot >= 0) & (local_slot < cap_loc)
        safe = jnp.clip(local_slot, 0, cap_loc - 1)
        kc = _ring_insert(cache["k"], k_new, safe)
        kc = jnp.where(mine[:, None, None, None], kc, cache["k"])
        vc = _ring_insert(cache["v"], v_new, safe)
        vc = jnp.where(mine[:, None, None, None], vc, cache["v"])
        pc = _ring_insert(cache["pos"], pos[:, None], safe)
        pc = jnp.where(mine[:, None], pc, cache["pos"])
        new_cache = {"k": kc, "v": vc, "pos": pc}
        o = _distributed_decode_attend(cfg, ctx, q, kc, vc, pc, pos, window)
    else:
        kc = _ring_insert(cache["k"], k_new, slot)
        vc = _ring_insert(cache["v"], v_new, slot)
        pc = _ring_insert(cache["pos"], pos[:, None], slot)
        new_cache = {"k": kc, "v": vc, "pos": pc}
        G = hq_loc // kv_loc
        qg = q.reshape(b, 1, kv_loc, G, dh)
        o = attention_core(qg, kc, vc, pos[:, None], pc,
                           window=window, cap=cfg.attn_softcap)
        o = o.reshape(b, 1, hq_loc * dh)

    y = o @ p["wo"]
    if head_tp:
        y = ctx.psum_model(y)                                # (b, 1, d)
    return y, new_cache


def _distributed_decode_attend(cfg, ctx, q, k_loc, v_loc, kpos_loc, pos, window):
    """Partial attention over the local cache shard + distributed logsumexp
    combine over the model axis (seq-TP decode)."""
    b, _, hq, dh = q.shape
    kv = k_loc.shape[2]
    G = hq // kv
    scale = 1.0 / np.sqrt(dh)
    qf = q.reshape(b, kv, G, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, k_loc.astype(jnp.float32)) * scale
    s = softcap(s, cfg.attn_softcap)
    m = kpos_loc[:, None, None, :] <= pos[:, None, None, None]
    if window is not None:
        m &= kpos_loc[:, None, None, :] > (pos[:, None, None, None] - window)
    s = jnp.where(m, s, NEG_INF)
    local_max = jnp.max(s, axis=-1)                          # (b, kv, G)
    gmax = ctx.pmax_model(local_max)
    w = jnp.exp(s - gmax[..., None]) * m
    den = ctx.psum_model(jnp.sum(w, axis=-1))                # (b, kv, G)
    num = ctx.psum_model(
        jnp.einsum("bkgc,bckd->bkgd", w, v_loc.astype(jnp.float32)))
    o = num / jnp.maximum(den[..., None], 1e-30)
    return o.reshape(b, 1, hq * dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_fwd(cfg, ctx, p, x, *, window, cache, pos):
    m = cfg.mla
    head_tp = ctx.model_axis is not None
    b, s_loc, d = x.shape
    hq_loc = cfg.n_heads // (ctx.tp if head_tp else 1)
    nope, rope_d, vd, r = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                           m.v_head_dim, m.kv_lora_rank)
    from repro.models.common import rmsnorm

    if pos is None:
        xg = ctx.gather_seq(x) if head_tp else x             # (b, s, d)
        s = xg.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        q = _split_heads(xg @ p["wq"], hq_loc, nope + rope_d)
        qn, qr = q[..., :nope], q[..., nope:]
        qr = apply_rope(qr, positions, cfg.rope_theta)
        dkv = xg @ p["w_dkv"]                                # (b, s, r+rope)
        latent = rmsnorm(dkv[..., :r], p["kv_norm"])
        k_rope = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)  # (b,s,1,rope)
        kn = _split_heads(latent @ p["w_uk"], hq_loc, nope)
        vv = _split_heads(latent @ p["w_uv"], hq_loc, vd)
        k = jnp.concatenate([kn, jnp.broadcast_to(k_rope, kn.shape[:-1] + (rope_d,))], -1)
        # GQA form: kv heads = hq_loc, G = 1 (attention_core allows v_dim != qk_dim)
        qg = jnp.concatenate([qn, qr], -1).reshape(b, s, hq_loc, 1, nope + rope_d)
        o = attention_core(qg, k, vv, q_pos=positions,
                           k_pos=positions, window=window, cap=cfg.attn_softcap)
        o = o.reshape(b, s, hq_loc * vd)
        y = o @ p["wo"]
        y = ctx.scatter_seq(y) if head_tp else y
        new_cache = None
        if cache is not None:
            new_cache = _fill_mla_cache(cache, latent, k_rope[:, :, 0, :], positions)
        return y, new_cache
    return _mla_decode(cfg, ctx, p, x, window=window, cache=cache, pos=pos)


def _fill_mla_cache(cache, latent, rope_post, positions):
    """Store the last `capacity` latents + post-rope rotary keys in the ring."""
    b, s, r = latent.shape
    capacity = cache["pos"].shape[1]
    take = min(s, capacity)
    lat, rp = latent[:, s - take:], rope_post[:, s - take:]
    pos_last = positions[s - take:]
    slots = pos_last % capacity
    ring_lat = jnp.zeros((b, capacity, r), cache["latent"].dtype).at[:, slots].set(
        lat.astype(cache["latent"].dtype))
    ring_rope = jnp.zeros((b, capacity, rp.shape[-1]), cache["k_rope"].dtype
                          ).at[:, slots].set(rp.astype(cache["k_rope"].dtype))
    ring_pos = jnp.full((b, capacity), POS_SENTINEL, jnp.int32).at[:, slots].set(
        jnp.broadcast_to(pos_last[None], (b, take)))
    return {"latent": ring_lat, "k_rope": ring_rope, "pos": ring_pos}


def _mla_decode(cfg, ctx, p, x, *, window, cache, pos):
    """Absorbed low-rank MLA decode: scores and values stay in latent space."""
    m = cfg.mla
    head_tp = ctx.model_axis is not None
    b = x.shape[0]
    hq_loc = cfg.n_heads // (ctx.tp if head_tp else 1)
    nope, rope_d, vd, r = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                           m.v_head_dim, m.kv_lora_rank)
    from repro.models.common import rmsnorm

    q = _split_heads(x @ p["wq"], hq_loc, nope + rope_d)     # (b,1,h,qd)
    qn, qr = q[..., :nope], q[..., nope:]
    qr = apply_rope(qr, pos[:, None], cfg.rope_theta)
    dkv = x @ p["w_dkv"]
    latent_new = rmsnorm(dkv[..., :r], p["kv_norm"])         # (b,1,r)
    krope_new = apply_rope(dkv[..., None, r:], pos[:, None], cfg.rope_theta)[:, :, 0]

    capacity = cache["pos"].shape[1]
    slot = (pos % capacity).astype(jnp.int32)
    lat_c = _ring_insert(cache["latent"], latent_new, slot)
    rope_c = _ring_insert(cache["k_rope"], krope_new, slot)
    pos_c = _ring_insert(cache["pos"], pos[:, None], slot)
    new_cache = {"latent": lat_c, "k_rope": rope_c, "pos": pos_c}

    # absorb W_uk into q: (b,1,h,nope) @ (r, h*nope) -> (b,h,r)
    w_uk = p["w_uk"].reshape(r, hq_loc, nope)
    qlat = jnp.einsum("bhn,rhn->bhr", qn[:, 0].astype(jnp.float32),
                      w_uk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(nope + rope_d)
    s_lat = jnp.einsum("bhr,bcr->bhc", qlat, lat_c.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bcd->bhc", qr[:, 0].astype(jnp.float32),
                        rope_c.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    s = softcap(s, cfg.attn_softcap)
    mask = pos_c[:, None, :] <= pos[:, None, None]
    if window is not None:
        mask &= pos_c[:, None, :] > (pos[:, None, None] - window)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhc,bcr->bhr", w, lat_c.astype(jnp.float32))  # (b,h,r)
    w_uv = p["w_uv"].reshape(r, hq_loc, vd)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, hq_loc * vd).astype(x.dtype)
    y = o @ p["wo"]
    if head_tp:
        y = ctx.psum_model(y)
    return y, new_cache
