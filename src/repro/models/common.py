"""Shared model machinery: shard context, norms, RoPE, parameter specs.

Parameter handling has one source of truth: :func:`param_defs` builders
return a pytree of :class:`ParamDef` (shape + sharded dims + init rule).
From it we derive
  * concrete arrays            (``instantiate``)
  * ``jax.ShapeDtypeStruct``s  (``abstract``)      — for the dry-run
  * ``PartitionSpec``s         (``pspec``)         — for pjit/shard_map

Model forward code is written against LOCAL (per-device) shapes inside
``shard_map``; :class:`ShardCtx` carries the mesh axis names and the
collective helpers, all of which degrade to no-ops at tp=1 so the same code
runs single-device in the CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

PyTree = Any


# ---------------------------------------------------------------------------
# Shard context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardCtx:
    """Mesh axes visible to model code (inside shard_map)."""

    model_axis: Optional[str] = None     # tensor-parallel axis name
    dp_axes: Tuple[str, ...] = ()        # data-parallel axes (consistency sync)
    tp: int = 1                          # size of the model axis

    # ---- collectives (no-ops at tp == 1) ------------------------------------
    def index(self) -> jnp.ndarray:
        if self.model_axis is None:
            return jnp.zeros((), jnp.int32)
        return lax.axis_index(self.model_axis)

    def gather_seq(self, x: jnp.ndarray, axis: int = 1,
                   compress: bool = False) -> jnp.ndarray:
        """(…, s/tp, …) -> (…, s, …): sequence-parallel all-gather.

        compress=True sends int8 with a per-shard scale (halves the gather
        volume vs bf16 at ~0.4% activation error — EXPERIMENTS §Perf)."""
        if self.model_axis is None:
            return x
        if not compress or not jnp.issubdtype(x.dtype, jnp.floating):
            return lax.all_gather(x, self.model_axis, axis=axis, tiled=True)
        scale = (jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        qg = lax.all_gather(q, self.model_axis, axis=axis, tiled=True)
        sg = lax.all_gather(scale[None], self.model_axis)        # (tp,)
        # de-quantize block-wise: axis is a concat of tp per-shard blocks
        shape = qg.shape
        loc = shape[axis] // self.tp
        blocked = qg.reshape(shape[:axis] + (self.tp, loc) + shape[axis + 1:])
        s_shape = (1,) * axis + (self.tp, 1) + (1,) * (len(shape) - axis - 1)
        out = blocked.astype(jnp.float32) * sg.reshape(s_shape)
        return out.reshape(shape).astype(x.dtype)

    def scatter_seq(self, x: jnp.ndarray, axis: int = 1) -> jnp.ndarray:
        """(…, s, …) partial-sums -> (…, s/tp, …): reduce-scatter."""
        if self.model_axis is None:
            return x
        return lax.psum_scatter(x, self.model_axis, scatter_dimension=axis,
                                tiled=True)

    def psum_model(self, x):
        if self.model_axis is None:
            return x
        return lax.psum(x, self.model_axis)

    def pmax_model(self, x):
        if self.model_axis is None:
            return x
        return lax.pmax(x, self.model_axis)

    def all_to_all(self, x: jnp.ndarray, split_axis: int, concat_axis: int) -> jnp.ndarray:
        if self.model_axis is None:
            return x
        return lax.all_to_all(x, self.model_axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: global shape + sharding + init rule."""

    shape: Tuple[int, ...]
    shard: Tuple[Optional[str], ...] = ()    # per-dim mesh axis (or None)
    init: str = "fan_in"                     # fan_in | zeros | ones | embed | kv_dup
    scale: float = 1.0
    dtype: Any = jnp.float32
    # kv_dup: generate (d, base_heads, hd) and repeat heads `rep`× -> shape
    kv_base_heads: int = 0
    kv_rep: int = 1

    def __post_init__(self):
        if self.shard and len(self.shard) != len(self.shape):
            raise ValueError(f"shard {self.shard} vs shape {self.shape}")

    def instantiate(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            return (jax.random.normal(key, self.shape, self.dtype)
                    * jnp.asarray(self.scale, self.dtype))
        if self.init == "fan_in":
            # fan-in = the matmul input dim (second-to-last; robust to
            # scan-stacked leading dims)
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale / np.sqrt(max(fan_in, 1))
            return (jax.random.truncated_normal(key, -3, 3, self.shape, self.dtype)
                    * jnp.asarray(std, self.dtype))
        if self.init == "kv_dup":
            # duplicated-KV layout: identical weights for replicated kv heads.
            # shape is (*lead, d, heads*hd); duplication happens on the head
            # axis of the LAST dim (robust to scan-stacked leading dims).
            lead, d, rest = self.shape[:-2], self.shape[-2], self.shape[-1]
            hd = rest // (self.kv_base_heads * self.kv_rep)
            std = self.scale / np.sqrt(d)
            base = (jax.random.truncated_normal(
                key, -3, 3, lead + (d, self.kv_base_heads, hd), self.dtype)
                * jnp.asarray(std, self.dtype))
            full = jnp.repeat(base, self.kv_rep, axis=-2)
            return full.reshape(self.shape)
        raise ValueError(f"unknown init {self.init!r}")

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def pspec(self) -> P:
        if not self.shard:
            return P()
        return P(*self.shard)


def instantiate_tree(defs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.instantiate(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.abstract(), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def pspec_tree(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.pspec(), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def local_view(defs: PyTree, tp: int) -> PyTree:
    """ShapeDtypeStructs of the per-device (local) shapes at tensor-parallel
    degree tp — used by tests to sanity-check the forward code's layout."""

    def loc(d: ParamDef) -> jax.ShapeDtypeStruct:
        shape = list(d.shape)
        for i, ax in enumerate(d.shard or ()):
            if ax == "model":
                if shape[i] % tp:
                    raise ValueError(f"dim {i} of {d.shape} not divisible by tp={tp}")
                shape[i] //= tp
        return jax.ShapeDtypeStruct(tuple(shape), d.dtype)

    return jax.tree.map(loc, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: Optional[jnp.ndarray], eps: float = 1e-6,
            gemma_style: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    if scale is not None:
        s = scale.astype(jnp.float32)
        x = x * (1.0 + s) if gemma_style else x * s
    return x.astype(dt)


def layernorm(x: jnp.ndarray, scale: Optional[jnp.ndarray],
              bias: Optional[jnp.ndarray], eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(kind: str, x: jnp.ndarray, params: Optional[Dict]) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if kind == "gemma_rmsnorm":
        return rmsnorm(x, params["scale"], gemma_style=True)
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if kind == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


def norm_defs(kind: str, d: int) -> Optional[Dict[str, ParamDef]]:
    if kind in ("rmsnorm",):
        return {"scale": ParamDef((d,), (None,), init="ones")}
    if kind == "gemma_rmsnorm":
        return {"scale": ParamDef((d,), (None,), init="zeros")}   # (1+scale)
    if kind == "layernorm":
        return {"scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros")}
    if kind == "nonparam_ln":
        return {}   # empty dict keeps the pytree structure homogeneous
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (b, s, h, hd); positions: (b, s) or (s,) int32."""
    dt = x.dtype
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                              # (b, s, hd/2) / (s, hd/2)
    if ang.ndim == 2:                                       # (s, hd/2)
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Activations / misc
# ---------------------------------------------------------------------------


def activation(kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def kv_eff_heads(n_kv: int, tp: int) -> Tuple[int, int]:
    """(effective kv heads after duplication, repeat factor)."""
    if n_kv >= tp:
        if n_kv % tp:
            raise ValueError(f"n_kv={n_kv} not divisible by tp={tp}")
        return n_kv, 1
    if tp % n_kv:
        raise ValueError(f"tp={tp} not a multiple of n_kv={n_kv}")
    return tp, tp // n_kv
