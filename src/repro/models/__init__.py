from repro.models import attention, common, model, moe, recurrent
from repro.models.common import ShardCtx
from repro.models.model import (decode_step, forward, lm_loss, model_cache_defs,
                                model_defs, prefill, sample_greedy)

__all__ = [
    "ShardCtx", "attention", "common", "decode_step", "forward", "lm_loss",
    "model", "model_cache_defs", "model_defs", "moe", "prefill", "recurrent",
    "sample_greedy",
]
