"""Consistency policies — the paper's §2 models as data.

A :class:`Policy` is pure data; the *Consistency Controller*
(:mod:`repro.core.controller`) interprets it.  This mirrors the paper's §4.3
split between *Consistency Policy* (data structure) and *Consistency
Controller* (logic), and the same Policy object drives both the faithful
asynchronous simulator (:mod:`repro.core.server`) and the TPU/SPMD sync layer
(:mod:`repro.core.sync`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ConsistencySpec

INF = math.inf


@dataclass(frozen=True)
class Policy:
    """A bounded-asynchronous consistency policy.

    kind         one of bsp | ssp | cap | vap | cvap
    staleness    s — clock bound (ssp / cap / cvap).  A worker at clock c is
                 guaranteed to see all updates timestamped ≤ c - s - 1.
    value_bound  v_thr — value bound (vap / cvap).  A worker's accumulated
                 unsynchronized updates per parameter stay ≤ max(u, v_thr).
    strong       strong-VAP: additionally bounds the total magnitude of
                 *half-synchronized* updates per parameter by max(u, v_thr),
                 giving divergence ≤ 2·max(u, v_thr) independent of P.
    push_at_clock_only
                 SSP semantics: updates leave the worker only during the
                 synchronization phase.  CAP/VAP/CVAP push updates as soon as
                 network bandwidth is available.
    """

    kind: str
    staleness: int = 0
    value_bound: float = INF
    strong: bool = False
    push_at_clock_only: bool = False

    def __post_init__(self):
        if self.kind not in ("bsp", "ssp", "cap", "vap", "cvap"):
            raise ValueError(f"unknown consistency kind {self.kind!r}")
        if self.staleness < 0:
            raise ValueError("staleness must be >= 0")
        if self.value_bound <= 0:
            raise ValueError("value_bound must be > 0")

    # --- which bounds are active -------------------------------------------
    @property
    def clock_bounded(self) -> bool:
        return self.kind in ("bsp", "ssp", "cap", "cvap")

    @property
    def value_bounded(self) -> bool:
        return self.kind in ("vap", "cvap") and self.value_bound != INF


def bsp() -> Policy:
    return Policy("bsp", staleness=0, push_at_clock_only=True)


def ssp(staleness: int) -> Policy:
    return Policy("ssp", staleness=staleness, push_at_clock_only=True)


def cap(staleness: int) -> Policy:
    return Policy("cap", staleness=staleness)


def vap(value_bound: float, strong: bool = False) -> Policy:
    return Policy("vap", value_bound=value_bound, strong=strong)


def cvap(staleness: int, value_bound: float, strong: bool = False) -> Policy:
    return Policy("cvap", staleness=staleness, value_bound=value_bound,
                  strong=strong)


def from_spec(spec: ConsistencySpec) -> Policy:
    kind = spec.model.lower()
    if kind == "bsp":
        return bsp()
    if kind == "ssp":
        return ssp(spec.staleness)
    if kind == "cap":
        return cap(spec.staleness)
    if kind == "vap":
        return vap(spec.value_bound or INF, spec.strong)
    if kind == "cvap":
        return cvap(spec.staleness, spec.value_bound or INF, spec.strong)
    raise ValueError(f"unknown consistency model {spec.model!r}")
