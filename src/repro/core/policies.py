"""Consistency policies — the paper's §2 models as data.

A :class:`Policy` is pure data; the *Consistency Controller*
(:mod:`repro.core.controller`) interprets it.  This mirrors the paper's §4.3
split between *Consistency Policy* (data structure) and *Consistency
Controller* (logic), and the same Policy object drives both the faithful
asynchronous simulator (:mod:`repro.core.server`) and the TPU/SPMD sync layer
(:mod:`repro.core.sync`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ConsistencySpec

INF = math.inf


@dataclass(frozen=True)
class Policy:
    """A bounded-asynchronous consistency policy.

    kind         one of bsp | ssp | cap | essp | vap | cvap | elastic
    staleness    s — clock bound (ssp / cap / essp / cvap).  A worker at clock
                 c is guaranteed to see all updates timestamped ≤ c - s - 1.
                 ESSP (arXiv:1410.8043) keeps the SSP gate but the server
                 eagerly pushes applied deltas to every worker at each clock
                 boundary, so *observed* staleness sits well below s.
    value_bound  v_thr — value bound (vap / cvap): a worker's accumulated
                 unsynchronized updates per parameter stay ≤ max(u, v_thr).
                 For kind "elastic" (arXiv:2001.05918) the same field is the
                 elastic bound B on the L2 *norm* of the worker's whole
                 unobserved-update sum: ‖Σ unsynced‖₂ ≤ max(‖u‖₂, B).
    strong       strong-VAP: additionally bounds the total magnitude of
                 *half-synchronized* updates per parameter by max(u, v_thr),
                 giving divergence ≤ 2·max(u, v_thr) independent of P.
    push_at_clock_only
                 SSP semantics: updates leave the worker only during the
                 synchronization phase.  CAP/ESSP/VAP/CVAP/elastic push
                 updates as soon as network bandwidth is available.

    Construction rejects arguments the kind does not interpret (a staleness
    on vap, a value bound on ssp, ...) instead of silently dropping them.
    """

    kind: str
    staleness: int = 0
    value_bound: float = INF
    strong: bool = False
    push_at_clock_only: bool = False

    def __post_init__(self):
        if self.kind not in ("bsp", "ssp", "cap", "essp", "vap", "cvap",
                             "elastic"):
            raise ValueError(f"unknown consistency kind {self.kind!r}")
        if self.staleness < 0:
            raise ValueError("staleness must be >= 0")
        if self.value_bound <= 0:
            raise ValueError("value_bound must be > 0")
        # inactive-bound arguments are errors, not no-ops: every parameter a
        # caller passes must be one the controller actually reads for this
        # kind, otherwise Policy("vap", staleness=3) silently runs unbounded
        # in clock and Policy("ssp", value_bound=0.5) silently runs
        # unbounded in value.
        if self.staleness != 0 and not self.clock_bounded:
            raise ValueError(
                f"kind {self.kind!r} does not interpret a staleness bound "
                f"(got staleness={self.staleness})")
        if self.value_bound != INF and self.kind not in ("vap", "cvap",
                                                         "elastic"):
            raise ValueError(
                f"kind {self.kind!r} does not interpret a value bound "
                f"(got value_bound={self.value_bound})")
        if self.strong and self.kind not in ("vap", "cvap"):
            raise ValueError(
                f"strong delivery gating only applies to vap/cvap "
                f"(got kind {self.kind!r})")
        if self.push_at_clock_only and self.kind in ("essp", "elastic"):
            raise ValueError(
                f"kind {self.kind!r} is constitutively eager; "
                f"push_at_clock_only does not apply")

    # --- which bounds are active -------------------------------------------
    @property
    def clock_bounded(self) -> bool:
        return self.kind in ("bsp", "ssp", "cap", "essp", "cvap")

    @property
    def value_bounded(self) -> bool:
        return self.kind in ("vap", "cvap") and self.value_bound != INF

    @property
    def norm_bounded(self) -> bool:
        """Elastic consistency: one bound on ‖unsynced sum‖₂ per worker."""
        return self.kind == "elastic" and self.value_bound != INF

    @property
    def tracks_sync(self) -> bool:
        """Does the runtime need exact delivered-update accounting (the
        unsynced accumulators + FullyDelivered ack path)?  True for any
        value- or norm-bounded policy."""
        return self.value_bounded or self.norm_bounded

    @property
    def server_push_on_boundary(self) -> bool:
        """ESSP: shards coalesce applied deltas per destination and push one
        frame per peer at every clock boundary (eager server push)."""
        return self.kind == "essp"


def bsp() -> Policy:
    return Policy("bsp", staleness=0, push_at_clock_only=True)


def ssp(staleness: int) -> Policy:
    return Policy("ssp", staleness=staleness, push_at_clock_only=True)


def cap(staleness: int) -> Policy:
    return Policy("cap", staleness=staleness)


def vap(value_bound: float, strong: bool = False) -> Policy:
    return Policy("vap", value_bound=value_bound, strong=strong)


def cvap(staleness: int, value_bound: float, strong: bool = False) -> Policy:
    return Policy("cvap", staleness=staleness, value_bound=value_bound,
                  strong=strong)


def essp(staleness: int) -> Policy:
    """Eager SSP: SSP's clock gate, server pushes at every clock boundary."""
    return Policy("essp", staleness=staleness)


def elastic(norm_bound: float) -> Policy:
    """Elastic consistency: ‖worker's unsynced sum‖₂ ≤ max(‖u‖₂, B)."""
    return Policy("elastic", value_bound=norm_bound)


def from_spec(spec: ConsistencySpec) -> Policy:
    kind = spec.model.lower()
    if kind == "bsp":
        return bsp()
    if kind == "ssp":
        return ssp(spec.staleness)
    if kind == "cap":
        return cap(spec.staleness)
    if kind == "essp":
        return essp(spec.staleness)
    if kind == "vap":
        return vap(spec.value_bound or INF, spec.strong)
    if kind == "cvap":
        return cvap(spec.staleness, spec.value_bound or INF, spec.strong)
    if kind == "elastic":
        return elastic(spec.value_bound or INF)
    raise ValueError(f"unknown consistency model {spec.model!r}")
