"""Theoretical bounds from the paper (§2.2, §3) as executable formulas.

These are used by the validation tests and benchmarks to compare measured
quantities against the paper's claims:

* Lemma 1   : |A_t| + |B_t| ≤ 2·v_thr·(P-1)
* Theorem 1 : R[X] ≤ σL²√T + F²√T/σ + 2σLv_thr·P·√T  with σ = F/(L√(v_thr·P))
* weak VAP  : |θ_A - θ_B| ≤ max(u, v_thr)·P
* strong VAP: |θ_A - θ_B| ≤ 2·max(u, v_thr)
"""
from __future__ import annotations

import math

import numpy as np


def sigma_star(F: float, L: float, v_thr: float, P: int) -> float:
    """The paper's step-size constant σ = F / (L·sqrt(v_thr·P))."""
    return F / (L * math.sqrt(max(v_thr * P, 1e-30)))


def step_size(t: int, F: float, L: float, v_thr: float, P: int) -> float:
    """η_t = σ/√t (t is 1-based)."""
    return sigma_star(F, L, v_thr, P) / math.sqrt(t)


def lemma1_bound(v_thr: float, P: int) -> float:
    """Bound on the aggregate missing+extra update mass at any t."""
    return 2.0 * v_thr * (P - 1)


def theorem1_regret_bound(T: int, F: float, L: float, v_thr: float, P: int) -> float:
    """Upper bound on the cumulative regret R[X] after T component steps."""
    s = sigma_star(F, L, v_thr, P)
    return (s * L**2 * math.sqrt(T)
            + F**2 * math.sqrt(T) / s
            + 2.0 * s * L * v_thr * P * math.sqrt(T))


def theorem1_regret_curve(T: int, F: float, L: float, v_thr: float, P: int) -> np.ndarray:
    """Bound evaluated at every t in [1, T] (for convergence plots)."""
    t = np.arange(1, T + 1, dtype=np.float64)
    s = sigma_star(F, L, v_thr, P)
    return s * L**2 * np.sqrt(t) + F**2 * np.sqrt(t) / s + 2.0 * s * L * v_thr * P * np.sqrt(t)


def weak_vap_divergence_bound(u: float, v_thr: float, P: int) -> float:
    """|θ_A − θ_B| ≤ max(u, v_thr)·P under weak VAP (§2.2)."""
    return max(u, v_thr) * P


def strong_vap_divergence_bound(u: float, v_thr: float) -> float:
    """|θ_A − θ_B| ≤ 2·max(u, v_thr) under strong VAP — independent of P."""
    return 2.0 * max(u, v_thr)


def regret_is_sublinear(regret: np.ndarray, tol: float = 0.0) -> bool:
    """Check R[X]_t / t is (eventually) decreasing — the o(T) condition that
    implies convergence in Theorem 1."""
    t = np.arange(1, len(regret) + 1)
    avg = regret / t
    n = len(avg)
    head = avg[: max(n // 4, 1)].mean()
    tail = avg[-max(n // 4, 1):].mean()
    return tail <= head + tol
