"""The paper's contribution: bounded-asynchronous consistency models.

Two layers (DESIGN.md §3):
  * faithful semantics — :mod:`repro.core.server` (event-driven async PS
    simulator) with :mod:`repro.core.controller` deciding block/admit;
  * TPU/SPMD adaptation — :mod:`repro.core.sync` (per-replica drift with
    clock/value-triggered delta all-reduce).
"""
from repro.core import controller, policies, theory
from repro.core.client import ThreadCache, app_update_fn, run_app
from repro.core.policies import (Policy, bsp, cap, cvap, elastic, essp,
                                 from_spec, ssp, vap)
from repro.core.server import AsyncPS, NetworkModel, RunStats, Update, ViewHandle
from repro.core.sync import (SyncState, apply_and_sync, elastic_invariant_ok,
                             force_sync, init_sync_state, sync_trigger,
                             tree_l2_norm, tree_max_abs, vap_invariant_ok)
from repro.core.tables import Row, SparseRow, Table, TableGroup
from repro.core.vector_clock import VectorClock

__all__ = [
    "AsyncPS", "NetworkModel", "Policy", "Row", "RunStats", "SparseRow",
    "SyncState", "Table", "TableGroup", "ThreadCache", "Update", "VectorClock",
    "ViewHandle", "app_update_fn", "apply_and_sync", "bsp", "cap",
    "controller", "cvap", "elastic", "elastic_invariant_ok", "essp",
    "force_sync", "from_spec", "init_sync_state",
    "policies", "run_app", "ssp", "sync_trigger", "theory", "tree_l2_norm",
    "tree_max_abs", "vap", "vap_invariant_ok",
]
