"""Client library: the Get/Inc/Clock application API (paper §4.1–4.2).

The thread cache is a write-back overlay on the process cache: Gets are
serviced locally (base view + own pending writes → read-my-writes), Incs
accumulate in the write-back cache and are handed to the parameter server at
the end of the period (coalesced per key — the paper's message batching).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.server import AsyncPS, ViewHandle


class ThreadCache:
    """Write-back thread cache for one worker thread."""

    def __init__(self, view: ViewHandle):
        self._view = view
        self._writes: Dict[str, np.ndarray] = {}
        self.hits = 0
        self.misses = 0
        self._local: Dict[str, np.ndarray] = {}

    # --- Get(table, row) -----------------------------------------------------
    def get(self, key: str) -> np.ndarray:
        if key in self._local:
            self.hits += 1
            return self._local[key]
        self.misses += 1   # fetch from process cache
        base = self._view.get(key)
        w = self._writes.get(key)
        val = base + w if w is not None else base
        self._local[key] = val
        return val

    # --- Inc(table, row, delta) ----------------------------------------------
    def inc(self, key: str, delta) -> None:
        delta = np.asarray(delta, dtype=np.float64)
        if key in self._writes:
            self._writes[key] = self._writes[key] + delta
        else:
            self._writes[key] = delta.copy()
        if key in self._local:          # read-my-writes within the period
            self._local[key] = self._local[key] + delta

    # --- Clock() → write-back ------------------------------------------------
    def flush(self) -> Dict[str, np.ndarray]:
        out = self._writes
        self._writes = {}
        self._local = {}
        return out


def app_update_fn(app: Callable) -> Callable:
    """Adapt `app(worker, clock, cache: ThreadCache, rng)` (imperative
    Get/Inc style) into the simulator's batch update_fn."""

    def update_fn(worker: int, clock: int, view: ViewHandle, rng) -> Dict[str, np.ndarray]:
        cache = ThreadCache(view)
        app(worker, clock, cache, rng)
        return cache.flush()

    return update_fn


def run_app(ps: AsyncPS, app: Callable, n_clocks: int, **kw):
    """Convenience: run an imperative Get/Inc/Clock app on the simulator."""
    return ps.run(app_update_fn(app), n_clocks, **kw)
