"""Vector clocks (paper §4.2).

Each client library maintains a vector clock over its worker threads; the
minimum entry is the process's progress.  The server keeps a vector clock
over processes.
"""
from __future__ import annotations

import numpy as np


class VectorClock:
    def __init__(self, n_entries: int):
        self._c = np.zeros(n_entries, dtype=np.int64)

    def tick(self, entry: int) -> int:
        self._c[entry] += 1
        return int(self._c[entry])

    def set(self, entry: int, value: int) -> None:
        if value < self._c[entry]:
            raise ValueError(
                f"vector clock entry {entry} would move backwards "
                f"({self._c[entry]} -> {value})")
        self._c[entry] = value

    def get(self, entry: int) -> int:
        return int(self._c[entry])

    def min(self) -> int:
        return int(self._c.min())

    def max(self) -> int:
        return int(self._c.max())

    def snapshot(self) -> np.ndarray:
        return self._c.copy()

    def __len__(self) -> int:
        return len(self._c)

    def __repr__(self) -> str:
        return f"VectorClock({self._c.tolist()})"
