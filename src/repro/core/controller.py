"""Consistency Controller (paper §4.3, Fig. 3).

Pure decision logic: given a policy and the relevant worker/server state,
decide whether an access may proceed or must block, and what condition wakes
it.  The controller is deliberately side-effect free so it can be unit- and
property-tested in isolation; the event-driven simulator
(:mod:`repro.core.server`) and the SPMD sync layer (:mod:`repro.core.sync`)
both consult it.

Semantics implemented (paper §2):

* **Clock bound** (BSP/SSP/CAP/CVAP): a worker whose clock is ``c`` must see
  every update timestamped ``≤ c - s - 1`` from every other worker, else it
  blocks (fast workers wait for slow ones).

* **Value bound** (VAP/CVAP): applying an update that would push the
  element-wise accumulated *unsynchronized* sum beyond ``v_thr`` blocks the
  worker — unless the accumulator is zero at the violating elements, which
  admits a single update of magnitude ``> v_thr`` (hence the paper's
  ``max(u, v_thr)`` bound, Fig. 1).

* **Strong-VAP delivery gate**: an update may begin *partial* delivery only
  while the total magnitude of half-synchronized updates for its parameter
  stays within ``max(u, v_thr)``; otherwise it queues behind them.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.policies import Policy


def clock_gate(policy: Policy, my_clock: int,
               delivered_frontier: np.ndarray) -> bool:
    """May a worker at clock ``my_clock`` begin its next computation?

    ``delivered_frontier[q]`` is the highest timestamp T such that ALL
    updates from peer q with timestamp ≤ T have been delivered to this
    worker (-1 if none needed yet).
    """
    if not policy.clock_bounded:
        return True
    need = my_clock - policy.staleness - 1
    if need < 0:
        return True
    return bool(np.all(delivered_frontier >= need))


def observed_staleness(my_clock: int, delivered_frontier: np.ndarray) -> int:
    """Worst-case staleness this read experiences (for invariant checks)."""
    if len(delivered_frontier) == 0:
        return 0
    return int(my_clock - delivered_frontier.min() - 1)


def value_gate(policy: Policy, unsynced: np.ndarray,
               delta: np.ndarray) -> Tuple[bool, np.ndarray]:
    """May this update be applied under the value bound?

    Returns ``(ok, violating_mask)``.  Element-wise: blocked where the new
    accumulated magnitude would exceed v_thr AND the current accumulator is
    non-zero (a lone oversized update is admitted — paper Fig. 1 semantics,
    yielding the max(u, v_thr) bound).
    """
    if not policy.value_bounded:
        return True, np.zeros_like(delta, dtype=bool)
    new_acc = np.abs(unsynced + delta)
    # the 1e-12 tolerance absorbs float residue left by add/subtract cycles
    violating = (new_acc > policy.value_bound) & (np.abs(unsynced) > 1e-12)
    return not bool(violating.any()), violating


def strong_delivery_gate(policy: Policy, halfsync_mag: np.ndarray,
                         delta: np.ndarray) -> bool:
    """May this update begin partial delivery (strong VAP only)?"""
    if not (policy.value_bounded and policy.strong):
        return True
    mag = np.abs(delta)
    budget = np.maximum(policy.value_bound, mag)   # max(u, v_thr), element-wise
    # admit if nothing is currently half-synchronized at the violating spots
    # (1e-12 tolerance absorbs float residue left by add/subtract cycles)
    total = halfsync_mag + mag
    violating = (total > budget) & (halfsync_mag > 1e-12)
    return not bool(violating.any())


def vap_unsynced_bound(policy: Policy, max_update_mag: float) -> float:
    """The guaranteed bound on any worker's unsynchronized accumulator."""
    return max(max_update_mag, policy.value_bound)


def elastic_gate(policy: Policy, acc_norm: float, new_norm: float) -> bool:
    """May this update be applied under the elastic norm bound?

    ``acc_norm`` is the L2 norm of the worker's *whole* unsynchronized
    accumulator (all keys stacked) before the update, ``new_norm`` the norm
    it would have after.  Blocked when the new norm would exceed B AND the
    accumulator is non-empty — a lone oversized update is admitted, mirroring
    VAP's Fig. 1 semantics and yielding the ``max(‖u‖₂, B)`` bound.
    """
    if not policy.norm_bounded:
        return True
    if new_norm <= policy.value_bound + 1e-9:
        return True
    # the 1e-12 tolerance absorbs float residue left by add/subtract cycles
    return acc_norm <= 1e-12


def elastic_unsynced_bound(policy: Policy, max_update_norm: float) -> float:
    """The guaranteed bound on ‖any worker's unsynced sum‖₂ (elastic)."""
    return max(max_update_norm, policy.value_bound)
