"""Petuum-PS table abstraction (paper §4.1).

Shared parameters are organized as tables; an element is addressed by
(table_id, row_id, column_id).  Rows are the unit of distribution and
transmission; both dense and sparse rows are supported.  For vectorized ML
workloads a whole row is a numpy array and updates are row deltas — the
consistency bounds (VAP) are enforced *element-wise*, matching the paper's
per-parameter semantics.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class Row:
    """A dense row of parameters."""

    __slots__ = ("data",)

    def __init__(self, n_cols: int, dtype=np.float64, data: Optional[np.ndarray] = None):
        self.data = np.zeros(n_cols, dtype=dtype) if data is None else data

    def get(self, col: Optional[int] = None):
        return self.data.copy() if col is None else self.data[col]

    def inc(self, delta, col: Optional[int] = None) -> None:
        if col is None:
            self.data += delta
        else:
            self.data[col] += delta


class SparseRow:
    """A sparse row: dict of column -> value."""

    __slots__ = ("cols",)

    def __init__(self):
        self.cols: Dict[int, float] = {}

    def get(self, col: Optional[int] = None):
        if col is None:
            return dict(self.cols)
        return self.cols.get(col, 0.0)

    def inc(self, delta, col: Optional[int] = None) -> None:
        if col is None:  # delta is a dict
            for c, d in delta.items():
                v = self.cols.get(c, 0.0) + d
                if v == 0.0:
                    self.cols.pop(c, None)
                else:
                    self.cols[c] = v
        else:
            v = self.cols.get(col, 0.0) + delta
            if v == 0.0:
                self.cols.pop(col, None)
            else:
                self.cols[col] = v


class Table:
    """A (possibly sparse) table of rows, hash-partitionable by row id."""

    def __init__(self, table_id: str, n_cols: int, dtype=np.float64,
                 sparse: bool = False):
        self.table_id = table_id
        self.n_cols = n_cols
        self.dtype = dtype
        self.sparse = sparse
        self._rows: Dict[int, object] = {}

    def row(self, row_id: int):
        r = self._rows.get(row_id)
        if r is None:
            r = SparseRow() if self.sparse else Row(self.n_cols, self.dtype)
            self._rows[row_id] = r
        return r

    def get(self, row_id: int, col: Optional[int] = None):
        return self.row(row_id).get(col)

    def inc(self, row_id: int, delta, col: Optional[int] = None) -> None:
        self.row(row_id).inc(delta, col)

    def rows(self) -> Iterator[Tuple[int, object]]:
        return iter(self._rows.items())

    def server_partition(self, n_servers: int, server: int):
        """Rows owned by `server` under hash partitioning (paper §4.1)."""
        return {rid: r for rid, r in self._rows.items()
                if rid % n_servers == server}

    def dense_snapshot(self, n_rows: int) -> np.ndarray:
        out = np.zeros((n_rows, self.n_cols), dtype=self.dtype)
        for rid, r in self._rows.items():
            if rid < n_rows:
                if self.sparse:
                    for c, v in r.cols.items():
                        out[rid, c] = v
                else:
                    out[rid] = r.data
        return out


class TableGroup:
    """All tables of one application.  Different tables may use different
    consistency policies (paper §4.1) — the policy map lives here."""

    def __init__(self):
        self._tables: Dict[str, Table] = {}
        self.policies: Dict[str, object] = {}

    def create(self, table_id: str, n_cols: int, dtype=np.float64,
               sparse: bool = False, policy=None) -> Table:
        if table_id in self._tables:
            raise KeyError(f"table {table_id!r} already exists")
        t = Table(table_id, n_cols, dtype, sparse)
        self._tables[table_id] = t
        if policy is not None:
            self.policies[table_id] = policy
        return t

    def __getitem__(self, table_id: str) -> Table:
        return self._tables[table_id]

    def __contains__(self, table_id: str) -> bool:
        return table_id in self._tables

    def __iter__(self):
        return iter(self._tables.values())
